"""Span tracer — the checkpoint lifecycle as an append-only JSONL trace.

Every stage a checkpoint moves through, from the trainer's fsync to a
served query batch, is recorded as a *span* (an interval) or an *event*
(an instant) in a per-process trace file.  The lifecycle vocabulary, in
hand-off order::

    produced   -> trainer committed the checkpoint (event)
    snapshotted-> hand-off channel published a pre-durable host snapshot
                  (event; absent on the classic durable-only path)
    discovered -> watcher saw the COMMIT marker (event)
    published  -> fleet queue exposed a (step, task) unit (event)
    claimed    -> a worker won the claim race for a unit (event)
    store_build-> TokenStore padding/commit (span)
    staged     -> host->device staging wait inside one engine run (span)
    encoded    -> query tower encode (span)
    scored     -> one full engine run for one (step, task) unit (span)
    recorded   -> ledger append of the verdict rows (span)
    selected   -> control plane changed its best-step choice (event)
    promoted   -> serving promoter built+verified+installed an index (span)
    served     -> one answered query micro-batch (span)

Trace-record schema (one JSON object per line, mirroring the workqueue
claim-record docs)::

    {"kind": "span",  "name": "scored", "id": 7, "parent": 3,
     "t0": 1234.567890, "dur": 0.0123, "pid": 4242, "tid": 139823,
     "process": "worker-0", ...attrs}
    {"kind": "event", "name": "discovered", "id": 8, "parent": null,
     "t": 1234.560000, "pid": 4242, "tid": 139823,
     "process": "worker-0", ...attrs}

* ``t0`` / ``t`` / ``dur`` are **``time.monotonic()`` seconds**.  On Linux
  that clock is CLOCK_MONOTONIC, which is system-wide: trace files written
  by different fleet worker processes on one host share a timebase, so the
  exporter can merge them into a single timeline without skew correction.
  Monotonic time has an arbitrary epoch — compare within a host/boot only.
* ``id`` is unique within one trace file; ``parent`` is the ``id`` of the
  innermost span open *on the same thread* when the record was created
  (``null`` at top level).  Nesting is tracked with a thread-local stack,
  so spans opened on different threads never accidentally adopt each
  other.
* ``process`` and any extra attributes (``worker_id``, ``step``, ``task``,
  ``engine``, ``score_dtype``, ...) are flat top-level keys.  Default
  attributes passed to the tracer (e.g. the fleet worker id) are stamped
  on every record.

Writes go through :func:`repro.core.jsonl.append_jsonl_atomic` — the same
O_APPEND + single-``write`` + fsync discipline as the validation ledger —
so a crashed worker leaves at most one torn tail line, which the tolerant
reader (and the exporter) skips.  Records are buffered in memory and
flushed every ``flush_every`` records, on :meth:`SpanTracer.flush`, and at
interpreter exit; buffering keeps the per-span cost to a dict append
rather than an fsync.

The tracer **observes, never participates**: nothing in this module is
read back by any scheduling, claim, or selection decision, and the
decision folds (``workqueue.replay``, ``control.plane.replay_ledger``)
remain clock-free.  Disabled telemetry (``Telemetry.tracer is None``)
costs exactly one attribute check at each instrumentation site.
"""

from __future__ import annotations

import atexit
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.jsonl import append_jsonl_atomic, read_jsonl_tolerant

__all__ = ["SpanTracer", "read_trace", "LIFECYCLE_STAGES"]

#: canonical hand-off order; the exporter sorts same-timestamp records by it
LIFECYCLE_STAGES: Tuple[str, ...] = (
    "produced", "snapshotted", "discovered", "published", "claimed",
    "store_build", "staged", "encoded", "scored", "recorded", "selected",
    "promoted", "served")


class _Span:
    """Context manager handle; returned by :meth:`SpanTracer.span`."""

    __slots__ = ("tracer", "name", "attrs", "id", "t0")

    def __init__(self, tracer: "SpanTracer", name: str,
                 attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.id: Optional[int] = None
        self.t0 = 0.0

    def __enter__(self) -> "_Span":
        self.id = self.tracer._next_id()
        self.tracer._stack().append(self.id)
        self.t0 = time.monotonic()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        dur = time.monotonic() - self.t0
        stack = self.tracer._stack()
        stack.pop()
        parent = stack[-1] if stack else None
        if exc_type is not None:
            self.attrs = dict(self.attrs, error=repr(exc))
        self.tracer._emit("span", self.name, self.id, parent,
                          {"t0": self.t0, "dur": dur}, self.attrs)


class SpanTracer:
    """Buffered lifecycle tracer writing one JSONL trace file.

    Thread-safe: the record buffer and id counter are lock-protected and
    the open-span stack is thread-local.  One tracer per process (or per
    simulated worker in tests) is the intended granularity.
    """

    def __init__(self, path: str, *, process: Optional[str] = None,
                 attrs: Optional[Dict[str, Any]] = None,
                 flush_every: int = 128):
        self.path = str(path)
        self.process = process if process is not None \
            else f"pid-{os.getpid()}"
        self.default_attrs = dict(attrs or {})
        self.flush_every = max(1, int(flush_every))
        self._lock = threading.Lock()
        self._buf: List[Dict[str, Any]] = []
        self._ids = 0
        self._tls = threading.local()
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        atexit.register(self.flush)

    # -- internals ----------------------------------------------------------
    def _stack(self) -> List[int]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _next_id(self) -> int:
        with self._lock:
            self._ids += 1
            return self._ids

    def _emit(self, kind: str, name: str, rec_id: Optional[int],
              parent: Optional[int], times: Dict[str, float],
              attrs: Dict[str, Any]) -> None:
        rec: Dict[str, Any] = {"kind": kind, "name": name, "id": rec_id,
                               "parent": parent}
        rec.update(times)
        rec["pid"] = os.getpid()
        rec["tid"] = threading.get_ident()
        rec["process"] = self.process
        for k, v in self.default_attrs.items():
            rec.setdefault(k, v)
        for k, v in attrs.items():
            if v is not None:
                rec[k] = v
        with self._lock:
            self._buf.append(rec)
            should_flush = len(self._buf) >= self.flush_every
        if should_flush:
            self.flush()

    # -- public API ---------------------------------------------------------
    def span(self, name: str, **attrs: Any) -> _Span:
        """Open a lifecycle span; use as a context manager."""
        return _Span(self, name, attrs)

    def event(self, name: str, **attrs: Any) -> None:
        """Record an instantaneous lifecycle event at *now*."""
        stack = self._stack()
        parent = stack[-1] if stack else None
        self._emit("event", name, self._next_id(), parent,
                   {"t": time.monotonic()}, attrs)

    def record(self, name: str, t0: float, dur: float, **attrs: Any) -> None:
        """Record a span post-hoc from explicit monotonic ``t0``/``dur``
        (for hot loops that accumulate timings and emit once).  The parent
        is whatever span is currently open on this thread."""
        stack = self._stack()
        parent = stack[-1] if stack else None
        self._emit("span", name, self._next_id(), parent,
                   {"t0": float(t0), "dur": float(dur)}, attrs)

    def flush(self) -> None:
        with self._lock:
            buf, self._buf = self._buf, []
        if buf:
            append_jsonl_atomic(self.path, buf)

    def close(self) -> None:
        self.flush()


def read_trace(path: str) -> List[Dict[str, Any]]:
    """Read one trace file, skipping a torn tail line if present."""
    records, _ = read_jsonl_tolerant(path, kind="trace record")
    return records
