"""Trace export — Chrome trace-event JSON and per-stage latency summaries.

``python -m repro.obs.export --chrome out.json trace*.jsonl`` merges one
or more per-process trace files (see :mod:`repro.obs.trace` for the
record schema) into a single Chrome trace-event JSON file that
``chrome://tracing`` and Perfetto (https://ui.perfetto.dev) render as a
fleet timeline: one track per (process, thread), spans as slices, events
as instants.  Because span timestamps are CLOCK_MONOTONIC and that clock
is system-wide on Linux, traces from different worker processes on one
host line up without skew correction.

``stage_summary`` / ``breakdown_table`` turn the same records into the
latency tables printed by ``--obs_report``, ``examples/observability.py``
and ``benchmarks/bench_validation_time.py``.  Summaries report both
*inclusive* time (span duration) and *self* time (duration minus direct
children), so a parent ``scored`` span does not double-count its nested
``staged``/``encoded`` children in a breakdown.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.obs.trace import LIFECYCLE_STAGES, read_trace

__all__ = ["load_traces", "to_chrome", "write_chrome", "stage_summary",
           "breakdown_table", "main"]

_STAGE_ORDER = {name: i for i, name in enumerate(LIFECYCLE_STAGES)}


def load_traces(paths: Sequence[str]) -> List[Dict[str, Any]]:
    """Read and merge trace files; each record gains a ``_file`` key so
    span ``id``/``parent`` references (file-local) stay resolvable."""
    records: List[Dict[str, Any]] = []
    for path in paths:
        for rec in read_trace(path):
            rec = dict(rec, _file=os.path.abspath(path))
            records.append(rec)
    return records


def _sort_key(rec: Dict[str, Any]):
    t = rec.get("t0", rec.get("t", 0.0)) or 0.0
    return (t, _STAGE_ORDER.get(rec.get("name"), len(_STAGE_ORDER)))


def to_chrome(records: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Convert merged trace records to a Chrome trace-event dict.

    Spans become complete events (``ph: "X"``, microsecond ``ts``/``dur``)
    and instants become ``ph: "i"`` with thread scope; process-name
    metadata events label each track with the tracer's ``process`` string.
    """
    meta_keys = ("kind", "name", "id", "parent", "t0", "t", "dur",
                 "pid", "tid", "process", "_file")
    events: List[Dict[str, Any]] = []
    named: Dict[int, str] = {}
    for rec in sorted(records, key=_sort_key):
        pid = int(rec.get("pid", 0))
        tid = int(rec.get("tid", 0)) % 2 ** 31  # chrome wants small-ish ints
        proc = rec.get("process")
        if proc and named.get(pid) != proc:
            named[pid] = proc
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": tid, "args": {"name": str(proc)}})
        args = {k: v for k, v in rec.items() if k not in meta_keys}
        if rec.get("id") is not None:
            args["span_id"] = rec["id"]
        if rec.get("parent") is not None:
            args["parent_id"] = rec["parent"]
        if rec.get("kind") == "span":
            events.append({
                "ph": "X", "name": str(rec.get("name")), "cat": "lifecycle",
                "ts": float(rec.get("t0", 0.0)) * 1e6,
                "dur": max(1.0, float(rec.get("dur", 0.0)) * 1e6),
                "pid": pid, "tid": tid, "args": args})
        elif rec.get("kind") == "event":
            events.append({
                "ph": "i", "s": "t", "name": str(rec.get("name")),
                "cat": "lifecycle", "ts": float(rec.get("t", 0.0)) * 1e6,
                "pid": pid, "tid": tid, "args": args})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome(paths: Sequence[str], out: str) -> Dict[str, Any]:
    doc = to_chrome(load_traces(paths))
    tmp = f"{out}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    os.replace(tmp, out)
    return doc


def _percentile(vals: List[float], p: float) -> Optional[float]:
    if not vals:
        return None
    vals = sorted(vals)
    rank = max(1, int(math.ceil(p / 100.0 * len(vals))))
    return vals[min(rank, len(vals)) - 1]


def stage_summary(records: Iterable[Dict[str, Any]]) -> Dict[str, Dict[str, Any]]:
    """Per-stage latency summary over span records.

    Returns ``{name: {count, total_s, self_s, mean_s, p50_s, p99_s}}``
    where ``self_s`` excludes time spent in *direct child* spans (same
    file, ``parent`` pointing at the span) — the additive view for
    breakdown tables.  Events contribute ``count`` only.
    """
    recs = list(records)
    child_time: Dict[Any, float] = {}
    for rec in recs:
        if rec.get("kind") == "span" and rec.get("parent") is not None:
            key = (rec.get("_file"), rec.get("pid"), rec["parent"])
            child_time[key] = child_time.get(key, 0.0) \
                + float(rec.get("dur", 0.0))
    out: Dict[str, Dict[str, Any]] = {}
    for rec in recs:
        name = rec.get("name")
        ent = out.setdefault(name, {"count": 0, "total_s": 0.0,
                                    "self_s": 0.0, "durs": []})
        ent["count"] += 1
        if rec.get("kind") != "span":
            continue
        dur = float(rec.get("dur", 0.0))
        key = (rec.get("_file"), rec.get("pid"), rec.get("id"))
        ent["total_s"] += dur
        ent["self_s"] += max(0.0, dur - child_time.get(key, 0.0))
        ent["durs"].append(dur)
    for ent in out.values():
        durs = ent.pop("durs")
        ent["mean_s"] = (ent["total_s"] / len(durs)) if durs else None
        ent["p50_s"] = _percentile(durs, 50)
        ent["p99_s"] = _percentile(durs, 99)
    return out


def breakdown_table(records: Iterable[Dict[str, Any]]) -> str:
    """Fixed-width latency-breakdown table in lifecycle order."""
    summary = stage_summary(records)
    rows = [("stage", "count", "total_s", "self_s", "mean_s", "p50_s",
             "p99_s")]

    def fmt(v) -> str:
        return "-" if v is None else (f"{v:.4f}" if isinstance(v, float)
                                      else str(v))

    names = sorted(summary, key=lambda n: (_STAGE_ORDER.get(n, 99), str(n)))
    for name in names:
        ent = summary[name]
        rows.append((str(name), fmt(ent["count"]), fmt(ent["total_s"]),
                     fmt(ent["self_s"]), fmt(ent["mean_s"]),
                     fmt(ent["p50_s"]), fmt(ent["p99_s"])))
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = []
    for i, row in enumerate(rows):
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Export lifecycle trace files to Chrome trace-event "
                    "JSON (open in chrome://tracing or Perfetto) and/or "
                    "print a per-stage latency summary.")
    ap.add_argument("traces", nargs="+", help="trace .jsonl files to merge")
    ap.add_argument("--chrome", default=None, metavar="OUT.json",
                    help="write merged Chrome trace-event JSON here")
    ap.add_argument("--summary", action="store_true",
                    help="print the per-stage latency breakdown table")
    args = ap.parse_args(argv)
    records = load_traces(args.traces)
    if args.chrome:
        doc = write_chrome(args.traces, args.chrome)
        print(f"wrote {args.chrome}: {len(doc['traceEvents'])} events "
              f"from {len(args.traces)} trace file(s)")
    if args.summary or not args.chrome:
        print(breakdown_table(records))
    return 0


if __name__ == "__main__":
    sys.exit(main())
