"""Metrics registry — counters, gauges, EMAs, and histograms, no deps.

One process-wide registry maps instrument *names* (dotted strings, e.g.
``"validate.ckpt_to_verdict_s"``) to instrument objects.  Instruments are
created on first use and shared thereafter: two subsystems asking for the
same name get the same object, which is exactly how the watcher's
:class:`~repro.core.watcher.BudgetPolicy` and the validator share one
source of timing truth (the policy *reads* the EMA the validator *feeds*).

Design constraints, in priority order:

* **Zero dependencies.** Plain dicts, locks, and ``statistics``-free
  percentile math — the registry must import anywhere the repo does.
* **Cheap when idle.** An instrument that is never observed costs one dict
  entry; observation is a lock + float update.  Nothing here touches the
  clock — callers time things and hand in seconds.
* **Observe, never participate.** Registry state must not feed replayed
  decisions; it is rebuilt empty each process and is deliberately not
  persisted anywhere a decision fold could read it.

Instrument types
----------------
``Counter``    monotonically increasing int (``inc``).
``Gauge``      last-written float (``set``).
``Ewma``       exponential moving average with the repo's canonical
               update rule ``v if prev is None else s*prev + (1-s)*v``
               (bit-identical to the old private BudgetPolicy EMAs).
``Histogram``  count / total / min / max plus a bounded reservoir of the
               most recent observations for percentile queries.

Snapshots
---------
``snapshot()`` returns a plain-dict view (JSON-ready), ``dump(path)``
writes it as JSON, and ``render()`` produces the fixed-width text table
behind ``repro.core.cli --obs_report``.
"""

from __future__ import annotations

import collections
import json
import math
import os
import threading
from typing import Any, Dict, List, Optional

__all__ = ["Counter", "Gauge", "Ewma", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += int(n)

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value}


class Ewma:
    """Exponential moving average; ``smooth`` is the weight on the *old*
    estimate, matching the BudgetPolicy convention (``smooth=0.0`` tracks
    the last observation exactly)."""

    __slots__ = ("name", "smooth", "value", "count", "_lock")

    def __init__(self, name: str, smooth: float = 0.5):
        self.name = name
        self.smooth = float(smooth)
        self.value: Optional[float] = None
        self.count = 0
        self._lock = threading.Lock()

    def update(self, v: float) -> None:
        v = float(v)
        with self._lock:
            prev = self.value
            self.value = v if prev is None \
                else self.smooth * prev + (1.0 - self.smooth) * v
            self.count += 1

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "ewma", "value": self.value, "count": self.count,
                "smooth": self.smooth}


class Histogram:
    """Count/total/min/max plus a bounded reservoir of recent observations
    (newest ``maxlen`` values) for percentile queries.  The reservoir bound
    keeps a long-running fleet's memory flat; percentiles are therefore
    over the recent window, which is what an operator wants anyway."""

    __slots__ = ("name", "count", "total", "vmin", "vmax", "_values", "_lock")

    def __init__(self, name: str, maxlen: int = 2048):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self._values: collections.deque = collections.deque(maxlen=maxlen)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.vmin = v if self.vmin is None else min(self.vmin, v)
            self.vmax = v if self.vmax is None else max(self.vmax, v)
            self._values.append(v)

    @property
    def mean(self) -> Optional[float]:
        return (self.total / self.count) if self.count else None

    def percentile(self, p: float) -> Optional[float]:
        """Nearest-rank percentile over the retained reservoir."""
        with self._lock:
            vals = sorted(self._values)
        if not vals:
            return None
        rank = max(1, int(math.ceil(p / 100.0 * len(vals))))
        return vals[min(rank, len(vals)) - 1]

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "histogram", "count": self.count, "total": self.total,
                "mean": self.mean, "min": self.vmin, "max": self.vmax,
                "p50": self.percentile(50), "p99": self.percentile(99)}


class MetricsRegistry:
    """Thread-safe name → instrument map with create-on-first-use."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._items: Dict[str, Any] = {}

    def _get(self, name: str, cls, *args, **kwargs):
        with self._lock:
            inst = self._items.get(name)
            if inst is None:
                inst = cls(name, *args, **kwargs)
                self._items[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def ewma(self, name: str, smooth: float = 0.5) -> Ewma:
        return self._get(name, Ewma, smooth)

    def histogram(self, name: str, maxlen: int = 2048) -> Histogram:
        return self._get(name, Histogram, maxlen)

    def get(self, name: str):
        """Existing instrument or None — read-side lookups must not
        create empty instruments."""
        with self._lock:
            return self._items.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._items)

    # -- snapshot endpoint --------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            items = list(self._items.items())
        return {name: inst.snapshot() for name, inst in sorted(items)}

    def dump(self, path: str) -> None:
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    def render(self) -> str:
        """Fixed-width text table (the ``--obs_report`` body)."""
        rows = [("metric", "type", "count", "value/mean", "p50", "p99")]

        def fmt(v) -> str:
            if v is None:
                return "-"
            if isinstance(v, float):
                return f"{v:.6g}"
            return str(v)

        for name, snap in self.snapshot().items():
            kind = snap["type"]
            if kind == "counter":
                rows.append((name, kind, fmt(snap["value"]), "-", "-", "-"))
            elif kind == "gauge":
                rows.append((name, kind, "-", fmt(snap["value"]), "-", "-"))
            elif kind == "ewma":
                rows.append((name, kind, fmt(snap["count"]),
                             fmt(snap["value"]), "-", "-"))
            else:
                rows.append((name, kind, fmt(snap["count"]), fmt(snap["mean"]),
                             fmt(snap["p50"]), fmt(snap["p99"])))
        widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
        lines = []
        for i, row in enumerate(rows):
            lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
            if i == 0:
                lines.append("  ".join("-" * w for w in widths))
        return "\n".join(lines)
