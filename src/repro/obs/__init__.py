"""Observability — lifecycle span tracing and a process metrics registry.

The single entry point is :class:`Telemetry`: a nullable handle threaded
through the watcher, engine, work queue, validator, control plane, and
serving tier.  Every instrumentation site follows one pattern::

    tel = self.telemetry
    if tel is not None:
        tel.event("discovered", step=step)

so *disabled* telemetry (the default — every constructor defaults to
``telemetry=None``) costs one attribute check and one ``is not None``
branch per site, writes no files, and leaves ledgers and event logs
byte-identical.  Enabled telemetry writes spans to its own trace file
(never to any ledger) and aggregates metrics in memory; nothing it
produces is ever read back by a scheduling, claim, or selection decision.

A ``Telemetry`` can be metrics-only (``trace_path=None``): the registry
aggregates latencies for ``--obs_report`` without any span file I/O.
``mark``/``since`` provide cross-stage latency measurement (e.g.
checkpoint discovery → verdict recorded) keyed on arbitrary tuples.
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import Any, Dict, Optional

from repro.obs.metrics import (Counter, Ewma, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.trace import LIFECYCLE_STAGES, SpanTracer, read_trace

__all__ = ["Telemetry", "MetricsRegistry", "SpanTracer", "read_trace",
           "LIFECYCLE_STAGES", "Counter", "Gauge", "Ewma", "Histogram"]

_NULL_CM = contextlib.nullcontext()


class Telemetry:
    """Tracer + metrics registry + cross-stage marks, behind one handle.

    Parameters
    ----------
    trace_path:
        JSONL trace file for lifecycle spans; ``None`` for metrics-only.
    registry:
        Share an existing :class:`MetricsRegistry` (e.g. between a
        validator and its watcher policy); a fresh one is created if
        omitted.
    process / attrs:
        Tracer identity: ``process`` labels this process's timeline track
        and ``attrs`` (e.g. ``{"worker_id": "w0"}``) are stamped on every
        span/event the tracer writes.
    """

    def __init__(self, trace_path: Optional[str] = None, *,
                 registry: Optional[MetricsRegistry] = None,
                 process: Optional[str] = None,
                 attrs: Optional[Dict[str, Any]] = None):
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer: Optional[SpanTracer] = (
            SpanTracer(trace_path, process=process, attrs=attrs)
            if trace_path else None)
        self._marks: Dict[Any, float] = {}
        self._marks_lock = threading.Lock()

    # -- tracing ------------------------------------------------------------
    def span(self, name: str, **attrs: Any):
        """Context manager for a lifecycle span (no-op without a tracer)."""
        tracer = self.tracer
        return _NULL_CM if tracer is None else tracer.span(name, **attrs)

    def event(self, name: str, **attrs: Any) -> None:
        tracer = self.tracer
        if tracer is not None:
            tracer.event(name, **attrs)

    def record(self, name: str, t0: float, dur: float, **attrs: Any) -> None:
        tracer = self.tracer
        if tracer is not None:
            tracer.record(name, t0, dur, **attrs)

    def flush(self) -> None:
        tracer = self.tracer
        if tracer is not None:
            tracer.flush()

    # -- cross-stage latency marks ------------------------------------------
    def mark(self, name: str, key: Any) -> None:
        """Remember *now* (monotonic) under ``(name, key)``."""
        with self._marks_lock:
            self._marks[(name, key)] = time.monotonic()

    def since(self, name: str, key: Any, *, pop: bool = False
              ) -> Optional[float]:
        """Seconds since :meth:`mark`, or ``None`` if never marked (e.g.
        the mark lives in another fleet process)."""
        with self._marks_lock:
            t0 = (self._marks.pop((name, key), None) if pop
                  else self._marks.get((name, key)))
        return None if t0 is None else time.monotonic() - t0
