"""Exact MIPS top-k retrieval — the paper's retrieval stage, TPU-native.

Replaces the paper's host-side FAISS flat index: the corpus embedding matrix
stays device-resident (row-sharded at scale) and retrieval is a blocked
matmul + running top-k:

  * ``topk_exact``       — single-device: ``lax.scan`` over corpus blocks with
                           an online top-k merge (XLA path; the Pallas kernel
                           in ``repro.kernels.topk_mips`` is the TPU-target
                           implementation of the same loop, selected with
                           impl="pallas").
  * ``topk_sharded``     — shard_map over a mesh: corpus rows sharded, local
                           top-k per shard, hierarchical merge via all_gather
                           of the k candidates/shard (collective volume
                           O(devices x k) — negligible vs the scan).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.precision import chunk_scores, validate_score_dtype
from repro.distributed import compat


def _merge_topk(scores_a, idx_a, scores_b, idx_b, k: int):
    """Merge two (Q, ka/kb) candidate sets into (Q, k)."""
    s = jnp.concatenate([scores_a, scores_b], axis=1)
    i = jnp.concatenate([idx_a, idx_b], axis=1)
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(i, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "block", "unroll",
                                             "score_dtype"))
def topk_exact(q_emb: jnp.ndarray, c_emb: jnp.ndarray, *, k: int,
               block: int = 4096, unroll: int = 1,
               score_dtype: str = "f32"):
    """q_emb (Q, D) x c_emb (N, D) -> (scores (Q,k), indices (Q,k)).

    Scans corpus blocks, carrying a running top-k so the full (Q, N) score
    matrix is never materialized (N can be 10^7).  ``score_dtype`` (static)
    picks the scoring precision via :func:`repro.core.precision.
    chunk_scores`; ``"f32"`` compiles the literal legacy expression.
    Per-row quantization makes the block scores block-size independent, so
    every precision agrees with the streaming stages at equal dtype."""
    Q, D = q_emb.shape
    N = c_emb.shape[0]
    k = min(k, N)
    nb = max(1, min(block, N))
    n_blocks = -(-N // nb)
    padN = n_blocks * nb
    c = jnp.pad(c_emb, ((0, padN - N), (0, 0)))
    c = c.reshape(n_blocks, nb, D)

    init_s = jnp.full((Q, k), -jnp.inf, jnp.float32)
    init_i = jnp.zeros((Q, k), jnp.int32)

    def body(carry, inp):
        run_s, run_i = carry
        cb, bi = inp
        if score_dtype == "f32":
            s = (q_emb @ cb.T).astype(jnp.float32)           # (Q, nb)
        else:
            s = chunk_scores(q_emb, cb, score_dtype)         # (Q, nb)
        base = bi * nb
        valid = (base + jnp.arange(nb))[None, :] < N
        s = jnp.where(valid, s, -jnp.inf)
        kk = min(k, nb)
        bs, bidx = jax.lax.top_k(s, kk)
        bidx = bidx + base
        return _merge_topk(run_s, run_i, bs, bidx.astype(jnp.int32), k), None

    (scores, idx), _ = jax.lax.scan(body, (init_s, init_i),
                                    (c, jnp.arange(n_blocks)),
                                    unroll=(n_blocks if unroll <= 0
                                            else min(unroll, n_blocks)))
    return scores, idx


def _hierarchical_topk_merge(s, i, axis_names, k: int):
    """Reduce per-shard (Q, kk) candidates to the global (Q, <=k) top-k by
    all-gathering one mesh axis at a time, innermost first.  A flat n-way
    gather moves (n_shards-1) x Q x k candidate rows per device; two 16-way
    levels move 2 x 15 x Q x k — ~8.5x less wire on the 16x16 mesh
    (EXPERIMENTS.md §Perf).  Must run inside shard_map."""
    for merge_ax in reversed(tuple(axis_names)):
        all_s = jax.lax.all_gather(s, merge_ax, axis=0, tiled=False)
        all_i = jax.lax.all_gather(i, merge_ax, axis=0, tiled=False)
        Sn = all_s.shape[0] * all_s.shape[2]
        flat_s = jnp.moveaxis(all_s, 0, 1).reshape(s.shape[0], Sn)
        flat_i = jnp.moveaxis(all_i, 0, 1).reshape(s.shape[0], Sn)
        s, pos = jax.lax.top_k(flat_s, min(k, Sn))
        i = jnp.take_along_axis(flat_i, pos, axis=1)
    return s, i


def _hierarchical_slot_max(x, axis_names):
    """Slot-aligned sibling of :func:`_hierarchical_topk_merge` for the
    sharded rerank stage: per-shard partial candidate-score matrices are
    already aligned on the (Q, Cmax) slot grid (each slot names one global
    corpus row, which lives on exactly one shard), so the cross-shard merge
    degenerates from a gather+top-k to an elementwise max — reduced one mesh
    axis at a time, innermost first, like the top-k merge, but each level is
    a ``pmax`` (the reduction happens on the wire, so the per-level volume is
    Q x Cmax instead of the gather's n_ax x Q x Cmax).  Must run inside
    shard_map."""
    for merge_ax in reversed(tuple(axis_names)):
        x = jax.lax.pmax(x, merge_ax)
    return x


def topk_sharded(mesh, q_emb, c_emb, *, k: int, axis_names=("data", "model"),
                 block: int = 4096, score_dtype: str = "f32"):
    """Distributed exact top-k: corpus rows sharded over ``axis_names``.

    Each shard computes a local top-k over its rows (global indices), then a
    hierarchical merge all-gathers the (k-candidate) lists and reduces.
    ``score_dtype`` threads to the per-shard :func:`topk_exact`; per-ROW
    quantization means each shard's quantized scores equal the single-device
    slice, so sharded narrow-dtype runs match unsharded ones.
    """
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    N = c_emb.shape[0]
    rows = N // n_shards
    assert rows * n_shards == N, "corpus rows must divide shards (pad first)"
    kk = min(k, rows)

    def local(q, c_local):
        ax = axis_names[0] if len(axis_names) == 1 else axis_names
        shard_id = jax.lax.axis_index(ax)
        s, i = topk_exact(q, c_local, k=kk, block=block,
                          score_dtype=score_dtype)
        i = i + shard_id * rows
        return _hierarchical_topk_merge(s, i, axis_names, k)

    spec_c = P(axis_names if len(axis_names) > 1 else axis_names[0])
    # check=False (check_vma/check_rep): the inner lax.scan carry starts
    # replicated and becomes device-varying after the first block — a legal
    # pattern the varying-manual-axes checker can't type; outputs are
    # re-replicated by the final merge anyway.
    fn = compat.shard_map(local, mesh=mesh,
                          in_specs=(P(), spec_c),
                          out_specs=(P(), P()), check=False)
    return fn(q_emb, c_emb)


def retrieve_run(query_ids, q_emb, doc_ids, c_emb, *, k: int,
                 impl: str = "xla", mesh=None, block: int = 4096,
                 score_dtype: str = "f32"):
    """Build a {qid: [docid...]} run (+scores) from embeddings."""
    validate_score_dtype(score_dtype)
    if impl == "pallas":
        from repro.kernels.topk_mips import ops as mips_ops
        scores, idx = mips_ops.topk_mips(jnp.asarray(q_emb),
                                         jnp.asarray(c_emb), k=k,
                                         score_dtype=score_dtype)
    elif mesh is not None:
        scores, idx = topk_sharded(mesh, jnp.asarray(q_emb),
                                   jnp.asarray(c_emb), k=k, block=block,
                                   score_dtype=score_dtype)
    else:
        scores, idx = topk_exact(jnp.asarray(q_emb), jnp.asarray(c_emb),
                                 k=k, block=block, score_dtype=score_dtype)
    scores = np.asarray(scores)
    idx = np.asarray(idx)
    run, run_scores = {}, {}
    for qi, qid in enumerate(query_ids):
        run[qid] = [doc_ids[j] for j in idx[qi]]
        run_scores[qid] = [float(s) for s in scores[qi]]
    return run, run_scores


def pad_candidates(query_ids, doc_ids, per_query: dict):
    """Per-query candidate lists -> a padded (Q, Cmax) matrix of corpus row
    positions (-1 = padding), plus the filtered candidate id lists."""
    doc_pos = {d: i for i, d in enumerate(doc_ids)}
    cands = [[d for d in per_query.get(qid, []) if d in doc_pos]
             for qid in query_ids]
    c_max = max((len(c) for c in cands), default=0)
    idx = np.full((len(query_ids), max(c_max, 1)), -1, np.int32)
    for qi, row in enumerate(cands):
        idx[qi, :len(row)] = [doc_pos[d] for d in row]
    return idx, cands


def rank_candidates(query_ids, s, cands, *, k: int):
    """Candidate-score matrix -> ({qid: [docid...]}, {qid: [score...]}).

    The ONE selection routine every rerank path (dense/blocked materialized,
    streaming single-device, streaming sharded) finalizes through: a
    *stable* descending sort of the (Q, Cmax) score matrix, keeping the top
    ``min(k, len(cands[q]))`` slots per query.  Stability is what makes the
    cross-mode parity guarantee bit-for-bit: duplicate doc ids (and any
    other exact score ties) resolve to the lower candidate slot regardless
    of which path produced the matrix, so identical score matrices imply
    identical runs — not just identical up to tie order.  Padding slots are
    ``-inf`` and sort last; they are additionally fenced off by the
    per-query candidate count, so a ``k`` larger than the candidate list
    never surfaces a pad.
    """
    s = np.asarray(s)
    order = np.argsort(-s, axis=1, kind="stable")
    run, run_scores = {}, {}
    for qi, qid in enumerate(query_ids):
        keep = order[qi, :min(k, len(cands[qi]))]
        run[qid] = [cands[qi][j] for j in keep]
        run_scores[qid] = [float(s[qi, j]) for j in keep]
    return run, run_scores


# default per-block candidate-gather budget for the materialized rerank path
RERANK_BLOCK_BYTES = 256 << 20


def _quantize_values_np(x: np.ndarray, score_dtype: str) -> np.ndarray:
    """Value-level quantization for the host-side rerank path: return the
    f32 array whose entries are exactly what the device would score at
    ``score_dtype`` — bf16 is a round-trip through the storage dtype (a
    bf16 x bf16 product is exact in f32, so f32 math over round-tripped
    values IS the device bf16-input/f32-accumulate matmul up to summation
    order), int8 is dequantized per-row symmetric quantization
    (:func:`repro.core.precision.quantize_rows_np`)."""
    if score_dtype == "bf16":
        return np.asarray(np.asarray(x, jnp.bfloat16), np.float32)
    if score_dtype == "int8":
        from repro.core.precision import quantize_rows_np
        vals, scale = quantize_rows_np(x)
        return vals.astype(np.float32) * scale
    raise ValueError(f"unexpected score_dtype {score_dtype!r}")


def rerank_run(query_ids, q_emb, doc_ids, c_emb, per_query: dict, *, k: int,
               q_block: int = None, block_bytes: int = RERANK_BLOCK_BYTES,
               score_dtype: str = "f32"):
    """RocketQA-style re-rank validation: score only each query's candidate
    list (no global top-k).  ``score_dtype`` quantizes the embeddings at
    value level before the (unchanged, f32) blocked einsum — see
    :func:`_quantize_values_np`.

    Memory model — query-blocked materialized gather: the candidate
    embeddings are gathered one *query block* at a time, ``(Q_block, Cmax,
    D)`` per gather followed by one batched matmul, so peak candidate-block
    memory is ``O(Q_block x Cmax x D)`` instead of the dense gather's
    ``O(Q x Cmax x D)`` (~21 GB at MS MARCO rerank scale: Q=7k, Cmax=1000,
    D=768).  ``q_block`` pins the block height explicitly; when ``None``
    (default) it is auto-sized so one block's gather fits ``block_bytes``
    (256 MiB default), clamped to [1, Q].  Per-element math is unchanged —
    each (q, c) dot product reduces over D exactly as in the dense gather —
    so runs and scores are bit-for-bit identical for every block size,
    including the Q_block=1 and Q_block>=Q extremes (enforced by
    tests/test_rerank_parity.py).  Selection is the shared
    :func:`rank_candidates` (stable tie-break), the same routine the
    streaming rerank stages finalize through.
    """
    validate_score_dtype(score_dtype)
    q = np.asarray(q_emb)
    c = np.asarray(c_emb)
    if score_dtype != "f32":
        q = _quantize_values_np(q, score_dtype)
        c = _quantize_values_np(c, score_dtype)
    cand_idx, cands = pad_candidates(query_ids, doc_ids, per_query)
    valid = cand_idx >= 0
    if not valid.any():
        return {qid: [] for qid in query_ids}, {qid: [] for qid in query_ids}
    Q, c_max = cand_idx.shape
    if q_block is None:
        row_bytes = c_max * c.shape[-1] * c.dtype.itemsize
        q_block = int(max(1, block_bytes // max(row_bytes, 1)))
    q_block = max(1, min(int(q_block), Q))
    s = np.full((Q, c_max), -np.inf, np.float32)
    clipped = np.clip(cand_idx, 0, max(len(doc_ids) - 1, 0))
    for b0 in range(0, Q, q_block):
        b1 = min(b0 + q_block, Q)
        sub = c[clipped[b0:b1]]                       # (Q_block, Cmax, D)
        sb = np.einsum("qcd,qd->qc", sub, q[b0:b1])   # (Q_block, Cmax)
        s[b0:b1] = np.where(valid[b0:b1], sb, -np.inf)
    return rank_candidates(query_ids, s, cands, k=k)
