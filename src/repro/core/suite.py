"""Multi-task ValidationSuite — the toolkit's public validation API.

One *task* is what the legacy ``ValidationPipeline`` bound for a whole run:
a (corpus, queries, qrels) triple plus its mode, sampler, metrics, and
retrieval cut-off.  A *suite* validates every checkpoint against N such
tasks in one pass — the "multiple efficient validation sets" protocol of
Cho et al. 2022 (validate against several small sets and select checkpoints
that transfer), layered on Asyncval's asynchronous loop:

    suite = ValidationSuite(spec, [
        ValidationTask("dev",     corpus, dev_q,  dev_qrels),
        ValidationTask("heldout", corpus, ho_q,   ho_qrels),
    ], ValidationConfig(engine="streaming"))
    result = suite.validate_params(params, step=1000)   # one SuiteResult
    result.tasks["dev"].metrics["MRR@10"]
    result.metrics["heldout:MRR@10"]                    # flat view

The suite owns the shared resources:

  * the encoder spec and validator mesh are bound once;
  * each task's sampler runs ONCE (the subset depends only on the baseline
    run + qrels, never on the checkpoint — the paper's §3 amortization);
  * corpus :class:`~repro.core.engine.TokenStore`\\ s are cached by
    (corpus, sampled subset, chunk geometry, backing): tasks validating the
    same sampled corpus share ONE store — padded once, staged once per
    checkpoint pass, one mmap cache dir (``store_builds`` counts actual
    builds so tests can assert the sharing);
  * one engine per task is built lazily through
    :func:`repro.core.engine.make_engine`, i.e. through the pluggable
    component registries.

``AsyncValidator`` accepts a suite anywhere it accepted a pipeline; the
ledger then keys rows by ``(step, task)`` (schema v2) and the control plane
can select / early-stop on a composite ``"task:metric"`` spec.  The legacy
single-task ``ValidationPipeline`` survives in :mod:`repro.core.pipeline`
as a deprecated shim over a one-task suite — bit-for-bit identical.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import metrics as metrics_lib
from repro.core.engine import (ValidationStore, chunk_geometry, doc_cache_dir,
                               make_engine)
from repro.core.engine import TokenStore
from repro.core.registry import MODES, resolve_sampler
from repro.core.samplers import SubsetResult
from repro.models.biencoder import EncoderSpec

_NULL_CM = contextlib.nullcontext()


@dataclasses.dataclass
class ValidationConfig:
    """How to validate — shared across every task of a suite.  ``metrics`` /
    ``mode`` / ``k`` double as the defaults a :class:`ValidationTask` can
    override per task."""

    metrics: tuple = ("MRR@10",)
    mode: str = "retrieval"          # retrieval | rerank | average_rank
    k: int = 100                     # retrieval cut-off
    batch_size: int = 64
    impl: str = "xla"                # xla | pallas
    mesh: Any = None                 # optional sharded retrieval mesh
    engine: str = "streaming"        # streaming | materialized (legacy)
    chunk_size: Optional[int] = None  # streaming chunk rows; None -> batch_size
    scan_window: int = 8             # chunks folded per dispatch (xla stage)
    staging: str = "double_buffered"  # double_buffered | sync host->device
    staging_depth: int = 2           # prefetch depth (2 = double buffer;
                                     # deeper for remote-storage stores)
    token_backing: str = "memory"    # memory | mmap (out-of-core TokenStore)
    mmap_dir: Optional[str] = None   # cache dir for token_backing="mmap"
    token_fingerprint: str = "fast"  # fast (O(1)) | full (content hash)
    rerank_block: Optional[int] = None  # queries per materialized rerank
                                     # candidate gather (None = auto budget)
    score_dtype: str = "f32"         # scoring precision: f32 | bf16 | int8
                                     # (precision-as-fidelity; recorded in
                                     # every ledger row like `engine`)
    rerank_compact: bool = True      # pack sparse rerank candidates into
                                     # dense pseudo-chunks before encoding
    write_run: bool = False
    output_dir: Optional[str] = None
    run_tag: str = "asyncval"
    # nullable observability handle (repro.obs.Telemetry).  None (default)
    # keeps every path span-free at the cost of one attribute check; set,
    # it receives store_build/staged/encoded/scored lifecycle spans and
    # engine metrics.  Excluded from comparisons so two configs differing
    # only in instrumentation still compare equal.
    telemetry: Any = dataclasses.field(default=None, compare=False,
                                       repr=False)


@dataclasses.dataclass
class ValidationResult:
    """One checkpoint × one task.  ``task`` is ``"default"`` for legacy
    single-task runs — exactly how schema-v1 ledger rows migrate."""

    step: int
    metrics: Dict[str, float]
    timings: Dict[str, float]
    subset_size: int
    # which data path produced the numbers ("streaming"/"materialized"/...);
    # recorded in the validator ledger so cross-mode parity can be audited
    # after the fact.
    engine: str = ""
    # scoring precision the engine ran at ("f32"/"bf16"/"int8") — ledgered
    # like `engine`, so mixed-precision histories audit and replay offline.
    score_dtype: str = "f32"
    task: str = "default"
    # which fleet worker scored this row ("" outside fleet mode — the key is
    # then omitted from the ledger row, keeping single-process ledgers
    # byte-identical to pre-fleet ones); threaded like `engine` so
    # mixed-fleet ledgers are auditable offline.
    worker_id: str = ""
    # which hand-off route supplied the params: "snapshot" when scored from
    # a host-resident pre-durable snapshot (repro.handoff), "" when restored
    # from the durable checkpoint — ledgered only when "snapshot", so
    # pre-handoff ledgers stay byte-identical (the worker_id discipline).
    handoff: str = ""


@dataclasses.dataclass
class ValidationTask:
    """One validation set: the data triple plus how to score it.  ``mode`` /
    ``metrics`` / ``k`` are per-task overrides — ``None`` (the default)
    inherits the suite :class:`ValidationConfig`'s value, so a single-task
    migration needs to state them only once.  Everything else (engine,
    staging, mesh, ...) always comes from the shared config.  ``sampler``
    is a sampler instance or a registered sampler name
    (:data:`repro.core.registry.SAMPLERS`), ``sampler_depth`` the named
    sampler's subset depth."""

    name: str
    corpus: Dict[str, list]
    queries: Dict[str, list]
    qrels: Dict[str, Dict[str, int]]
    mode: Optional[str] = None            # None -> vcfg.mode
    sampler: Any = None
    sampler_depth: int = 0                # subset depth for a NAMED sampler
                                          # (0 -> the strategy's default;
                                          # ignored for instances)
    baseline_run: Optional[Dict[str, list]] = None
    metrics: Optional[tuple] = None       # None -> vcfg.metrics
    k: Optional[int] = None               # None -> vcfg.k
    # fleet capability requirements for this task's work units (e.g.
    # {"mesh_size": 8} pins a full-corpus sharded task to big workers);
    # merged over the config-derived defaults in plan_units.  Ignored —
    # harmless — outside fleet mode.
    requires: Optional[Dict[str, Any]] = None

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            raise ValueError(f"task name must be a non-empty string, "
                             f"got {self.name!r}")
        if ":" in self.name:
            # "task:metric" is the composite control-metric syntax; a colon
            # in the task name would make those specs ambiguous
            raise ValueError(f"task name {self.name!r} must not contain ':'")


@dataclasses.dataclass
class SuiteResult:
    """One checkpoint × every task, in suite order.

    ``metrics`` is the flat view the ledger-independent consumers (loggers,
    control plane) key on: every metric under ``"task:metric"``, plus bare
    names for the ``"default"`` task so single-task suites keep the legacy
    schema (a v1 ledger and a v2 default-task ledger replay identically).
    """

    step: int
    tasks: Dict[str, ValidationResult]

    @property
    def metrics(self) -> Dict[str, float]:
        flat: Dict[str, float] = {}
        for name, res in self.tasks.items():
            if name == "default":
                flat.update(res.metrics)
        for name, res in self.tasks.items():
            for m, v in res.metrics.items():
                flat[f"{name}:{m}"] = v
        return flat

    @property
    def log_metrics(self) -> Dict[str, float]:
        """The reporter view (CSV/JSONL columns): bare names for the
        ``default`` task — a single-task run's schema is byte-identical to
        the legacy pipeline's — and task-qualified names for every other
        task, with no redundant ``default:``-qualified duplicates.
        (:attr:`metrics` keeps both spellings for control-metric specs.)"""
        flat: Dict[str, float] = {}
        for name, res in self.tasks.items():
            if name == "default":
                flat.update(res.metrics)
            else:
                flat.update({f"{name}:{m}": v
                             for m, v in res.metrics.items()})
        return flat

    @property
    def timings(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for res in self.tasks.values():
            for k, v in res.timings.items():
                out[k] = out.get(k, 0.0) + float(v)
        return out

    @property
    def subset_size(self) -> int:
        return sum(r.subset_size for r in self.tasks.values())

    @property
    def engine(self) -> str:
        names = {r.engine for r in self.tasks.values()}
        return names.pop() if len(names) == 1 else ",".join(sorted(names))

    @property
    def score_dtype(self) -> str:
        names = {getattr(r, "score_dtype", "f32")
                 for r in self.tasks.values()}
        return names.pop() if len(names) == 1 else ",".join(sorted(names))

    @property
    def worker_id(self) -> str:
        names = {getattr(r, "worker_id", "") for r in self.tasks.values()}
        return names.pop() if len(names) == 1 else ",".join(sorted(names))

    @property
    def handoff(self) -> str:
        names = {getattr(r, "handoff", "") or "durable"
                 for r in self.tasks.values()}
        return names.pop() if len(names) == 1 else ",".join(sorted(names))


class ValidationSuite:
    """Validate checkpoints against N tasks in one pass, sharing stores.

    ``engines`` optionally injects a pre-built engine per task name (the
    multi-task twin of the old ``ValidationPipeline(engine=...)`` hook);
    unlisted tasks build theirs lazily via :func:`make_engine`.
    """

    def __init__(self, spec: EncoderSpec, tasks: Sequence[ValidationTask],
                 vcfg: Optional[ValidationConfig] = None, *,
                 engines: Optional[Dict[str, Any]] = None):
        vcfg = vcfg if vcfg is not None else ValidationConfig()
        self.spec = spec
        self.vcfg = vcfg
        self.tasks: Dict[str, ValidationTask] = {}
        for t in tasks:
            if t.name in self.tasks:
                raise ValueError(f"duplicate task name {t.name!r}")
            # resolve the per-task overrides against the shared config NOW,
            # so every downstream consumer sees concrete values
            t = dataclasses.replace(
                t, mode=t.mode if t.mode is not None else vcfg.mode,
                metrics=tuple(t.metrics) if t.metrics is not None
                else tuple(vcfg.metrics),
                k=t.k if t.k is not None else vcfg.k)
            MODES.get(t.mode)                    # fail fast, with options
            self.tasks[t.name] = t
        if not self.tasks:
            raise ValueError("ValidationSuite needs at least one task")
        self._engines: Dict[str, Any] = dict(engines or {})
        # shared TokenStore cache: key -> store; store_builds counts actual
        # pad-and-build events (tests assert corpus-sharing tasks hit 1)
        self._stores: Dict[tuple, TokenStore] = {}
        self._store_order: Dict[tuple, int] = {}
        self.store_builds = 0
        # samplers run ONCE per task, now — the subset depends only on the
        # baseline run + qrels, never on the checkpoint (paper §3)
        self.subsets: Dict[str, SubsetResult] = {}
        self.sampler_names: Dict[str, str] = {}
        self._data: Dict[str, ValidationStore] = {}
        for name, t in self.tasks.items():
            sampler = resolve_sampler(t.sampler, depth=t.sampler_depth)
            self.sampler_names[name] = sampler.name
            subset = sampler.sample(list(t.corpus), t.baseline_run, t.qrels)
            self.subsets[name] = subset
            qids = list(t.queries)
            self._data[name] = ValidationStore(
                query_ids=qids,
                query_texts=[t.queries[q] for q in qids],
                doc_ids=subset.doc_ids,
                doc_texts=[t.corpus[d] for d in subset.doc_ids],
                per_query=subset.per_query)
        if vcfg.token_backing == "mmap":
            # assign each distinct store its cache-dir index NOW, in task
            # declaration order — if it depended on lazy engine-BUILD order,
            # a run that touched tasks in a different order would remap
            # corpora onto each other's cache dirs and rebuild both (the
            # fingerprint check keeps that safe, but the cache is defeated)
            for name, t in self.tasks.items():
                tcfg = self._task_cfg(t)
                key = self._store_key(t, self._data[name], tcfg)
                self._store_order.setdefault(key, len(self._store_order))

    # -- shared resources ----------------------------------------------------
    @property
    def task_names(self) -> Tuple[str, ...]:
        return tuple(self.tasks)

    def _task_cfg(self, task: ValidationTask) -> ValidationConfig:
        return dataclasses.replace(self.vcfg, mode=task.mode,
                                   metrics=tuple(task.metrics), k=task.k)

    def _store_key(self, task: ValidationTask, data: ValidationStore,
                   tcfg: ValidationConfig) -> tuple:
        chunk, _ = chunk_geometry(tcfg, len(data.doc_texts), tcfg.mesh)
        ids = hashlib.sha1("\x00".join(data.doc_ids).encode()).hexdigest()
        return (id(task.corpus), ids, chunk, self.spec.p_max_len,
                tcfg.token_backing, tcfg.token_fingerprint)

    def _shared_doc_store(self, task: ValidationTask, data: ValidationStore,
                          tcfg: ValidationConfig) -> TokenStore:
        """The suite-wide TokenStore cache: tasks whose sampled corpus and
        chunk geometry match share one padded store (and, for mmap backing,
        one on-disk cache directory)."""
        key = self._store_key(task, data, tcfg)
        store = self._stores.get(key)
        if store is None:
            if tcfg.token_backing == "mmap" and not tcfg.mmap_dir:
                raise ValueError("token_backing='mmap' needs mmap_dir")
            index = self._store_order.setdefault(key, len(self._store_order))
            chunk, _ = chunk_geometry(tcfg, len(data.doc_texts), tcfg.mesh)
            with self.vcfg.telemetry.span(
                    "store_build", task=task.name, n_docs=len(data.doc_texts),
                    backing=tcfg.token_backing) \
                    if self.vcfg.telemetry is not None else _NULL_CM:
                store = TokenStore.build(
                    data.doc_texts, max_len=self.spec.p_max_len, chunk=chunk,
                    backing=tcfg.token_backing,
                    cache_dir=doc_cache_dir(tcfg.mmap_dir, index),
                    fingerprint=tcfg.token_fingerprint)
            self._stores[key] = store
            self.store_builds += 1
        return store

    def engine(self, name: str):
        """The (lazily built) engine for one task — built through the
        registry-backed :func:`make_engine` with the task-effective config
        passed whole."""
        if name not in self.tasks:
            raise ValueError(f"unknown task {name!r} "
                             f"(tasks: {', '.join(self.tasks)})")
        eng = self._engines.get(name)
        if eng is None:
            task, data = self.tasks[name], self._data[name]
            tcfg = self._task_cfg(task)
            # route the corpus store through the suite cache so
            # corpus-sharing tasks pad it exactly once — for every engine
            # factory that declares `uses_token_stores = True` (the built-in
            # streaming engine does; third-party registered engines opt in
            # with the same attribute)
            from repro.core.registry import ENGINES
            factory = ENGINES.get(tcfg.engine)
            if getattr(factory, "uses_token_stores", False) \
                    and data.doc_store is None:
                data.doc_store = self._shared_doc_store(task, data, tcfg)
            eng = make_engine(self.spec, data, tcfg)
            self._engines[name] = eng
        return eng

    def build_engines(self) -> None:
        """Eagerly build every task's engine.  Long-running drivers (the
        CLI, launch/train) call this at startup so a deterministic config
        error — bad staging depth, unknown engine, a third-party factory
        that raises — fails fast, instead of being swallowed per checkpoint
        by the validator's never-kill-training catch and retry loop."""
        for name in self.tasks:
            self.engine(name)

    # -- work-unit planning (the fleet's claimable granularity) --------------
    def plan_units(self, step: int):
        """The checkpoint's validation work as independently claimable
        :class:`~repro.core.workqueue.WorkUnit`\\ s — one per task, in task
        declaration order (``validate_params`` runs exactly this plan
        in-line, so a fleet draining the units computes the same rows).

        Each unit's capability requirements derive from the task-effective
        config (``mesh_size`` = the validator mesh's device count, 1
        unsharded) merged under any explicit ``ValidationTask.requires``."""
        from repro.core.workqueue import WorkUnit
        units = []
        for name, task in self.tasks.items():
            tcfg = self._task_cfg(task)
            requires = {"mesh_size": (tcfg.mesh.devices.size
                                      if tcfg.mesh is not None else 1)}
            requires.update(task.requires or {})
            units.append(WorkUnit.make(step, name, requires))
        return units

    def run_unit(self, params, unit, *, engine=None,
                 write_runs: Optional[bool] = None) -> ValidationResult:
        """Run ONE (step, task) work unit — the per-task body of
        ``validate_params``, exposed so fleet workers can execute units
        independently (two tasks of one step may run in different
        processes; the fingerprinted mmap TokenStore cache makes the
        shared-corpus case safe — each process maps the same pre-padded
        bytes, see :meth:`_shared_doc_store`)."""
        name = getattr(unit, "task", unit if isinstance(unit, str) else None)
        if name not in self.tasks:
            raise ValueError(f"unknown task {name!r} "
                             f"(tasks: {', '.join(self.tasks)})")
        step, task = int(getattr(unit, "step", 0)), self.tasks[name]
        eng = engine if engine is not None else self.engine(name)
        tel = self.vcfg.telemetry
        if tel is None:
            run, scores, timings = eng.run(params)
        else:
            # exactly ONE scored span per (step, task) unit; the engine's
            # staged/encoded spans nest under it via the tracer's
            # thread-local parent stack
            with tel.span("scored", step=step, task=name,
                          engine=getattr(eng, "name", ""),
                          score_dtype=getattr(eng, "score_dtype", "f32")):
                run, scores, timings = eng.run(params)
        names = list(task.metrics)
        if task.mode == "average_rank" and "AverageRank" not in names:
            names.append("AverageRank")
        m = metrics_lib.compute_metrics(run, task.qrels, names)
        v = self.vcfg
        do_write = v.write_run if write_runs is None else write_runs
        if do_write and v.output_dir:
            import os
            os.makedirs(v.output_dir, exist_ok=True)
            # default task keeps the legacy file name; other tasks get
            # a task-qualified tag so runs never collide
            tag = v.run_tag if name == "default" \
                else f"{v.run_tag}.{name}"
            metrics_lib.write_trec_run(
                f"{v.output_dir}/{tag}_step{step}.trec", run, scores,
                tag=tag)
        return ValidationResult(
            step=step, metrics=m, timings=timings,
            subset_size=len(self._data[name].doc_ids),
            engine=getattr(eng, "name", ""),
            score_dtype=getattr(eng, "score_dtype", "f32"), task=name)

    # -- one checkpoint, every task -----------------------------------------
    def validate_params(self, params, step: int = 0, *, engine=None,
                        write_runs: Optional[bool] = None) -> SuiteResult:
        """Validate one checkpoint against every task.  ``engine`` overrides
        every task's engine for this call only (the AsyncValidator injection
        path) — the suite itself is never mutated.  ``write_runs`` overrides
        ``vcfg.write_run`` for this call (scoring passes — e.g. ensemble
        soup candidates — set it False so they never clobber a real
        checkpoint's TREC run file).

        This IS the work-unit pipeline run in-line: ``plan_units`` then
        ``run_unit`` per unit, in task order — a single process and a fleet
        draining the same plan produce identical rows."""
        if engine is not None and len(self.tasks) > 1:
            # an injected engine was built over ONE task's queries/corpus;
            # scoring every task with it would silently ledger garbage
            # metrics for the others (use ValidationSuite(engines={...}) to
            # inject per task instead)
            raise ValueError(
                "a single engine override cannot serve a multi-task suite "
                f"(tasks: {', '.join(self.tasks)}); pass per-task engines "
                "via ValidationSuite(engines={name: engine})")
        out: Dict[str, ValidationResult] = {}
        for unit in self.plan_units(step):
            out[unit.task] = self.run_unit(params, unit, engine=engine,
                                           write_runs=write_runs)
        return SuiteResult(step=step, tasks=out)


def params_from_checkpoint(state: Any) -> Any:
    """Default extractor: trainer saves {"params":..., "opt_state":...}."""
    return state["params"] if isinstance(state, dict) and "params" in state \
        else state
