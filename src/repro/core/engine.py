"""Streaming device-resident ValidationEngine — encode→top-k with no host hop.

The legacy ``ValidationPipeline`` path materialized the full ``(N, D)`` corpus
embedding matrix on host (one ``np.asarray`` per batch), then shipped it back
to device for retrieval: 2x the memory traffic and a hard host-RAM cap on
corpus size.  This module replaces that with a staged, device-resident
pipeline:

  1. :class:`TokenStore` — the corpus is padded ONCE into fixed-shape
     ``(chunk, L)`` token/mask chunks (the paper's §3 pre-tokenization
     argument, extended to pre-padding: the cost amortizes across every
     checkpoint the validator ever sees, and every chunk compiles to the
     same XLA program).  With ``backing="mmap"`` the chunks live in
     memory-mapped files on disk (built once, reused across checkpoints and
     processes), so even the corpus *tokens* can exceed host RAM.
  2. A **fused encode→top-k streaming loop** — each chunk is encoded on
     device and its scores are immediately folded into the running ``(Q, k)``
     top-k carry inside one jitted step; the chunk's embedding buffer is an
     XLA temporary, freed as soon as the step retires.  Peak embedding
     memory is ``O(chunk x D + Q x k)`` — the ``(N, D)`` matrix is *never*
     materialized, on host or device, so the corpus can exceed host RAM.
  3. **Pipelined host→device staging** (:func:`staged_batches`) — the
     async ``jax.device_put`` of chunk ``i+1`` is issued while chunk ``i``'s
     fused step is still in flight, for both the single-device and
     ``shard_map`` paths (sharded chunks are placed with the row sharding
     the step's ``in_specs`` expect, so no re-layout happens at dispatch).
     The prefetch depth is configurable (``staging_depth``; 2 = the classic
     double buffer, deeper for remote-storage token stores).  Peak
     host-staged token memory is ``O(depth x window x chunk x L)``.
  4. A shared :class:`Stage` interface through which every validation mode
     (``retrieval``, ``rerank``, ``average_rank``) and every implementation
     (``xla``, ``pallas`` via ``repro.kernels.topk_mips``, sharded via
     ``shard_map`` on the validator mesh) is routed — rerank included: the
     sharded rerank stage shards chunk rows over the mesh and folds per-
     shard candidate scores with a slot-aligned hierarchical merge, so
     ``make_stage(mode="rerank", mesh=...)`` scales exactly like retrieval.
     Query encoding routes through the same sharded path
     (``encode_store(mesh=...)``) so huge query sets shard with the corpus.

``MaterializedEngine`` preserves the legacy encode-all-then-retrieve path
behind the same interface for A/B benchmarking
(``benchmarks/bench_streaming_engine.py``) and backward compatibility.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
import time
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.encoder import cached_compiled, encode_texts, jitted_encoder
from repro.core.precision import chunk_scores, validate_score_dtype
from repro.core.registry import (ENGINES, IMPLS, MODES, STAGES,
                                 register_engine, register_impl,
                                 register_mode, register_stage)
from repro.core.retrieval import (_hierarchical_slot_max,
                                  _hierarchical_topk_merge, _merge_topk,
                                  pad_candidates, rank_candidates, rerank_run,
                                  retrieve_run)
from repro.data.corpus import Tokens, pad_batch
from repro.distributed import compat

Run = Dict[str, List[str]]
Scores = Dict[str, List[float]]


def _donate(*argnums: int) -> tuple:
    """Donation positions for the top-k carry — skipped on CPU where XLA
    cannot alias the buffers (it would only warn)."""
    return () if jax.default_backend() == "cpu" else argnums


# ---------------------------------------------------------------------------
# Stage 1: TokenStore — pad/chunk the corpus once, amortized over checkpoints
# ---------------------------------------------------------------------------


_STORE_META = "store_meta.json"
_STORE_TOKENS = "tokens.int32.bin"
_STORE_MASK = "mask.bool.bin"
_STORE_MANIFEST = "chunk_hashes.json"
_STORE_VERSION = 1


def _chunk_hash(texts: Sequence[Tokens]) -> str:
    """Content hash of one chunk's texts (the unit of the full-fingerprint
    manifest: a changed chunk hash means exactly that chunk must be
    re-padded and re-written)."""
    h = hashlib.sha1()
    for t in texts:
        h.update(np.asarray(list(t), np.int64).tobytes())
        h.update(b"|")
    return h.hexdigest()


def _full_fingerprint(chunk_hashes: Sequence[str], *, n: int, max_len: int,
                      chunk: int) -> str:
    """Overall full-content fingerprint, derived from the per-chunk hashes
    so the digest and the manifest can never disagree."""
    h = hashlib.sha1()
    h.update(f"v{_STORE_VERSION}:full:{n}:{max_len}:{chunk}".encode())
    for ch in chunk_hashes:
        h.update(ch.encode())
    return h.hexdigest()


def _store_fingerprint(texts: Sequence[Tokens], *, max_len: int,
                       chunk: int, mode: str = "fast") -> str:
    """Content fingerprint for mmap-cache reuse.

    ``mode="fast"`` (default): geometry plus a hash of the first/last 16
    texts.  Deliberately O(1) in corpus size — the point of the cache is to
    NOT re-read millions of texts per checkpoint.  The documented hazard:
    a caller that mutates the *middle* of a corpus in place (same length,
    same edges) gets a stale cache hit; such callers must use a fresh
    ``cache_dir`` or opt into ``mode="full"``.

    ``mode="full"``: hashes every text — O(corpus) per build, but any
    single-token mutation anywhere invalidates the cache.  The two modes
    hash disjoint tag prefixes, so switching modes always rebuilds rather
    than trusting the other mode's marker.
    """
    if mode not in ("fast", "full"):
        raise ValueError(f"unknown fingerprint mode {mode!r} "
                         "(expected 'fast' or 'full')")
    if mode == "full":
        n_chunks = -(-len(texts) // max(chunk, 1)) if len(texts) else 0
        hashes = [_chunk_hash(texts[ci * chunk:(ci + 1) * chunk])
                  for ci in range(n_chunks)]
        return _full_fingerprint(hashes, n=len(texts), max_len=max_len,
                                 chunk=chunk)
    h = hashlib.sha1()
    h.update(f"v{_STORE_VERSION}:{mode}:{len(texts)}:{max_len}:{chunk}"
             .encode())
    for t in list(texts[:16]) + list(texts[-16:]):
        h.update(np.asarray(list(t), np.int64).tobytes())
        h.update(b"|")
    return h.hexdigest()


@dataclasses.dataclass
class TokenStore:
    """Corpus tokens padded into fixed-shape device-friendly chunks.

    ``tokens``/``mask`` are ``(n_chunks, chunk, L)`` host arrays; every chunk
    has the same shape (the final ragged chunk is zero-padded and masked by
    ``n_valid``), so the fused step compiles exactly once.  With
    ``backing="mmap"`` they are read-only ``numpy.memmap`` views over files
    in ``cache_dir`` and only the staged chunks ever occupy host RAM.
    """

    tokens: np.ndarray          # (n_chunks, chunk, L) int32
    mask: np.ndarray            # (n_chunks, chunk, L) bool
    chunk: int
    n_texts: int
    backing: str = "memory"     # memory | mmap
    cache_dir: Optional[str] = None
    reused: bool = False        # mmap only: True when cache files were reused
    rebuilt_chunks: int = 0     # chunks padded+written by THIS build (0 on a
                                # cache hit; < n_chunks on a full-fingerprint
                                # incremental rebuild via the hash manifest)

    @classmethod
    def build(cls, texts: Sequence[Tokens], *, max_len: int, chunk: int,
              backing: str = "memory", cache_dir: Optional[str] = None,
              fingerprint: str = "fast") -> "TokenStore":
        """Pad ``texts`` into ``(n_chunks, chunk, max_len)`` token/mask arrays.

        ``backing="memory"`` (default) holds both arrays in host RAM.

        ``backing="mmap"`` spills them to memory-mapped files under
        ``cache_dir`` (required), built once and reused by every later
        ``build`` with the same geometry + content fingerprint — across
        checkpoints AND across processes.  ``fingerprint`` picks the cache
        key: ``"fast"`` (default) is O(1) in corpus size (geometry + edge
        texts — a *middle* mutation with unchanged edges is a documented
        stale hit; use a fresh ``cache_dir`` or ``"full"``), ``"full"``
        hashes every text so any in-place mutation rebuilds the cache (see
        :func:`_store_fingerprint`).  On-disk format (version 1):

        * ``store_meta.json`` — ``{"version", "n_texts", "chunk", "max_len",
          "n_chunks", "fingerprint"}``; written LAST, so a torn build (crash
          mid-write) is never mistaken for a valid cache.
        * ``tokens.int32.bin`` — raw C-order ``(n_chunks, chunk, max_len)``
          little-endian int32, zero-padded past each text's length and past
          ``n_texts`` in the final ragged chunk.
        * ``mask.bool.bin`` — raw C-order ``(n_chunks, chunk, max_len)``
          1-byte bool, ``True`` exactly on real token positions.
        * ``chunk_hashes.json`` — ``fingerprint="full"`` only: the per-chunk
          content-hash manifest ``{"version", "hashes": [sha1, ...]}``.  On a
          rebuild with unchanged geometry, only chunks whose hash differs
          from the manifest are re-padded and re-written (the memmaps are
          opened ``r+``), so full-fidelity revalidation costs O(changed
          chunks) of padding/IO instead of O(corpus) — change detection
          itself is a hash pass, which is what ``full`` already paid.
          Written immediately before the meta marker; fast-mode rebuilds
          delete it so it can never describe bins they rewrote.

        The build itself streams chunk by chunk, so peak host memory during
        construction is ``O(chunk x max_len)`` regardless of corpus size;
        afterwards the maps are reopened read-only (``mode="r"``) so the
        cache cannot be corrupted by a stray write.
        """
        if fingerprint not in ("fast", "full"):
            raise ValueError(f"unknown fingerprint mode {fingerprint!r} "
                             "(expected 'fast' or 'full')")
        n = len(texts)
        chunk = max(1, chunk)
        n_chunks = -(-n // chunk) if n else 0
        shape = (n_chunks, chunk, max_len)
        if backing == "memory":
            toks = np.zeros(shape, np.int32)
            mask = np.zeros(shape, bool)
            for ci in range(n_chunks):
                part = list(texts[ci * chunk:(ci + 1) * chunk])
                t, m = pad_batch(part, max_len)
                toks[ci, :len(part)] = t
                mask[ci, :len(part)] = m
            return cls(tokens=toks, mask=mask, chunk=chunk, n_texts=n,
                       rebuilt_chunks=n_chunks)
        if backing != "mmap":
            raise ValueError(f"unknown TokenStore backing {backing!r} "
                             "(expected 'memory' or 'mmap')")
        if not cache_dir:
            raise ValueError("TokenStore backing='mmap' needs a cache_dir")
        os.makedirs(cache_dir, exist_ok=True)
        meta_path = os.path.join(cache_dir, _STORE_META)
        tok_path = os.path.join(cache_dir, _STORE_TOKENS)
        mask_path = os.path.join(cache_dir, _STORE_MASK)
        manifest_path = os.path.join(cache_dir, _STORE_MANIFEST)
        chunk_hashes: Optional[List[str]] = None
        if fingerprint == "full":
            chunk_hashes = [_chunk_hash(texts[ci * chunk:(ci + 1) * chunk])
                            for ci in range(n_chunks)]
            fp = _full_fingerprint(chunk_hashes, n=n, max_len=max_len,
                                   chunk=chunk)
        else:
            fp = _store_fingerprint(texts, max_len=max_len, chunk=chunk,
                                    mode=fingerprint)
        meta = {"version": _STORE_VERSION, "n_texts": n, "chunk": chunk,
                "max_len": max_len, "n_chunks": n_chunks, "fingerprint": fp}
        n_slots = int(np.prod(shape))
        stored = None
        if os.path.exists(meta_path):
            try:
                with open(meta_path) as f:
                    stored = json.load(f)
            except ValueError:      # torn/truncated meta: rebuild, not crash
                stored = None
        # a valid marker alone is not enough: the bins must exist with
        # exactly the bytes the marker promises (a partially copied or
        # hand-cleaned cache_dir must rebuild, not crash or mis-map)
        sizes_ok = True
        if n_chunks:
            try:
                sizes_ok = (os.path.getsize(tok_path) == n_slots * 4
                            and os.path.getsize(mask_path) == n_slots)
            except OSError:
                sizes_ok = False
        same_geometry = stored is not None and all(
            stored.get(k) == meta[k]
            for k in ("version", "n_texts", "chunk", "max_len", "n_chunks"))
        reused = (same_geometry and sizes_ok
                  and stored.get("fingerprint") == fp)
        rebuilt: List[int] = []
        if not reused and n_chunks:
            # full-fingerprint incremental rebuild: when the geometry is
            # unchanged and the previous *full* build left a per-chunk hash
            # manifest, only chunks whose hash changed are re-padded and
            # re-written — O(changed chunks) instead of O(corpus).  The
            # manifest is trustworthy because every code path that rewrites
            # the bins either rewrites it too (full builds, below) or
            # removes it (fast builds), and a reused cache touches neither.
            prev_hashes: Optional[List[str]] = None
            if same_geometry and sizes_ok and chunk_hashes is not None:
                try:
                    with open(manifest_path) as f:
                        prev = json.load(f)
                    if (prev.get("version") == _STORE_VERSION
                            and isinstance(prev.get("hashes"), list)
                            and len(prev["hashes"]) == n_chunks):
                        prev_hashes = prev["hashes"]
                except (OSError, ValueError):
                    prev_hashes = None
            incremental = prev_hashes is not None
            rebuilt = ([ci for ci in range(n_chunks)
                        if prev_hashes[ci] != chunk_hashes[ci]]
                       if incremental else list(range(n_chunks)))
            # invalidate the old commit marker FIRST: if this rebuild dies
            # mid-write, no stale meta can bless the half-rewritten bins
            if os.path.exists(meta_path):
                os.remove(meta_path)
            if not incremental and os.path.exists(manifest_path):
                # bins are about to stop matching the old manifest; a fast
                # build writes no replacement, so the stale one must go
                os.remove(manifest_path)
            wmode = "r+" if incremental else "w+"
            wt = np.memmap(tok_path, dtype=np.int32, mode=wmode, shape=shape)
            wm = np.memmap(mask_path, dtype=bool, mode=wmode, shape=shape)
            for ci in rebuilt:
                part = list(texts[ci * chunk:(ci + 1) * chunk])
                t, m = pad_batch(part, max_len)
                wt[ci] = 0
                wm[ci] = False
                wt[ci, :len(part)] = t
                wm[ci, :len(part)] = m
            wt.flush()
            wm.flush()
            del wt, wm
        if not reused:
            if chunk_hashes is not None:
                # manifest before meta: a crash in between leaves no meta,
                # forcing a rebuild — never a meta blessing a stale manifest
                tmp = manifest_path + ".tmp"
                with open(tmp, "w") as f:
                    json.dump({"version": _STORE_VERSION,
                               "hashes": chunk_hashes}, f)
                os.replace(tmp, manifest_path)
            # commit marker: meta written LAST, and atomically (a crash
            # mid-write must leave no half-valid marker behind)
            tmp = meta_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(meta, f)
            os.replace(tmp, meta_path)
        if n_chunks:
            toks = np.memmap(tok_path, dtype=np.int32, mode="r", shape=shape)
            mask = np.memmap(mask_path, dtype=bool, mode="r", shape=shape)
        else:
            toks = np.zeros(shape, np.int32)
            mask = np.zeros(shape, bool)
        return cls(tokens=toks, mask=mask, chunk=chunk, n_texts=n,
                   backing="mmap", cache_dir=cache_dir, reused=reused,
                   rebuilt_chunks=len(rebuilt))

    @property
    def n_chunks(self) -> int:
        return self.tokens.shape[0]

    def rows_valid(self, ci: int) -> int:
        return min(self.chunk, self.n_texts - ci * self.chunk)

    def chunks(self) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray, int, int]]:
        """Yield (tokens, mask, base_row, n_valid_rows) per chunk."""
        for ci in range(self.n_chunks):
            yield (jnp.asarray(self.tokens[ci]), jnp.asarray(self.mask[ci]),
                   ci * self.chunk, self.rows_valid(ci))

    def candidate_map(self, cand_idx: np.ndarray) -> "CandidateMap":
        """Precompute candidate membership against THIS store's chunking.

        ``cand_idx`` is the padded ``(Q, Cmax)`` slot map of global corpus
        rows from :func:`repro.core.retrieval.pad_candidates` (-1 = pad).
        The result is what lets rerank stages touch only the corpus that
        matters: a per-chunk ``(chunk,)`` row-membership mask (is this row
        any query's candidate?) plus per-chunk counts the engine uses to
        skip — never stage, never encode — chunks with zero candidate rows.
        Built once per validator lifetime, like the store itself.
        """
        rows = np.unique(cand_idx[cand_idx >= 0])
        rows = rows[rows < self.n_texts]
        row_mask = np.zeros((self.n_chunks, self.chunk), bool)
        if rows.size and self.n_chunks:
            row_mask[rows // self.chunk, rows % self.chunk] = True
        return CandidateMap(slot_map=np.asarray(cand_idx, np.int32),
                            row_mask=row_mask,
                            chunk_counts=row_mask.sum(axis=1),
                            chunk=self.chunk)


@dataclasses.dataclass
class CandidateMap:
    """Per-chunk candidate membership for the rerank stages (built on the
    TokenStore side, where the chunk geometry lives).

    ``slot_map`` is the replicated ``(Q, Cmax)`` candidate slot map (global
    corpus rows, -1 = pad); ``row_mask[ci]`` is the ``(chunk,)`` mask of
    rows in chunk ``ci`` that appear in ANY query's candidate set; and
    ``chunk_counts[ci]`` is its popcount — zero means the chunk holds no
    candidates and the engine skips it entirely (no staging, no encode).
    """

    slot_map: np.ndarray        # (Q, Cmax) int32 global rows, -1 = pad
    row_mask: np.ndarray        # (n_chunks, chunk) bool candidate membership
    chunk_counts: np.ndarray    # (n_chunks,) int per-chunk candidate rows
    chunk: int

    def has_candidates(self, ci: int) -> bool:
        return bool(self.chunk_counts[ci])


# Sharded-encoder cache keyed on (encode_fn, mesh, axis_names) — one compiled
# shard_map executable per encoder+mesh, shared across checkpoints (the same
# per-checkpoint retrace bug ``jitted_encoder`` fixes for the 1-device path).
# Bounded-LRU via encoder.cached_compiled, same policy as _JIT_CACHE.
_SHARDED_ENC_CACHE: "collections.OrderedDict" = collections.OrderedDict()


def _sharded_encoder(encode_fn: Callable, mesh,
                     axis_names: Tuple[str, ...]) -> Callable:
    ax = axis_names[0] if len(axis_names) == 1 else axis_names

    def build():
        return jax.jit(compat.shard_map(
            encode_fn, mesh=mesh, in_specs=(P(), P(ax), P(ax)),
            out_specs=P(ax), check=False))

    return cached_compiled(_SHARDED_ENC_CACHE, (encode_fn, mesh, axis_names),
                           build)


def encode_store(encode_fn: Callable, params, store: TokenStore, *,
                 mesh=None, axis_names=None) -> jnp.ndarray:
    """Encode a TokenStore fully — used for queries, whose ``(Q, D)`` matrix
    is part of the streaming carry anyway.  Stays on device.

    With ``mesh`` the chunk rows are sharded over ``axis_names`` and each
    shard encodes its rows under one ``shard_map`` — the same sharded stage
    the corpus streams through, so huge query sets scale with the mesh
    instead of capping on one device.  Requires ``store.chunk`` divisible by
    the shard count (``make_engine`` rounds the query chunk up to that).
    """
    if mesh is None:
        fn = jitted_encoder(encode_fn)
        put = None
    else:
        from repro.distributed.sharding import rows_sharding
        axis_names = tuple(axis_names or mesh.axis_names)
        fn = _sharded_encoder(encode_fn, mesh, axis_names)
        put = rows_sharding(mesh, axis_names)
    outs = []
    for toks, mask in staged_batches(store,
                                     plan_schedule(store.n_chunks, 1),
                                     sharding=put):
        outs.append(fn(params, toks, mask))
    if not outs:
        return jnp.zeros((0, 1), jnp.float32)
    return jnp.concatenate(outs, axis=0)[:store.n_texts]


# ---------------------------------------------------------------------------
# Stage 2: host→device staging — double-buffered device_put ahead of compute
# ---------------------------------------------------------------------------


def plan_schedule(n_chunks: int, window: int) -> List[Tuple[int, int]]:
    """Dispatch schedule ``[(first_chunk, n_chunks_in_batch), ...]``.

    ``window`` > 1 groups that many chunks per dispatch with a halving tail:
    a corpus of C chunks costs ~C/window + log2(window) dispatches and at
    most log2(window)+2 compiled programs (amortized across every checkpoint
    the engine ever validates)."""
    out: List[Tuple[int, int]] = []
    ci, w = 0, max(1, window)
    while ci < n_chunks:
        while w > 1 and ci + w > n_chunks:
            w //= 2
        out.append((ci, w))
        ci += w
    return out


def staged_batches(store: TokenStore, schedule: Sequence[Tuple[int, int]], *,
                   sharding=None, depth: int = 2,
                   _put: Callable = None) -> Iterator[Tuple[Any, Any]]:
    """Yield ``(tokens, mask)`` device buffers for each schedule entry,
    staged ``depth`` batches ahead of the consumer.

    ``depth=1`` is synchronous staging (copy, then compute).  ``depth=2``
    (default) is the double buffer: when batch ``i`` is yielded, batch
    ``i+1``'s ``jax.device_put`` has already been issued, so the host→device
    copy of the next chunk overlaps the fused encode→top-k step of the
    current one — the consumer's compute dispatch returns before the copy is
    needed.  Peak host-staged token memory is ``O(depth x w x chunk x L)``
    (with a memory-backed store the whole corpus is resident anyway; with
    ``backing="mmap"`` this bound is the engine's entire host token
    footprint).

    ``sharding`` (a ``Sharding``) places each batch directly in the layout
    the consuming jitted step expects — for the ``shard_map`` stage the rows
    land pre-sharded across the mesh, so dispatch does no re-layout.
    """
    put = _put or (lambda x: jax.device_put(x, sharding))
    depth = max(1, depth)

    def stage(ci: int, w: int) -> Tuple[Any, Any]:
        if w == 1:
            return put(store.tokens[ci]), put(store.mask[ci])
        return put(store.tokens[ci:ci + w]), put(store.mask[ci:ci + w])

    q: "collections.deque" = collections.deque()
    idx = 0
    while q or idx < len(schedule):
        while idx < len(schedule) and len(q) < depth:
            q.append(stage(*schedule[idx]))
            idx += 1
        yield q.popleft()


# ---------------------------------------------------------------------------
# Stage 2+3: fused encode→fold stages behind one interface
# ---------------------------------------------------------------------------


class Stage:
    """One streaming validation strategy: a device carry folded chunk by chunk.

    ``init(q_emb) -> carry``; ``step(params, q_emb, carry, toks, mask, base,
    n_valid) -> carry``; ``finalize(carry) -> (run, run_scores)``.
    """

    name = "stage"

    def init(self, q_emb: jnp.ndarray):
        raise NotImplementedError

    def step(self, params, q_emb, carry, toks, mask, base: int, n_valid: int):
        raise NotImplementedError

    def finalize(self, carry) -> Tuple[Run, Scores]:
        raise NotImplementedError


class StreamTopKStage(Stage):
    """Retrieval mode, XLA path: encode a chunk and merge its local top-k into
    the running (Q, k) carry in a single jitted (fused) step.

    ``window`` > 1 additionally compiles a ``lax.scan`` over that many chunks
    so the engine can fold a whole window of chunks per dispatch — same
    per-chunk math in the same order (parity is preserved bit for bit), but
    the Python/dispatch overhead amortizes ``window``-fold.  Token staging
    grows to O(window x chunk x L); embeddings stay O(chunk x D).
    """

    name = "topk_xla"

    def __init__(self, encode_fn: Callable, *, k: int, query_ids: List[str],
                 doc_ids: List[str], window: int = 8,
                 score_dtype: str = "f32"):
        self.query_ids = query_ids
        self.doc_ids = doc_ids
        self.k = max(1, min(k, len(doc_ids))) if doc_ids else 0
        self.window = max(1, window)
        self.score_dtype = validate_score_dtype(score_dtype)
        k_carry = self.k

        def fold(carry, q_emb, params, toks, mask, base, n_valid):
            run_s, run_i = carry
            emb = encode_fn(params, toks, mask)               # (chunk, D)
            # static precision branch: "f32" keeps the literal legacy
            # expression (bit-for-bit); narrow dtypes cast the chunk's
            # embeddings once, right here, and dequantize to f32 scores
            # before the mask + merge below ever see them.
            if score_dtype == "f32":
                s = (q_emb @ emb.T).astype(jnp.float32)       # (Q, chunk)
            else:
                s = chunk_scores(q_emb, emb, score_dtype)     # (Q, chunk)
            chunk = toks.shape[0]
            col = jnp.arange(chunk, dtype=jnp.int32)
            s = jnp.where((col < n_valid)[None, :], s, -jnp.inf)
            # single top_k over [carry ‖ chunk]: selecting top-k of the union
            # directly is identical to local-top-k-then-merge (top-k of a set
            # equals top-k of carry ∪ top-k(chunk)) but does one sort of
            # width k+chunk instead of two of width chunk and 2k.
            gcol = jnp.broadcast_to((col + base)[None, :], s.shape)
            return _merge_topk(run_s, run_i, s, gcol, k_carry)

        def fused(params, q_emb, run_s, run_i, toks, mask, base, n_valid):
            return fold((run_s, run_i), q_emb, params, toks, mask, base,
                        n_valid)

        def fused_window(params, q_emb, run_s, run_i, toks_w, mask_w,
                         bases, n_valids):
            def body(carry, inp):
                toks, mask, base, n_valid = inp
                return fold(carry, q_emb, params, toks, mask, base,
                            n_valid), None
            carry, _ = jax.lax.scan(body, (run_s, run_i),
                                    (toks_w, mask_w, bases, n_valids))
            return carry

        self._fused = jax.jit(fused, donate_argnums=_donate(2, 3))
        self._fused_window = jax.jit(fused_window,
                                     donate_argnums=_donate(2, 3))

    def init(self, q_emb):
        Q = q_emb.shape[0]
        return (jnp.full((Q, self.k), -jnp.inf, jnp.float32),
                jnp.zeros((Q, self.k), jnp.int32))

    def step(self, params, q_emb, carry, toks, mask, base, n_valid):
        run_s, run_i = carry
        return self._fused(params, q_emb, run_s, run_i, toks, mask,
                           jnp.asarray(base, jnp.int32),
                           jnp.asarray(n_valid, jnp.int32))

    def step_window(self, params, q_emb, carry, toks_w, mask_w, bases,
                    n_valids):
        """Fold ``window`` chunks in one dispatch (scan inside the jit)."""
        run_s, run_i = carry
        return self._fused_window(params, q_emb, run_s, run_i, toks_w,
                                  mask_w, jnp.asarray(bases, jnp.int32),
                                  jnp.asarray(n_valids, jnp.int32))

    def finalize(self, carry):
        run_s, run_i = np.asarray(carry[0]), np.asarray(carry[1])
        run, scores = {}, {}
        for qi, qid in enumerate(self.query_ids):
            run[qid] = [self.doc_ids[j] for j in run_i[qi]]
            scores[qid] = [float(s) for s in run_s[qi]]
        return run, scores


class PallasStreamTopKStage(StreamTopKStage):
    """Retrieval mode, Pallas path: the chunk's local top-k runs in the
    ``topk_mips`` Mosaic kernel (VMEM-resident running candidates), then the
    chunk-carry merge folds it into the engine carry."""

    name = "topk_pallas"

    def __init__(self, encode_fn: Callable, *, k: int, query_ids: List[str],
                 doc_ids: List[str], score_dtype: str = "f32"):
        # window=1: every chunk must go through the Pallas kernel, not the
        # XLA scan fallback.
        super().__init__(encode_fn, k=k, query_ids=query_ids, doc_ids=doc_ids,
                         window=1, score_dtype=score_dtype)
        self._encode = jitted_encoder(encode_fn)

    def step(self, params, q_emb, carry, toks, mask, base, n_valid):
        from repro.kernels.topk_mips import ops as mips_ops
        emb = self._encode(params, toks, mask)                # device-resident
        run_s, run_i = carry
        return mips_ops.topk_mips_chunk(q_emb, emb, run_s, run_i, base=base,
                                        n_valid=n_valid,
                                        score_dtype=self.score_dtype)


class ShardedStreamTopKStage(StreamTopKStage):
    """Retrieval mode on the validator mesh: each chunk's rows are sharded
    over ``axis_names``; every shard encodes and local-top-ks its rows, a
    hierarchical all-gather merge (innermost axis first — same wire math as
    ``retrieval.topk_sharded``) re-replicates the chunk candidates, and the
    carry merge happens replicated.  The whole streaming step runs under one
    ``shard_map``."""

    name = "topk_sharded"

    def __init__(self, encode_fn: Callable, mesh, *, k: int,
                 query_ids: List[str], doc_ids: List[str],
                 axis_names=None, score_dtype: str = "f32"):
        # window=1: the scan-window fast path is single-device XLA; every
        # sharded chunk must go through the shard_map step below.
        super().__init__(encode_fn, k=k, query_ids=query_ids,
                         doc_ids=doc_ids, window=1, score_dtype=score_dtype)
        axis_names = tuple(axis_names or mesh.axis_names)
        k_carry = self.k
        ax = axis_names[0] if len(axis_names) == 1 else axis_names

        def local(params, q_emb, run_s, run_i, toks, mask, base, n_valid):
            emb = encode_fn(params, toks, mask)               # (rows, D) local
            rows = toks.shape[0]
            shard = jax.lax.axis_index(ax)
            # per-ROW quantization is sharding-independent, so each shard's
            # local quantized scores equal the single-device stage's slice
            if score_dtype == "f32":
                s = (q_emb @ emb.T).astype(jnp.float32)       # (Q, rows)
            else:
                s = chunk_scores(q_emb, emb, score_dtype)     # (Q, rows)
            col = shard * rows + jnp.arange(rows, dtype=jnp.int32)
            s = jnp.where((col < n_valid)[None, :], s, -jnp.inf)
            kk = min(k_carry, rows)
            bs, pos = jax.lax.top_k(s, kk)
            bi = jnp.take(col, pos) + base                    # global doc rows
            bs, bi = _hierarchical_topk_merge(bs, bi, axis_names, k_carry)
            return _merge_topk(run_s, run_i, bs, bi, k_carry)

        spec_rows = P(ax)
        # check=False: the carry is replicated-in, device-varying mid-step,
        # re-replicated by the final merge — same legal pattern topk_sharded
        # documents.
        self._fused = jax.jit(compat.shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P(), P(), spec_rows, spec_rows, P(), P()),
            out_specs=(P(), P()), check=False))
        # layout staged token chunks must be device_put with so the step's
        # in_specs find them already resident (no re-layout at dispatch)
        from repro.distributed.sharding import rows_sharding
        self.input_sharding = rows_sharding(mesh, axis_names)

    def step(self, params, q_emb, carry, toks, mask, base, n_valid):
        run_s, run_i = carry
        return self._fused(params, q_emb, run_s, run_i, toks, mask,
                           jnp.asarray(base, jnp.int32),
                           jnp.asarray(n_valid, jnp.int32))


class StreamRerankStage(Stage):
    """Rerank / average-rank modes: the carry is the padded per-query
    candidate score matrix (Q, Cmax); each chunk's scores are gathered into
    it where the candidates' global rows fall inside the chunk.

    With a ``store`` the stage precomputes a :class:`CandidateMap` — the
    per-chunk ``(chunk,)`` candidate-row masks plus the replicated
    ``(Q, Cmax)`` slot map — so (a) the engine skips chunks with zero
    candidate rows (``wants_chunk``) and (b) the fused step only ever scores
    rows that appear in some query's candidate set (non-members are masked
    to ``-inf`` before the slot gather; members are untouched, so the carry
    is bit-for-bit what the unmasked step produced).  Finalization routes
    through the shared :func:`repro.core.retrieval.rank_candidates`, the
    same stable-tie-break selection the materialized ``rerank_run`` uses —
    that sharing is what makes cross-mode runs identical, not just close.
    """

    name = "rerank"

    def __init__(self, encode_fn: Callable, *, k: int, query_ids: List[str],
                 doc_ids: List[str], per_query: Dict[str, List[str]],
                 store: Optional[TokenStore] = None,
                 score_dtype: str = "f32", compact: bool = False):
        self.query_ids = query_ids
        self.k = k
        self.score_dtype = validate_score_dtype(score_dtype)
        cand_idx, self.cands = pad_candidates(query_ids, doc_ids, per_query)
        self.cmap = store.candidate_map(cand_idx) \
            if store is not None and store.n_chunks else None
        # gather compaction: at very sparse candidate depths most rows of a
        # surviving chunk are non-candidates that get encoded and masked to
        # -inf anyway.  Packing the candidate rows into dense pseudo-chunks
        # (and remapping the slot map onto them) makes every encoded row a
        # candidate — bit-for-bit identical scores for any row-independent
        # encoder, since the same token rows land in the same slots.  The
        # engine streams self.store_override instead of the original store.
        self.store_override: Optional[TokenStore] = None
        if compact and self.cmap is not None:
            packed = self._pack_candidates(store, cand_idx)
            if packed is not None:
                cand_idx, self.store_override = packed
                self.cmap = self.store_override.candidate_map(cand_idx)
        self.cand_idx = jnp.asarray(cand_idx)
        self._row_masks: Dict[int, jnp.ndarray] = {}

        def fused(params, q_emb, cand_s, cand_idx, toks, mask, row_mask,
                  base, n_valid):
            emb = encode_fn(params, toks, mask)               # (chunk, D)
            if score_dtype == "f32":
                s = (q_emb @ emb.T).astype(jnp.float32)       # (Q, chunk)
            else:
                s = chunk_scores(q_emb, emb, score_dtype)     # (Q, chunk)
            chunk = toks.shape[0]
            # score only candidate-member rows (membership precomputed per
            # chunk on the TokenStore side); hit slots always reference
            # member rows, so the gather below sees unmasked scores.
            s = jnp.where(row_mask[None, :], s, -jnp.inf)
            local = cand_idx - base
            hit = (cand_idx >= 0) & (local >= 0) & (local < n_valid)
            g = jnp.take_along_axis(s, jnp.clip(local, 0, chunk - 1), axis=1)
            return jnp.where(hit, g, cand_s)

        self._fused = jax.jit(fused, donate_argnums=_donate(2,))

    @staticmethod
    def _pack_candidates(store: TokenStore, cand_idx: np.ndarray):
        """Pack candidate token rows into dense pseudo-chunks.

        Returns ``(remapped_cand_idx, compact_store)``, or ``None`` when the
        candidate set is not sparse enough to pay for itself (the compacted
        store must need at most HALF the chunks the chunk-skipping schedule
        would already encode).  Host cost is one gather of
        O(candidate_rows x L) tokens, amortized across every checkpoint the
        stage validates — the same once-per-lifetime deal as the
        CandidateMap itself.
        """
        rows = np.unique(cand_idx[cand_idx >= 0])
        rows = rows[rows < store.n_texts]
        if not rows.size or not store.n_chunks:
            return None
        row_mask = np.zeros((store.n_chunks, store.chunk), bool)
        row_mask[rows // store.chunk, rows % store.chunk] = True
        surviving = int((row_mask.any(axis=1)).sum())
        n_compact = -(-int(rows.size) // store.chunk)
        if n_compact * 2 > surviving:
            return None
        L = store.tokens.shape[2]
        flat_t = store.tokens.reshape(store.n_chunks * store.chunk, L)
        flat_m = store.mask.reshape(store.n_chunks * store.chunk, L)
        toks = np.zeros((n_compact, store.chunk, L), np.int32)
        mask = np.zeros((n_compact, store.chunk, L), bool)
        toks.reshape(-1, L)[:rows.size] = flat_t[rows]   # memmap-safe copy
        mask.reshape(-1, L)[:rows.size] = flat_m[rows]
        compact = TokenStore(tokens=toks, mask=mask, chunk=store.chunk,
                             n_texts=int(rows.size))
        remapped = np.where(
            cand_idx >= 0,
            np.searchsorted(rows, np.clip(cand_idx, 0, None))
            .astype(np.int32),
            np.int32(-1))
        return np.asarray(remapped, np.int32), compact

    def wants_chunk(self, ci: int) -> bool:
        """False for chunks holding no candidate rows — the engine neither
        stages nor encodes them (a skipped chunk cannot write any slot, so
        skipping preserves bit-for-bit parity)."""
        return self.cmap is None or self.cmap.has_candidates(ci)

    def _row_mask(self, ci: int, chunk: int) -> jnp.ndarray:
        """Device-cached (chunk,) membership mask for chunk ``ci`` (all-True
        when the stage was built without a store)."""
        key = ci if self.cmap is not None else -1
        m = self._row_masks.get(key)
        if m is None:
            host = self.cmap.row_mask[ci] if self.cmap is not None \
                else np.ones((chunk,), bool)
            m = self._place_mask(host)
            self._row_masks[key] = m
        return m

    def _place_mask(self, host: np.ndarray) -> jnp.ndarray:
        return jnp.asarray(host)

    def init(self, q_emb):
        Q = q_emb.shape[0]
        return jnp.full((Q, self.cand_idx.shape[1]), -jnp.inf, jnp.float32)

    def step(self, params, q_emb, carry, toks, mask, base, n_valid):
        ci = base // (self.cmap.chunk if self.cmap is not None
                      else max(toks.shape[0], 1))
        return self._fused(params, q_emb, carry, self.cand_idx, toks, mask,
                           self._row_mask(ci, toks.shape[0]),
                           jnp.asarray(base, jnp.int32),
                           jnp.asarray(n_valid, jnp.int32))

    def finalize(self, carry):
        return rank_candidates(self.query_ids, np.asarray(carry), self.cands,
                               k=self.k)


class ShardedStreamRerankStage(StreamRerankStage):
    """Rerank / average-rank modes on the validator mesh — rerank as a
    first-class mesh citizen, mirroring :class:`ShardedStreamTopKStage`.

    Each chunk's rows are sharded over ``axis_names`` (the engine stages
    them pre-sharded via ``input_sharding``, like the retrieval stage);
    every shard encodes its rows under the one compiled ``shard_map`` step,
    scores only its candidate-member rows, and gathers them into its local
    view of the replicated ``(Q, Cmax)`` slot carry.  Because every slot
    names one global corpus row — which lives on exactly one shard of one
    chunk — the cross-shard fold is the slot-aligned degenerate case of the
    retrieval stage's hierarchical all-gather merge: an elementwise max per
    mesh axis, innermost first (:func:`~repro.core.retrieval.
    _hierarchical_slot_max`), which re-replicates the carry.  The slot map
    and query matrix stay replicated; collective volume per chunk is
    O(axes x Q x Cmax), independent of corpus size.  Carry, finalize, and
    chunk-skipping are inherited — so sharded runs are bit-for-bit the
    single-device runs (tests/test_rerank_parity.py).
    """

    name = "rerank_sharded"

    def __init__(self, encode_fn: Callable, mesh, *, k: int,
                 query_ids: List[str], doc_ids: List[str],
                 per_query: Dict[str, List[str]],
                 store: Optional[TokenStore] = None, axis_names=None,
                 score_dtype: str = "f32", compact: bool = False):
        super().__init__(encode_fn, k=k, query_ids=query_ids,
                         doc_ids=doc_ids, per_query=per_query, store=store,
                         score_dtype=score_dtype, compact=compact)
        axis_names = tuple(axis_names or mesh.axis_names)
        ax = axis_names[0] if len(axis_names) == 1 else axis_names

        def local(params, q_emb, cand_s, cand_idx, toks, mask, row_mask,
                  base, n_valid):
            emb = encode_fn(params, toks, mask)           # (rows, D) local
            rows = toks.shape[0]
            shard = jax.lax.axis_index(ax)
            # per-row quantization: shard-local quantized scores equal the
            # single-device stage's slice (see ShardedStreamTopKStage)
            if score_dtype == "f32":
                s = (q_emb @ emb.T).astype(jnp.float32)   # (Q, rows) local
            else:
                s = chunk_scores(q_emb, emb, score_dtype)  # (Q, rows) local
            col = shard * rows + jnp.arange(rows, dtype=jnp.int32)
            s = jnp.where((row_mask & (col < n_valid))[None, :], s, -jnp.inf)
            pos = cand_idx - base - shard * rows          # shard-local slot
            hit = (cand_idx >= 0) & (cand_idx - base < n_valid) \
                & (pos >= 0) & (pos < rows)
            g = jnp.take_along_axis(s, jnp.clip(pos, 0, rows - 1), axis=1)
            part = jnp.where(hit, g, cand_s)
            # slot-aligned hierarchical merge: each slot's row lives on one
            # shard, so max(part over shards) == the written score where a
            # shard hit and the (replicated) carry everywhere else.
            return _hierarchical_slot_max(part, axis_names)

        spec_rows = P(ax)
        # check=False: the carry enters replicated, is device-varying after
        # the per-shard slot writes, and is re-replicated by the final merge
        # — the same legal pattern ShardedStreamTopKStage documents.
        self._fused = jax.jit(compat.shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P(), P(), spec_rows, spec_rows, spec_rows,
                      P(), P()),
            out_specs=P(), check=False), donate_argnums=_donate(2,))
        from repro.distributed.sharding import replicated_sharding, \
            rows_sharding
        # staged token chunks (and the per-chunk row masks) land pre-sharded;
        # the slot map is placed replicated once so dispatch does no
        # re-layout on any step.
        self.input_sharding = rows_sharding(mesh, axis_names)
        self.cand_idx = jax.device_put(self.cand_idx,
                                       replicated_sharding(mesh))

    def _place_mask(self, host: np.ndarray) -> jnp.ndarray:
        return jax.device_put(host, self.input_sharding)


# ---------------------------------------------------------------------------
# Registry wiring: modes route to impls route to stage names; stage names
# resolve to normalized factories.  Third-party stages plug in with
# @register_stage("name") plus a @register_mode / @register_impl route that
# returns that name — no edits to make_stage required.
# ---------------------------------------------------------------------------


@register_impl("xla")
def _route_impl_xla(*, mesh=None) -> str:
    return "topk_sharded" if mesh is not None else "topk_xla"


@register_impl("pallas")
def _route_impl_pallas(*, mesh=None) -> str:
    # the Pallas chunk-carry kernel is single-device; a mesh does not
    # override it (mesh users pick impl="xla", the shard_map path)
    return "topk_pallas"


@register_mode("retrieval")
def _route_mode_retrieval(*, impl: str, mesh=None, per_query=None) -> str:
    return IMPLS.get(impl)(mesh=mesh)


@register_mode("rerank")
@register_mode("average_rank")
def _route_mode_rerank(*, impl: str, mesh=None, per_query=None) -> str:
    if not per_query:           # no candidate lists -> plain retrieval path
        return IMPLS.get(impl)(mesh=mesh)
    return "rerank_sharded" if mesh is not None else "rerank"


@register_stage("topk_xla")
def _stage_topk_xla(encode_fn, *, k, query_ids, doc_ids, scan_window=8,
                    mesh=None, per_query=None, store=None,
                    score_dtype="f32", rerank_compact=False) -> Stage:
    return StreamTopKStage(encode_fn, k=k, query_ids=query_ids,
                           doc_ids=doc_ids, window=scan_window,
                           score_dtype=score_dtype)


@register_stage("topk_pallas")
def _stage_topk_pallas(encode_fn, *, k, query_ids, doc_ids, scan_window=8,
                       mesh=None, per_query=None, store=None,
                       score_dtype="f32", rerank_compact=False) -> Stage:
    return PallasStreamTopKStage(encode_fn, k=k, query_ids=query_ids,
                                 doc_ids=doc_ids, score_dtype=score_dtype)


@register_stage("topk_sharded")
def _stage_topk_sharded(encode_fn, *, k, query_ids, doc_ids, scan_window=8,
                        mesh=None, per_query=None, store=None,
                        score_dtype="f32", rerank_compact=False) -> Stage:
    return ShardedStreamTopKStage(encode_fn, mesh, k=k, query_ids=query_ids,
                                  doc_ids=doc_ids, score_dtype=score_dtype)


@register_stage("rerank")
def _stage_rerank(encode_fn, *, k, query_ids, doc_ids, scan_window=8,
                  mesh=None, per_query=None, store=None,
                  score_dtype="f32", rerank_compact=True) -> Stage:
    return StreamRerankStage(encode_fn, k=max(k, 1000), query_ids=query_ids,
                             doc_ids=doc_ids, per_query=per_query,
                             store=store, score_dtype=score_dtype,
                             compact=rerank_compact)


@register_stage("rerank_sharded")
def _stage_rerank_sharded(encode_fn, *, k, query_ids, doc_ids, scan_window=8,
                          mesh=None, per_query=None, store=None,
                          score_dtype="f32", rerank_compact=True) -> Stage:
    return ShardedStreamRerankStage(encode_fn, mesh, k=max(k, 1000),
                                    query_ids=query_ids, doc_ids=doc_ids,
                                    per_query=per_query, store=store,
                                    score_dtype=score_dtype,
                                    compact=rerank_compact)


def make_stage(encode_fn: Callable, *, mode: str, impl: str, k: int,
               query_ids: List[str], doc_ids: List[str],
               per_query: Optional[Dict[str, List[str]]] = None,
               mesh=None, scan_window: int = 8,
               store: Optional[TokenStore] = None,
               score_dtype: str = "f32",
               rerank_compact: bool = True) -> Stage:
    """Route (mode, impl, mesh) to a Stage — the single dispatch point every
    validation path goes through, now resolved through the component
    registries: the ``mode`` route picks a stage name (consulting the
    ``impl`` route for the retrieval family), and the name resolves to a
    registered stage factory.  ``(mode="rerank", mesh=...)`` just works:
    rerank shards over the validator mesh exactly like retrieval does.
    ``store`` (the corpus TokenStore) lets the rerank stages precompute
    per-chunk candidate membership for chunk skipping (and, with
    ``rerank_compact``, pack sparse candidate rows into dense
    pseudo-chunks).  ``score_dtype`` picks the scoring precision
    (f32/bf16/int8) every stage family threads through
    :mod:`repro.core.precision`.  Unknown mode/impl/stage names raise
    listing the registered alternatives."""
    name = MODES.get(mode)(impl=impl, mesh=mesh, per_query=per_query)
    return STAGES.get(name)(encode_fn, k=k, query_ids=query_ids,
                            doc_ids=doc_ids, per_query=per_query, mesh=mesh,
                            scan_window=scan_window, store=store,
                            score_dtype=score_dtype,
                            rerank_compact=rerank_compact)


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------


class StreamingEngine:
    """Drive a Stage over a TokenStore: the full validation data path with
    peak embedding memory O(chunk x D + Q x k) — and, with an mmap-backed
    store, peak host token memory O(staging_depth x window x chunk x L).

    ``staging_depth`` is the prefetch depth of :func:`staged_batches`:
    2 (default) is the classic double buffer; deeper pipelines (3, 4, ...)
    keep that many batches' ``device_put`` in flight, which hides the
    longer/burstier latencies of remote-storage TokenStores (S3/GCS-backed
    mmap) at a host-memory cost of O(depth x window x chunk x L).  Stages
    exposing ``wants_chunk`` (the rerank stages, via their candidate maps)
    prune the schedule BEFORE staging, so skipped chunks are never read off
    the store backing at all.
    """

    name = "streaming"

    def __init__(self, spec, doc_store: TokenStore, query_store: TokenStore,
                 stage: Stage, *, staging: str = "double_buffered",
                 staging_depth: int = 2, query_mesh=None,
                 query_axis_names=None, telemetry=None):
        if staging not in ("double_buffered", "sync"):
            raise ValueError(f"unknown staging {staging!r} "
                             "(expected 'double_buffered' or 'sync')")
        if staging_depth < 1:
            raise ValueError(f"staging_depth must be >= 1, got "
                             f"{staging_depth!r}")
        self.spec = spec
        self.doc_store = doc_store
        self.query_store = query_store
        self.stage = stage
        self.staging = staging
        self.staging_depth = staging_depth
        self.query_mesh = query_mesh
        self.query_axis_names = query_axis_names
        # nullable repro.obs.Telemetry: staged/encoded spans + per-chunk
        # step-time and staging idle-gap metrics.  Observation only — the
        # schedule, staging, and scoring math are identical with or without
        # it (the timed next() below is the same next() zip() would issue).
        self.telemetry = telemetry

    @property
    def score_dtype(self) -> str:
        """Scoring precision of the wired stage — surfaced so the suite can
        ledger it alongside the engine name."""
        return getattr(self.stage, "score_dtype", "f32")

    def run(self, params) -> Tuple[Run, Scores, Dict[str, float]]:
        tel = self.telemetry
        t0 = time.time()
        m0 = time.monotonic() if tel is not None else 0.0
        q_emb = encode_store(self.spec.encode_query, params, self.query_store,
                             mesh=self.query_mesh,
                             axis_names=self.query_axis_names)
        q_emb.block_until_ready()
        t_query = time.time() - t0
        if tel is not None:
            tel.record("encoded", m0, t_query, role="query")

        t0 = time.time()
        # a compacting rerank stage re-packed the candidate rows into its
        # own dense pseudo-chunk store; stream that instead of the corpus
        store = getattr(self.stage, "store_override", None) or self.doc_store
        carry = self.stage.init(q_emb)
        window = getattr(self.stage, "window", 1)
        use_window = window > 1 and hasattr(self.stage, "step_window")
        schedule = plan_schedule(store.n_chunks, window if use_window else 1)
        # candidate-aware pruning: a rerank stage knows (from its
        # CandidateMap) which chunks hold candidate rows; the rest are
        # dropped from the schedule before staging ever reads them.
        wants = getattr(self.stage, "wants_chunk", None)
        if wants is not None:
            schedule = [(ci, w) for ci, w in schedule
                        if w > 1 or wants(ci)]
        # prefetch pipeline: batch i+depth-1's device_put is already in
        # flight when batch i's fused step dispatches (depth=2 is the double
        # buffer; sync staging forces depth=1 — copy, then compute — kept
        # for A/B benchmarking).
        batches = staged_batches(
            store, schedule,
            depth=1 if self.staging == "sync" else self.staging_depth,
            sharding=getattr(self.stage, "input_sharding", None))
        # explicit next() instead of zip() so telemetry can time the
        # staging wait (prefetch idle gap) separately from the fused step
        # dispatch; the iteration order and count are identical to the old
        # zip(schedule, batches) loop.
        m_stream = time.monotonic() if tel is not None else 0.0
        t_wait = 0.0
        step_hist = tel.metrics.histogram("engine.chunk_step_s") \
            if tel is not None else None
        for ci, w in schedule:
            if tel is None:
                toks, mask = next(batches)
            else:
                m0 = time.monotonic()
                toks, mask = next(batches)
                t_wait += time.monotonic() - m0
                m1 = time.monotonic()
            if w > 1:
                bases = store.chunk * np.arange(ci, ci + w, dtype=np.int32)
                n_valids = np.asarray([store.rows_valid(j) for j in
                                       range(ci, ci + w)], np.int32)
                carry = self.stage.step_window(params, q_emb, carry, toks,
                                               mask, bases, n_valids)
            else:
                carry = self.stage.step(params, q_emb, carry, toks, mask,
                                        store.chunk * ci,
                                        store.rows_valid(ci))
            if tel is not None:
                step_hist.observe(time.monotonic() - m1)
        jax.block_until_ready(carry)
        t_stream = time.time() - t0
        if tel is not None:
            stream_total = max(time.monotonic() - m_stream, 1e-12)
            idle_ratio = t_wait / stream_total
            # aggregate staging-wait span for the run (duration = summed
            # next() waits, not a contiguous interval — see obs.trace docs)
            tel.record("staged", m_stream, t_wait, n_batches=len(schedule),
                       staging=self.staging, idle_ratio=idle_ratio)
            tel.metrics.histogram("engine.staging_wait_s").observe(t_wait)
            tel.metrics.histogram("engine.staging_idle_ratio").observe(
                idle_ratio)

        t0 = time.time()
        run, scores = self.stage.finalize(carry)
        t_final = time.time() - t0
        # key names kept from the legacy path: the ledger/CSV schema is
        # stable across engines.  encode_corpus_s is the fused loop (encode
        # AND fold — they are one program now); retrieve_s is the host-side
        # finalize only.
        timings = {"encode_corpus_s": t_stream, "encode_query_s": t_query,
                   "retrieve_s": t_final,
                   "total_s": t_query + t_stream + t_final}
        return run, scores, timings


class MaterializedEngine:
    """The legacy path — encode everything, then retrieve — behind the same
    engine interface.  Kept for A/B benchmarks and as the fallback for
    encoders that cannot stream (none known)."""

    name = "materialized"

    def __init__(self, spec, doc_texts: List[Tokens], query_texts: List[Tokens],
                 *, mode: str, k: int, impl: str, batch_size: int,
                 query_ids: List[str], doc_ids: List[str],
                 per_query: Optional[Dict[str, List[str]]] = None, mesh=None,
                 rerank_block: Optional[int] = None,
                 score_dtype: str = "f32", telemetry=None):
        self.telemetry = telemetry
        self.spec = spec
        self.doc_texts = doc_texts
        self.query_texts = query_texts
        self.mode = mode
        self.k = k
        self.impl = impl
        self.batch_size = batch_size
        self.query_ids = query_ids
        self.doc_ids = doc_ids
        self.per_query = per_query
        self.mesh = mesh
        # queries per rerank candidate-gather block (None = auto from the
        # rerank_run memory budget); see rerank_run's docstring.
        self.rerank_block = rerank_block
        self.score_dtype = validate_score_dtype(score_dtype)

    def run(self, params) -> Tuple[Run, Scores, Dict[str, float]]:
        tel = self.telemetry
        t0 = time.time()
        m0 = time.monotonic() if tel is not None else 0.0
        c_emb, _ = encode_texts(self.spec.encode_passage, params,
                                self.doc_texts, max_len=self.spec.p_max_len,
                                batch_size=self.batch_size)
        if self.score_dtype == "bf16":
            # the resident (N, D) matrix — THE memory cost this engine pays
            # that streaming doesn't — shrinks 2x; scoring casts back per
            # block with f32 accumulation.  int8 keeps the f32 matrix and
            # quantizes at score time (value-level parity with streaming
            # beats resident shrink for the A/B baseline engine).
            c_emb = np.asarray(jnp.asarray(c_emb, jnp.bfloat16))
        t_corpus = time.time() - t0
        if tel is not None:
            tel.record("encoded", m0, t_corpus, role="corpus")
        t0 = time.time()
        m0 = time.monotonic() if tel is not None else 0.0
        q_emb, _ = encode_texts(self.spec.encode_query, params,
                                self.query_texts, max_len=self.spec.q_max_len,
                                batch_size=self.batch_size)
        t_query = time.time() - t0
        if tel is not None:
            tel.record("encoded", m0, t_query, role="query")

        t0 = time.time()
        if self.mode in ("rerank", "average_rank") and self.per_query:
            run, scores = rerank_run(self.query_ids, q_emb, self.doc_ids,
                                     c_emb, self.per_query,
                                     k=max(self.k, 1000),
                                     q_block=self.rerank_block,
                                     score_dtype=self.score_dtype)
        else:
            run, scores = retrieve_run(self.query_ids, q_emb, self.doc_ids,
                                       c_emb, k=self.k, impl=self.impl,
                                       mesh=self.mesh,
                                       score_dtype=self.score_dtype)
        t_retrieve = time.time() - t0
        timings = {"encode_corpus_s": t_corpus, "encode_query_s": t_query,
                   "retrieve_s": t_retrieve,
                   "total_s": t_corpus + t_query + t_retrieve}
        return run, scores, timings


@dataclasses.dataclass
class ValidationStore:
    """The sampled data one validation task runs over — the single "store"
    argument of :func:`make_engine`.

    Built by :class:`repro.core.suite.ValidationSuite` (one per task, after
    the task's sampler ran) or by any caller that already knows its subset.
    ``doc_store``/``query_store`` are optional pre-built
    :class:`TokenStore`\\ s: the suite fills ``doc_store`` from its shared
    cache so tasks over the same sampled corpus pad it exactly once; when
    absent, the engine factory builds them from the texts.
    """

    query_ids: List[str]
    query_texts: List[Tokens]
    doc_ids: List[str]
    doc_texts: List[Tokens]
    per_query: Optional[Dict[str, List[str]]] = None
    doc_store: Optional[TokenStore] = None
    query_store: Optional[TokenStore] = None


def chunk_geometry(vcfg, n_docs: int, mesh=None) -> Tuple[int, int]:
    """(corpus chunk rows, query chunk rows) for a config.  ``chunk_size``
    defaults to ``batch_size`` (legacy-equivalent encode granularity); with
    a mesh both are rounded up to a multiple of the shard count so every
    shard sees equal fixed-shape rows — for EVERY mode: retrieval, rerank,
    and average_rank all shard through the same ``make_stage`` dispatch.
    Shared by the engine factories and the suite's TokenStore cache (two
    tasks share a store only when this geometry matches)."""
    chunk = vcfg.chunk_size or vcfg.batch_size
    chunk = max(1, min(chunk, max(n_docs, 1)))
    q_chunk = max(1, vcfg.batch_size)
    if mesh is not None:
        n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        chunk = -(-chunk // n_shards) * n_shards
        # query chunks shard over the same mesh: equal fixed-shape rows too
        q_chunk = -(-q_chunk // n_shards) * n_shards
    return chunk, q_chunk


def doc_cache_dir(mmap_dir: Optional[str], index: int = 0) -> Optional[str]:
    """Cache subdirectory for the ``index``-th distinct corpus TokenStore
    under ``mmap_dir``.  Index 0 keeps the historical ``corpus_tokens`` name
    (single-task runs and their existing caches); later stores (a multi-task
    suite over several corpora) get numbered siblings."""
    if not mmap_dir:
        return None
    name = "corpus_tokens" if index == 0 else f"corpus_tokens_{index}"
    return os.path.join(mmap_dir, name)


@register_engine("streaming")
def make_streaming_engine(spec, store: ValidationStore, vcfg):
    """The default fused encode→top-k data path (see module docstring)."""
    mesh = vcfg.mesh
    chunk, q_chunk = chunk_geometry(vcfg, len(store.doc_texts), mesh)
    tel = getattr(vcfg, "telemetry", None)
    doc_store = store.doc_store
    if doc_store is None:
        if vcfg.token_backing == "mmap" and not vcfg.mmap_dir:
            raise ValueError("token_backing='mmap' needs mmap_dir")
        if tel is not None:
            t0 = time.monotonic()
        doc_store = TokenStore.build(
            store.doc_texts, max_len=spec.p_max_len, chunk=chunk,
            backing=vcfg.token_backing,
            cache_dir=doc_cache_dir(vcfg.mmap_dir),
            fingerprint=vcfg.token_fingerprint)
        if tel is not None:
            tel.record("store_build", t0, time.monotonic() - t0,
                       n_docs=len(store.doc_texts),
                       backing=vcfg.token_backing)
    query_store = store.query_store
    if query_store is None:
        query_store = TokenStore.build(store.query_texts,
                                       max_len=spec.q_max_len, chunk=q_chunk)
    stage = make_stage(spec.encode_passage, mode=vcfg.mode, impl=vcfg.impl,
                       k=vcfg.k, query_ids=store.query_ids,
                       doc_ids=store.doc_ids, per_query=store.per_query,
                       mesh=mesh, scan_window=vcfg.scan_window,
                       store=doc_store,
                       score_dtype=getattr(vcfg, "score_dtype", "f32"),
                       rerank_compact=getattr(vcfg, "rerank_compact", True))
    return StreamingEngine(spec, doc_store, query_store, stage,
                           staging=vcfg.staging,
                           staging_depth=vcfg.staging_depth, query_mesh=mesh,
                           telemetry=tel)


# declares that this factory consumes ValidationStore.doc_store when one is
# supplied: the ValidationSuite routes the corpus TokenStore through its
# shared cache for every factory carrying this attribute, so corpus-sharing
# tasks pad the store once.  Third-party engines opt in the same way.
make_streaming_engine.uses_token_stores = True


@register_engine("materialized")
def make_materialized_engine(spec, store: ValidationStore, vcfg):
    """The legacy encode-all-then-retrieve path, for A/B benchmarking."""
    return MaterializedEngine(spec, store.doc_texts, store.query_texts,
                              mode=vcfg.mode, k=vcfg.k, impl=vcfg.impl,
                              batch_size=vcfg.batch_size,
                              query_ids=store.query_ids,
                              doc_ids=store.doc_ids,
                              per_query=store.per_query, mesh=vcfg.mesh,
                              rerank_block=vcfg.rerank_block,
                              score_dtype=getattr(vcfg, "score_dtype",
                                                  "f32"),
                              telemetry=getattr(vcfg, "telemetry", None))


def make_engine(spec, store: ValidationStore, vcfg):
    """Build the engine a :class:`~repro.core.suite.ValidationConfig` asks
    for.  The whole config travels intact — engine factories read the fields
    they care about (``engine``, ``mode``, ``impl``, ``k``, staging/backing
    knobs, ``mesh``) instead of every call site exploding 15 kwargs.  The
    ``engine`` name resolves through the :data:`~repro.core.registry.
    ENGINES` registry, so third-party engines registered with
    ``@register_engine`` are constructed exactly like the built-ins;
    unknown engine/mode/impl names raise listing the registered
    alternatives."""
    MODES.get(vcfg.mode)            # fail fast, with alternatives, even for
    IMPLS.get(vcfg.impl)            # engines that defer stage construction
    return ENGINES.get(vcfg.engine)(spec, store, vcfg)
