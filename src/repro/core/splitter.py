"""The paper's ``python -m asyncval.splitter`` CLI (§3).

    python -m repro.core.splitter \\
        --candidate_dir corpus_dir --run_file bm25.trec \\
        --qrel_file qrels.txt --output_dir subset_dir --depth 100

Keeps the union over queries of the run's top-``depth`` passages plus all
gold passages, written as pre-tokenized JSONL ready for repro.core.cli.
"""

from __future__ import annotations

import argparse
import glob
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.core.splitter")
    ap.add_argument("--candidate_dir", required=True)
    ap.add_argument("--run_file", required=True)
    ap.add_argument("--qrel_file", required=True)
    ap.add_argument("--output_dir", required=True)
    ap.add_argument("--depth", type=int, required=True)
    args = ap.parse_args(argv)

    from repro.core.metrics import read_trec_qrels, read_trec_run
    from repro.core.samplers import RunFileTopK, write_subset_jsonl
    from repro.data.corpus import read_jsonl

    corpus = {}
    for p in sorted(glob.glob(os.path.join(args.candidate_dir, "*.json*"))):
        corpus.update(read_jsonl(p))
    run = read_trec_run(args.run_file)
    qrels = read_trec_qrels(args.qrel_file)

    subset = RunFileTopK(depth=args.depth).sample(list(corpus), run, qrels)
    os.makedirs(args.output_dir, exist_ok=True)
    out = os.path.join(args.output_dir, f"subset_top{args.depth}.jsonl")
    write_subset_jsonl(subset, corpus, out)
    print(f"[splitter] {len(corpus)} passages -> {subset.size} "
          f"(depth={args.depth}) -> {out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
