"""Corpus subset sampling strategies (the paper's §3 splitter + §2 variants).

Given a baseline run file (e.g. BM25 or a strong DR) and TREC qrels, keep
only the passages a validation query could plausibly retrieve — the paper
shows depth=100 cuts MS MARCO validation from ~2 h to ~10 min while
preserving the checkpoint-ranking trend (Figure 2).

Strategies:
  * FullCorpus        — no subsetting (the fidelity reference).
  * RunFileTopK       — paper's splitter: union over queries of the run's
                        top-``depth`` passages, plus all gold passages.
  * QrelPool          — DPR average-rank pool: golds + a small per-query pool.
  * RandomSubset      — control for the fidelity study.
  * RerankTopK        — RocketQA-style: per-query candidate lists (re-rank
                        validation instead of full retrieval).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.metrics import Qrels
from repro.core.registry import register_sampler


@dataclasses.dataclass
class SubsetResult:
    """Either a global corpus subset or per-query candidates (rerank mode)."""
    doc_ids: List[str]
    per_query: Optional[Dict[str, List[str]]] = None

    @property
    def size(self) -> int:
        return len(self.doc_ids)


def _gold_ids(qrels: Qrels) -> set:
    out = set()
    for docs in qrels.values():
        out.update(d for d, g in docs.items() if g > 0)
    return out


class FullCorpus:
    name = "full"

    def sample(self, corpus_ids: Sequence[str], run=None, qrels=None
               ) -> SubsetResult:
        return SubsetResult(doc_ids=list(corpus_ids))


@dataclasses.dataclass
class RunFileTopK:
    """The paper's ``asyncval.splitter``: --run_file + --qrel_file + --depth."""
    depth: int

    @property
    def name(self):
        return f"run_top{self.depth}"

    def sample(self, corpus_ids: Sequence[str], run: Dict[str, List[tuple]],
               qrels: Qrels) -> SubsetResult:
        keep = _gold_ids(qrels)
        for qid, ranked in run.items():
            keep.update(d for d, _ in ranked[:self.depth])
        known = set(corpus_ids)
        return SubsetResult(doc_ids=sorted(keep & known))


@dataclasses.dataclass
class QrelPool:
    """DPR §2 average-rank pool: golds + per-query top-``pool`` candidates.
    Validation metric should be AverageRank over this pool."""
    pool: int = 30

    @property
    def name(self):
        return f"qrel_pool{self.pool}"

    def sample(self, corpus_ids: Sequence[str], run: Dict[str, List[tuple]],
               qrels: Qrels) -> SubsetResult:
        keep = _gold_ids(qrels)
        per_query: Dict[str, List[str]] = {}
        for qid, ranked in (run or {}).items():
            cands = [d for d, _ in ranked[:self.pool]]
            golds = [d for d, g in qrels.get(qid, {}).items() if g > 0]
            per_query[qid] = list(dict.fromkeys(golds + cands))
            keep.update(per_query[qid])
        known = set(corpus_ids)
        return SubsetResult(doc_ids=sorted(keep & known), per_query=per_query)


@dataclasses.dataclass
class RandomSubset:
    n: int
    seed: int = 0

    @property
    def name(self):
        return f"random{self.n}"

    def sample(self, corpus_ids: Sequence[str], run=None, qrels: Qrels = None
               ) -> SubsetResult:
        import random
        r = random.Random(self.seed)
        ids = list(corpus_ids)
        picked = set(r.sample(ids, min(self.n, len(ids))))
        if qrels:
            picked |= _gold_ids(qrels) & set(ids)
        return SubsetResult(doc_ids=sorted(picked))


@dataclasses.dataclass
class RerankTopK:
    """RocketQA-style re-rank validation: per-query top-``depth`` candidates
    (plus golds) — only these are encoded and scored for that query."""
    depth: int

    @property
    def name(self):
        return f"rerank_top{self.depth}"

    def sample(self, corpus_ids: Sequence[str], run: Dict[str, List[tuple]],
               qrels: Qrels) -> SubsetResult:
        known = set(corpus_ids)
        per_query: Dict[str, List[str]] = {}
        union = set()
        for qid, ranked in run.items():
            golds = [d for d, g in qrels.get(qid, {}).items() if g > 0]
            cands = [d for d, _ in ranked[:self.depth]]
            merged = [d for d in dict.fromkeys(golds + cands) if d in known]
            per_query[qid] = merged
            union.update(merged)
        return SubsetResult(doc_ids=sorted(union), per_query=per_query)


# ---------------------------------------------------------------------------
# Registry wiring: the sampler names the CLI / ValidationTask accept.  Each
# factory takes the subset ``depth`` (falling back to the strategy's
# historical default when 0) so `--sampler NAME --depth D` and
# `ValidationTask(sampler="NAME", sampler_depth=D)` both resolve here.
# Third-party samplers plug in with @register_sampler("name").
# ---------------------------------------------------------------------------


@register_sampler("full")
def _make_full(depth: int = 0) -> FullCorpus:
    return FullCorpus()


@register_sampler("run_topk")
def _make_run_topk(depth: int = 0) -> RunFileTopK:
    return RunFileTopK(depth=depth or 100)


@register_sampler("qrel_pool")
def _make_qrel_pool(depth: int = 0) -> QrelPool:
    return QrelPool(pool=depth or 30)


@register_sampler("random")
def _make_random(depth: int = 0) -> RandomSubset:
    return RandomSubset(n=depth or 100)


@register_sampler("rerank_topk")
def _make_rerank_topk(depth: int = 0) -> RerankTopK:
    return RerankTopK(depth=depth or 100)


def write_subset_jsonl(subset: SubsetResult, corpus: dict, out_path: str):
    """The splitter CLI's output: a pre-tokenized corpus JSONL restricted to
    the subset (paper §3 --output_dir)."""
    import json
    with open(out_path, "w") as f:
        for did in subset.doc_ids:
            f.write(json.dumps({"text_id": did, "text": corpus[did]}) + "\n")
