"""Validation-fidelity analysis (paper Figure 2 left, quantified).

The paper's observation: subset validation overestimates MRR@10 but
preserves the *trend* across checkpoints; subsets induced by stronger
baselines track the full-corpus curve better.  These statistics quantify
that: rank correlation of checkpoint orderings, best-checkpoint agreement,
and the overestimation bias.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence


def pearson(a: Sequence[float], b: Sequence[float]) -> float:
    n = len(a)
    ma, mb = sum(a) / n, sum(b) / n
    cov = sum((x - ma) * (y - mb) for x, y in zip(a, b))
    va = math.sqrt(sum((x - ma) ** 2 for x in a))
    vb = math.sqrt(sum((y - mb) ** 2 for y in b))
    return cov / (va * vb) if va * vb > 0 else 0.0


def _ranks(xs: Sequence[float]) -> List[float]:
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        avg = (i + j) / 2 + 1
        for k in range(i, j + 1):
            ranks[order[k]] = avg
        i = j + 1
    return ranks


def spearman(a: Sequence[float], b: Sequence[float]) -> float:
    return pearson(_ranks(a), _ranks(b))


def kendall_tau(a: Sequence[float], b: Sequence[float]) -> float:
    n = len(a)
    conc = disc = 0
    for i in range(n):
        for j in range(i + 1, n):
            s = (a[i] - a[j]) * (b[i] - b[j])
            if s > 0:
                conc += 1
            elif s < 0:
                disc += 1
    total = n * (n - 1) / 2
    return (conc - disc) / total if total else 0.0


def best_checkpoint_agreement(reference: Sequence[float],
                              estimate: Sequence[float],
                              higher_is_better: bool = True) -> bool:
    """Does the subset pick the same argbest checkpoint as the full corpus?"""
    pick = max if higher_is_better else min
    ref_best = pick(range(len(reference)), key=lambda i: reference[i])
    est_best = pick(range(len(estimate)), key=lambda i: estimate[i])
    return ref_best == est_best


def overestimation(reference: Sequence[float],
                   estimate: Sequence[float]) -> Dict[str, float]:
    deltas = [e - r for r, e in zip(reference, estimate)]
    return {"mean_delta": sum(deltas) / len(deltas),
            "max_delta": max(deltas), "min_delta": min(deltas),
            "always_overestimates": float(all(d >= 0 for d in deltas))}


def fidelity_report(reference: Sequence[float], estimate: Sequence[float],
                    higher_is_better: bool = True) -> Dict[str, float]:
    return {
        "pearson": pearson(reference, estimate),
        "spearman": spearman(reference, estimate),
        "kendall_tau": kendall_tau(reference, estimate),
        "best_ckpt_agreement": float(best_checkpoint_agreement(
            reference, estimate, higher_is_better)),
        **overestimation(reference, estimate),
    }
