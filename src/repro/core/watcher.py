"""Checkpoint watcher — the paper's "listen to --ckpts_dir" loop, hardened.

Only directories carrying the COMMIT marker are visible (two-phase commit,
see ``repro.ckpt.checkpoint``), so a validator polling while the trainer is
mid-write can never read a torn checkpoint.

Scheduling policies (beyond-paper, needed when validation is slower than the
checkpoint cadence at scale):
  * FIFO          — the paper's behaviour: validate every checkpoint in order.
  * LATEST_FIRST  — always jump to the newest checkpoint, skipping stale ones
                    (bounds validation staleness; skipped steps are recorded).
  * STRIDE(k)     — validate every k-th checkpoint.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Set

from repro.ckpt import checkpoint as ckpt


@dataclasses.dataclass
class Policy:
    kind: str = "fifo"            # fifo | latest_first | stride
    stride: int = 1

    def select(self, pending: List[int]) -> List[int]:
        """Order/filter newly discovered steps for validation."""
        if not pending:
            return []
        if self.kind == "fifo":
            return sorted(pending)
        if self.kind == "latest_first":
            return [max(pending)]
        if self.kind == "stride":
            return sorted(s for s in pending if (s // max(self.stride, 1))
                          * self.stride == s or s % self.stride == 0)
        raise ValueError(self.kind)


class CheckpointWatcher:
    def __init__(self, root: str, *, policy: Optional[Policy] = None,
                 skip_existing: bool = False):
        self.root = root
        self.policy = policy or Policy()
        self._seen: Set[int] = set()
        if skip_existing:
            self._seen.update(ckpt.list_steps(root))

    def poll(self) -> List[int]:
        """New committed steps since the last poll, policy-ordered."""
        steps = [s for s in ckpt.list_steps(self.root) if s not in self._seen]
        chosen = self.policy.select(steps)
        # under latest_first, skipped (stale) steps are marked seen too
        if self.policy.kind == "latest_first":
            self._seen.update(steps)
        else:
            self._seen.update(chosen)
        return chosen

    def mark_seen(self, step: int) -> None:
        self._seen.add(step)

    def requeue(self, step: int) -> None:
        """Make ``step`` visible to the next :meth:`poll` again.

        ``poll`` marks a step seen the moment it is *handed out*, before the
        caller knows whether validation succeeded — a checkpoint that fails
        (torn filesystem read, transient OOM) would otherwise be permanently
        swallowed.  The validator calls this on failure so the step is
        retried on a later poll."""
        self._seen.discard(step)
