"""Checkpoint watcher — the paper's "listen to --ckpts_dir" loop, hardened.

Only directories carrying the COMMIT marker are visible (two-phase commit,
see ``repro.ckpt.checkpoint``), so a validator polling while the trainer is
mid-write can never read a torn checkpoint.

Scheduling policies (beyond-paper, needed when validation is slower than the
checkpoint cadence at scale):
  * FIFO          — the paper's behaviour: validate every checkpoint in order.
  * LATEST_FIRST  — always jump to the newest checkpoint, skipping stale ones
                    (bounds validation staleness; skipped steps are recorded).
  * STRIDE(k)     — validate every k-th checkpoint.
  * BUDGET        — :class:`BudgetPolicy`: adapt the stride automatically
                    from observed validation latency vs checkpoint cadence
                    (queue depth), bounding staleness without hand-tuning.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional, Set

from repro.ckpt import checkpoint as ckpt
from repro.obs.metrics import MetricsRegistry

#: shared-registry instrument names (see repro.obs): the validator feeds
#: the latency EMA, the watcher feeds the cadence EMA, BudgetPolicy reads
#: both — one source of timing truth instead of private policy state.
VALIDATION_LATENCY_METRIC = "validate.latency_s"
CHECKPOINT_CADENCE_METRIC = "watcher.checkpoint_cadence_s"
DISCOVERY_LAG_METRIC = "watcher.discovery_lag_s"


@dataclasses.dataclass
class Policy:
    kind: str = "fifo"            # fifo | latest_first | stride
    stride: int = 1

    def select(self, pending: List[int]) -> List[int]:
        """Order/filter newly discovered steps for validation."""
        if not pending:
            return []
        if self.kind == "fifo":
            return sorted(pending)
        if self.kind == "latest_first":
            return [max(pending)]
        if self.kind == "stride":
            stride = max(self.stride, 1)
            return sorted(s for s in pending if s % stride == 0)
        raise ValueError(self.kind)

    # feedback hooks (no-ops here; BudgetPolicy adapts on them) -------------
    def observe_latency(self, seconds: float) -> None:
        """Called by the validator after each completed validation."""

    def observe_cadence(self, seconds: float) -> None:
        """Called by the watcher with the inter-arrival time of checkpoints."""


@dataclasses.dataclass
class BudgetPolicy(Policy):
    """Self-tuning stride: keep validation throughput within budget.

    Two coupled signals:
      * queue depth — more pending steps per poll than ``target_depth``
        means validation is falling behind the checkpoint cadence: double
        the stride (halve it again once the queue drains).  This is the
        integrated latency-vs-cadence signal and needs no clocks.
      * latency/cadence ratio — when both have been observed (EMA-smoothed),
        their ratio lower-bounds the stride directly: validating every
        checkpoint is only sustainable when latency <= cadence.

    Selection takes every ``stride``-th pending step counted **from the
    newest**, so the newest checkpoint is always validated — staleness stays
    bounded by one validation, whatever the stride.

    The latency/cadence estimates live as named :class:`~repro.obs.metrics.
    Ewma` instruments in a metrics registry rather than private floats:
    ``observe_latency``/``observe_cadence`` remain the feed API (same EMA
    update, bit for bit), but :meth:`bind_metrics` can re-home both onto a
    shared :class:`~repro.obs.MetricsRegistry` so the policy reads the same
    ``validate.latency_s`` / ``watcher.checkpoint_cadence_s`` estimates
    that ``--obs_report`` prints — one source of timing truth.
    """

    kind: str = "budget"
    target_depth: int = 1         # pending steps tolerated before widening
    min_stride: int = 1
    max_stride: int = 64
    smooth: float = 0.5           # EMA factor for latency/cadence estimates

    def __post_init__(self):
        self._stride_f = float(max(self.min_stride, 1))
        # private registry until bind_metrics() re-homes the instruments
        self.bind_metrics(MetricsRegistry())

    def bind_metrics(self, registry: MetricsRegistry) -> None:
        """Back the latency/cadence EMAs with ``registry``'s instruments,
        carrying any current estimate over so rebinding mid-run never
        forgets what the policy has learned."""
        lat = registry.ewma(VALIDATION_LATENCY_METRIC, smooth=self.smooth)
        cad = registry.ewma(CHECKPOINT_CADENCE_METRIC, smooth=self.smooth)
        # the policy owns these instruments' smoothing, even when rebinding
        # onto a registry where another party created them first
        lat.smooth = cad.smooth = self.smooth
        prev_lat = getattr(self, "_latency", None)
        prev_cad = getattr(self, "_cadence", None)
        if prev_lat is not None and prev_lat.value is not None \
                and lat.value is None:
            lat.value, lat.count = prev_lat.value, prev_lat.count
        if prev_cad is not None and prev_cad.value is not None \
                and cad.value is None:
            cad.value, cad.count = prev_cad.value, prev_cad.count
        self._latency = lat
        self._cadence = cad

    def observe_latency(self, seconds: float) -> None:
        self._latency.update(seconds)

    def observe_cadence(self, seconds: float) -> None:
        self._cadence.update(seconds)

    @property
    def effective_stride(self) -> int:
        return max(1, int(round(self._stride_f)))

    def select(self, pending: List[int]) -> List[int]:
        if not pending:
            return []
        depth = len(pending)
        if depth > self.target_depth:
            self._stride_f = min(float(self.max_stride), self._stride_f * 2.0)
        elif depth <= self.target_depth:
            self._stride_f = max(float(self.min_stride), self._stride_f / 2.0)
        latency, cadence = self._latency.value, self._cadence.value
        if latency is not None and cadence is not None and cadence > 0:
            floor = min(float(self.max_stride), latency / cadence)
            self._stride_f = max(self._stride_f, floor)
        k = self.effective_stride
        newest_first = sorted(pending, reverse=True)
        return sorted(newest_first[::k])


class CheckpointWatcher:
    def __init__(self, root: str, *, policy: Optional[Policy] = None,
                 skip_existing: bool = False, telemetry=None):
        self.root = root
        self.policy = policy or Policy()
        # telemetry observes discovery (spans + discovery-lag histogram);
        # it never influences which steps poll() returns.  Budget policies
        # re-home their EMAs onto the shared registry here so the same
        # numbers drive scheduling and --obs_report.
        self.telemetry = telemetry
        if telemetry is not None and hasattr(self.policy, "bind_metrics"):
            self.policy.bind_metrics(telemetry.metrics)
        self._seen: Set[int] = set()
        # steps a policy deliberately passed over (stale under latest_first,
        # off-stride, over-budget): they will never be validated, carry no
        # pending quality claim, and so must NOT hold GC protection forever
        # (validator.protect_set subtracts them).  Distinct from handed-out
        # steps that failed — those stay protected.
        self._skipped: Set[int] = set()
        self._last_arrival_t: Optional[float] = None
        # dir names already observed committed: commitment is monotonic (a
        # COMMIT marker never disappears while the dir exists), so each poll
        # only stats entries NOT yet known committed — O(new) stat calls per
        # tick instead of O(all checkpoints), which matters once a long run
        # has accumulated thousands of step dirs.
        self._committed_names: Set[str] = set()
        if skip_existing:
            self._seen.update(self._list_committed())

    def _list_committed(self) -> List[int]:
        """Committed steps, ascending — ``ckpt.list_steps`` semantics with
        the known-committed cache (see ``_committed_names``) so repeated
        polling of a large root stays cheap."""
        if not os.path.isdir(self.root):
            return []
        names = os.listdir(self.root)
        # GC'd checkpoints drop out of the cache with their dirs, so a step
        # re-using a name later (restart from an earlier step) is re-statted
        self._committed_names &= set(names)
        steps = []
        for name in names:
            if not name.startswith(ckpt.STEP_PREFIX) \
                    or name.endswith(".tmp"):
                continue
            try:
                step = int(name[len(ckpt.STEP_PREFIX):])
            except ValueError:
                continue
            if name in self._committed_names \
                    or ckpt.is_committed(os.path.join(self.root, name)):
                self._committed_names.add(name)
                steps.append(step)
        return sorted(steps)

    def poll(self) -> List[int]:
        """New committed steps since the last poll, policy-ordered."""
        steps = [s for s in self._list_committed() if s not in self._seen]
        if steps:
            now = time.monotonic()
            if self._last_arrival_t is not None:
                # inter-arrival estimate for adaptive (budget) policies:
                # time since the previous discovery, amortized per new step
                self.policy.observe_cadence(
                    (now - self._last_arrival_t) / len(steps))
            self._last_arrival_t = now
            tel = self.telemetry
            if tel is not None:
                self._observe_discovery(tel, steps)
        chosen = self.policy.select(steps)
        # every discovered step is consumed by this poll: chosen ones are
        # handed out, the rest are policy-skipped (stale under latest_first,
        # off-stride, over-budget).  Marking BOTH seen keeps the pending
        # list from regrowing — and being re-filtered — on every poll.
        self._seen.update(steps)
        self._skipped.update(set(steps) - set(chosen))
        return chosen

    def _observe_discovery(self, tel, steps: List[int]) -> None:
        """Emit one ``discovered`` event per new step, measure discovery
        lag (COMMIT-marker mtime → now, wall clock — metrics only, never a
        decision input), and mark discovery for the checkpoint-to-verdict
        latency measured when the verdict is recorded."""
        lag_hist = tel.metrics.histogram(DISCOVERY_LAG_METRIC)
        for step in steps:
            lag = None
            marker = os.path.join(ckpt._step_dir(self.root, step),
                                  ckpt.COMMIT_MARKER)
            try:
                lag = max(0.0, time.time() - os.path.getmtime(marker))
            except OSError:
                pass
            if lag is not None:
                lag_hist.observe(lag)
            tel.mark("discovered", step)
            tel.event("discovered", step=step, lag_s=lag)

    @property
    def skipped(self) -> Set[int]:
        """Steps the policy chose never to validate (snapshot)."""
        return set(self._skipped)

    def mark_seen(self, step: int) -> None:
        """Claim ``step`` as handled outside poll() (given-up failures, the
        validator's explicit validate_step): it is consumed, and it is not
        a policy skip — so it keeps (or regains) GC protection until a
        verdict lands."""
        self._seen.add(step)
        self._skipped.discard(step)

    def requeue(self, step: int) -> None:
        """Make ``step`` visible to the next :meth:`poll` again.

        ``poll`` marks a step seen the moment it is *handed out*, before the
        caller knows whether validation succeeded — a checkpoint that fails
        (torn filesystem read, transient OOM) would otherwise be permanently
        swallowed.  The validator calls this on failure so the step is
        retried on a later poll.  The retried step goes back through the
        policy: under ``latest_first``/``budget`` a newer checkpoint may win
        and the failed one is then dropped as stale — that is the staleness
        bound working as intended, not a lost retry."""
        self._seen.discard(step)
        self._skipped.discard(step)
