"""Checkpoint watcher — the paper's "listen to --ckpts_dir" loop, hardened.

Only directories carrying the COMMIT marker are visible (two-phase commit,
see ``repro.ckpt.checkpoint``), so a validator polling while the trainer is
mid-write can never read a torn checkpoint.

Scheduling policies (beyond-paper, needed when validation is slower than the
checkpoint cadence at scale):
  * FIFO          — the paper's behaviour: validate every checkpoint in order.
  * LATEST_FIRST  — always jump to the newest checkpoint, skipping stale ones
                    (bounds validation staleness; skipped steps are recorded).
  * STRIDE(k)     — validate every k-th checkpoint.
  * BUDGET        — :class:`BudgetPolicy`: adapt the stride automatically
                    from observed validation latency vs checkpoint cadence
                    (queue depth), bounding staleness without hand-tuning.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional, Set

from repro.ckpt import checkpoint as ckpt


@dataclasses.dataclass
class Policy:
    kind: str = "fifo"            # fifo | latest_first | stride
    stride: int = 1

    def select(self, pending: List[int]) -> List[int]:
        """Order/filter newly discovered steps for validation."""
        if not pending:
            return []
        if self.kind == "fifo":
            return sorted(pending)
        if self.kind == "latest_first":
            return [max(pending)]
        if self.kind == "stride":
            stride = max(self.stride, 1)
            return sorted(s for s in pending if s % stride == 0)
        raise ValueError(self.kind)

    # feedback hooks (no-ops here; BudgetPolicy adapts on them) -------------
    def observe_latency(self, seconds: float) -> None:
        """Called by the validator after each completed validation."""

    def observe_cadence(self, seconds: float) -> None:
        """Called by the watcher with the inter-arrival time of checkpoints."""


@dataclasses.dataclass
class BudgetPolicy(Policy):
    """Self-tuning stride: keep validation throughput within budget.

    Two coupled signals:
      * queue depth — more pending steps per poll than ``target_depth``
        means validation is falling behind the checkpoint cadence: double
        the stride (halve it again once the queue drains).  This is the
        integrated latency-vs-cadence signal and needs no clocks.
      * latency/cadence ratio — when both have been observed (EMA-smoothed),
        their ratio lower-bounds the stride directly: validating every
        checkpoint is only sustainable when latency <= cadence.

    Selection takes every ``stride``-th pending step counted **from the
    newest**, so the newest checkpoint is always validated — staleness stays
    bounded by one validation, whatever the stride.
    """

    kind: str = "budget"
    target_depth: int = 1         # pending steps tolerated before widening
    min_stride: int = 1
    max_stride: int = 64
    smooth: float = 0.5           # EMA factor for latency/cadence estimates

    def __post_init__(self):
        self._stride_f = float(max(self.min_stride, 1))
        self._latency_ema: Optional[float] = None
        self._cadence_ema: Optional[float] = None

    def observe_latency(self, seconds: float) -> None:
        prev = self._latency_ema
        self._latency_ema = seconds if prev is None else \
            self.smooth * prev + (1 - self.smooth) * seconds

    def observe_cadence(self, seconds: float) -> None:
        prev = self._cadence_ema
        self._cadence_ema = seconds if prev is None else \
            self.smooth * prev + (1 - self.smooth) * seconds

    @property
    def effective_stride(self) -> int:
        return max(1, int(round(self._stride_f)))

    def select(self, pending: List[int]) -> List[int]:
        if not pending:
            return []
        depth = len(pending)
        if depth > self.target_depth:
            self._stride_f = min(float(self.max_stride), self._stride_f * 2.0)
        elif depth <= self.target_depth:
            self._stride_f = max(float(self.min_stride), self._stride_f / 2.0)
        if self._latency_ema is not None and self._cadence_ema is not None \
                and self._cadence_ema > 0:
            floor = min(float(self.max_stride),
                        self._latency_ema / self._cadence_ema)
            self._stride_f = max(self._stride_f, floor)
        k = self.effective_stride
        newest_first = sorted(pending, reverse=True)
        return sorted(newest_first[::k])


class CheckpointWatcher:
    def __init__(self, root: str, *, policy: Optional[Policy] = None,
                 skip_existing: bool = False):
        self.root = root
        self.policy = policy or Policy()
        self._seen: Set[int] = set()
        # steps a policy deliberately passed over (stale under latest_first,
        # off-stride, over-budget): they will never be validated, carry no
        # pending quality claim, and so must NOT hold GC protection forever
        # (validator.protect_set subtracts them).  Distinct from handed-out
        # steps that failed — those stay protected.
        self._skipped: Set[int] = set()
        self._last_arrival_t: Optional[float] = None
        # dir names already observed committed: commitment is monotonic (a
        # COMMIT marker never disappears while the dir exists), so each poll
        # only stats entries NOT yet known committed — O(new) stat calls per
        # tick instead of O(all checkpoints), which matters once a long run
        # has accumulated thousands of step dirs.
        self._committed_names: Set[str] = set()
        if skip_existing:
            self._seen.update(self._list_committed())

    def _list_committed(self) -> List[int]:
        """Committed steps, ascending — ``ckpt.list_steps`` semantics with
        the known-committed cache (see ``_committed_names``) so repeated
        polling of a large root stays cheap."""
        if not os.path.isdir(self.root):
            return []
        names = os.listdir(self.root)
        # GC'd checkpoints drop out of the cache with their dirs, so a step
        # re-using a name later (restart from an earlier step) is re-statted
        self._committed_names &= set(names)
        steps = []
        for name in names:
            if not name.startswith(ckpt.STEP_PREFIX) \
                    or name.endswith(".tmp"):
                continue
            try:
                step = int(name[len(ckpt.STEP_PREFIX):])
            except ValueError:
                continue
            if name in self._committed_names \
                    or ckpt.is_committed(os.path.join(self.root, name)):
                self._committed_names.add(name)
                steps.append(step)
        return sorted(steps)

    def poll(self) -> List[int]:
        """New committed steps since the last poll, policy-ordered."""
        steps = [s for s in self._list_committed() if s not in self._seen]
        if steps:
            now = time.monotonic()
            if self._last_arrival_t is not None:
                # inter-arrival estimate for adaptive (budget) policies:
                # time since the previous discovery, amortized per new step
                self.policy.observe_cadence(
                    (now - self._last_arrival_t) / len(steps))
            self._last_arrival_t = now
        chosen = self.policy.select(steps)
        # every discovered step is consumed by this poll: chosen ones are
        # handed out, the rest are policy-skipped (stale under latest_first,
        # off-stride, over-budget).  Marking BOTH seen keeps the pending
        # list from regrowing — and being re-filtered — on every poll.
        self._seen.update(steps)
        self._skipped.update(set(steps) - set(chosen))
        return chosen

    @property
    def skipped(self) -> Set[int]:
        """Steps the policy chose never to validate (snapshot)."""
        return set(self._skipped)

    def mark_seen(self, step: int) -> None:
        """Claim ``step`` as handled outside poll() (given-up failures, the
        validator's explicit validate_step): it is consumed, and it is not
        a policy skip — so it keeps (or regains) GC protection until a
        verdict lands."""
        self._seen.add(step)
        self._skipped.discard(step)

    def requeue(self, step: int) -> None:
        """Make ``step`` visible to the next :meth:`poll` again.

        ``poll`` marks a step seen the moment it is *handed out*, before the
        caller knows whether validation succeeded — a checkpoint that fails
        (torn filesystem read, transient OOM) would otherwise be permanently
        swallowed.  The validator calls this on failure so the step is
        retried on a later poll.  The retried step goes back through the
        policy: under ``latest_first``/``budget`` a newer checkpoint may win
        and the failed one is then dropped as stale — that is the staleness
        bound working as intended, not a lost retry."""
        self._seen.discard(step)
        self._skipped.discard(step)
