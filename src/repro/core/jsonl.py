"""Crash-tolerant JSONL loading, shared by every append-only fsync'd log.

The validator ledger and the control event log append one fsync'd JSON line
per record.  A process killed mid-append (crash / power loss) leaves a torn
FINAL line; :func:`read_jsonl_tolerant` drops exactly that line and reports
its byte offset so the OWNING WRITER can truncate it away before its next
append (a clean line instead of gluing onto the fragment).  Loading never
mutates the file — an offline audit reading a LIVE log must not race the
writer's in-flight append by truncating what merely looks torn.  A
malformed line anywhere ELSE means real corruption (bit rot, concurrent
writers, hand edits) and raises — silently dropping interior records would
corrupt replay.
"""

from __future__ import annotations

import json
from typing import List, Optional, Tuple


def read_jsonl_tolerant(path: str, *,
                        kind: str = "row") -> Tuple[List[dict],
                                                    Optional[int]]:
    """Parse ``path`` as JSONL, tolerating a torn final line.

    Returns ``(records, torn_offset)`` — ``torn_offset`` is the byte offset
    of the dropped torn final line (None when the file is clean).  The
    single writer that owns the file calls :func:`truncate_torn_tail` with
    it before the first append; readers leave the file untouched.  ``kind``
    names the record type in error messages."""
    with open(path, "rb") as f:
        raw = f.read()
    offset, lines = 0, []                # (lineno, byte offset, line)
    for i, ln in enumerate(raw.splitlines(keepends=True), 1):
        if ln.strip():
            lines.append((i, offset, ln))
        offset += len(ln)
    out: List[dict] = []
    for pos, (lineno, start, line) in enumerate(lines):
        try:
            out.append(json.loads(line))
        except ValueError:
            if pos == len(lines) - 1:
                # torn final line: the append died mid-write; dropped here,
                # truncated by the owning writer before its next append
                return out, start
            raise ValueError(
                f"corrupt {kind} at {path}:{lineno} (only a torn FINAL "
                f"line is recoverable)")
    return out, None


def truncate_torn_tail(path: str, torn_offset: Optional[int]) -> None:
    """Writer-side repair: cut the torn tail reported by
    :func:`read_jsonl_tolerant` so the next append starts a clean line.
    No-op when the load was clean."""
    if torn_offset is not None:
        with open(path, "r+b") as f:
            f.truncate(torn_offset)
