"""Crash-tolerant JSONL loading, shared by every append-only fsync'd log.

The validator ledger and the control event log append one fsync'd JSON line
per record.  A process killed mid-append (crash / power loss) leaves a torn
FINAL line; :func:`read_jsonl_tolerant` drops exactly that line and reports
its byte offset so the OWNING WRITER can truncate it away before its next
append (a clean line instead of gluing onto the fragment).  Loading never
mutates the file — an offline audit reading a LIVE log must not race the
writer's in-flight append by truncating what merely looks torn.  A
malformed line anywhere ELSE means real corruption (bit rot, concurrent
writers, hand edits) and raises — silently dropping interior records would
corrupt replay.
"""

from __future__ import annotations

import json
import os
from typing import Iterable, List, Optional, Tuple

try:                                    # POSIX advisory locking (Linux/macOS)
    import fcntl
except ImportError:                     # pragma: no cover - non-POSIX hosts
    fcntl = None


def read_jsonl_tolerant(path: str, *,
                        kind: str = "row") -> Tuple[List[dict],
                                                    Optional[int]]:
    """Parse ``path`` as JSONL, tolerating a torn final line.

    Returns ``(records, torn_offset)`` — ``torn_offset`` is the byte offset
    of the dropped torn final line (None when the file is clean).  The
    single writer that owns the file calls :func:`truncate_torn_tail` with
    it before the first append; readers leave the file untouched.  ``kind``
    names the record type in error messages."""
    with open(path, "rb") as f:
        raw = f.read()
    offset, lines = 0, []                # (lineno, byte offset, line)
    for i, ln in enumerate(raw.splitlines(keepends=True), 1):
        if ln.strip():
            lines.append((i, offset, ln))
        offset += len(ln)
    out: List[dict] = []
    for pos, (lineno, start, line) in enumerate(lines):
        try:
            out.append(json.loads(line))
        except ValueError:
            if pos == len(lines) - 1:
                # torn final line: the append died mid-write; dropped here,
                # truncated by the owning writer before its next append
                return out, start
            raise ValueError(
                f"corrupt {kind} at {path}:{lineno} (only a torn FINAL "
                f"line is recoverable)")
    return out, None


def truncate_torn_tail(path: str, torn_offset: Optional[int]) -> None:
    """Writer-side repair: cut the torn tail reported by
    :func:`read_jsonl_tolerant` so the next append starts a clean line.
    No-op when the load was clean."""
    if torn_offset is not None:
        with open(path, "r+b") as f:
            f.truncate(torn_offset)


def append_jsonl_atomic(path: str, records: Iterable[dict]) -> int:
    """Append ``records`` as JSONL in ONE atomic, fsync'd write — safe for
    MULTIPLE processes sharing the file (the validator-fleet work queue:
    claim records and result rows from N workers land in one ledger).

    Three guarantees, in write order:

      * tail repair — if the previous appender crashed mid-write the file
        ends in a torn fragment (no trailing newline); gluing onto it would
        turn a recoverable torn FINAL line into unrecoverable interior
        corruption, so the fragment is truncated away first;
      * atomicity — the file is opened ``O_APPEND`` and all records go out
        in a single ``os.write`` (POSIX appends are atomic w.r.t. the file
        offset), so concurrent appenders can interleave *records* but never
        tear one; an advisory ``flock`` additionally serializes the
        repair-then-append sequence so two restarting workers cannot race
        the truncation;
      * durability — fsync before returning, matching the ledger's
        discipline: no reader (in-process or crash-restarted) observes a
        record that could still disappear.

    Returns the number of records written."""
    recs = list(records)
    if not recs:
        return 0
    # key order is preserved (no sort_keys): result rows must serialize
    # byte-identically to the single-writer path they replace
    data = "".join(json.dumps(r) + "\n" for r in recs).encode()
    fd = os.open(path, os.O_APPEND | os.O_CREAT | os.O_RDWR, 0o644)
    try:
        if fcntl is not None:
            fcntl.flock(fd, fcntl.LOCK_EX)
        size = os.fstat(fd).st_size
        if size:
            last = os.pread(fd, 1, size - 1)
            if last != b"\n":
                # previous appender died mid-write: cut back to the last
                # complete line (the loader would have dropped the fragment
                # anyway — repairing here keeps OUR record un-glued)
                whole = os.pread(fd, size, 0)
                os.ftruncate(fd, whole.rfind(b"\n") + 1)
        os.write(fd, data)
        os.fsync(fd)
    finally:
        if fcntl is not None:
            fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)
    return len(recs)
