"""Claimable (step, task) work units over the validation ledger — the
coordination layer of the validator fleet.

Asyncval decouples validation from training onto "another GPU"; this module
decouples it onto N of them.  The schema-v2 ledger already keys one fsync'd
row per ``(step, task)``, which is exactly the shape of a distributed work
queue — so the queue IS the ledger: claim/renew/complete/abandon records are
appended to the same JSONL file as sibling record types, and every fleet
decision (who owns which unit, which lease expired, which unit is retried)
is a pure function of the record sequence.  Crashes lose work units, never
correctness, and :func:`replay` re-derives the identical decision sequence
offline — the same append-only/fsync'd/replayable discipline the control
plane enforces (DataStates-LLM's coordination model).

Claim-record schema (v2 ledger sibling records — result rows carry no
``"kind"`` key and are untouched; every loader that predates the fleet
skips kind-bearing records):

    {"kind": "unit",     "step": S, "task": T, "requires": {...}}
    {"kind": "claim",    "step": S, "task": T, "worker": W}
    {"kind": "renew",    "step": S, "task": T, "worker": W}
    {"kind": "complete", "step": S, "task": T, "worker": W}
    {"kind": "abandon",  "step": S, "task": T, "worker": W, "error": "..."}
    {"kind": "tick",     "worker": W}

  * ``unit`` — the watcher/supervisor publishes a discovered checkpoint as
    one unit per suite task; ``requires`` names capability minima
    (``{"mesh_size": 8}``) a claiming worker must meet.
  * ``claim`` — a worker's bid for a unit.  The bid WINS iff, at its
    position in the record sequence, the unit is open or its current lease
    has expired; a bid against a live lease loses and is simply ignored by
    every (deterministic) reader.  Appends are atomic (single ``O_APPEND``
    write, see :func:`repro.core.jsonl.append_jsonl_atomic`), so ordering
    is total and every worker derives the same winner.
  * ``renew`` — lease heartbeat by the holding worker.
  * ``complete`` — the unit's result row(s) are durably appended; emitted
    AFTER the row so a complete always has its result.
  * ``abandon`` — voluntary release (validation failed): the unit reopens
    and any worker may retry it; the per-unit abandon count is the
    DISTRIBUTED retry budget (derived from the ledger, not worker state).
  * ``tick`` — seq-only heartbeat an idle-but-blocked worker appends so a
    dead peer's lease can expire (see below).

Leases are measured in ledger SEQUENCE, not wall clock: a claim's lease
timestamp is the index of its claim/latest-renew record, and it expires
once more than ``lease_ttl`` records have been appended after that touch
without a renew/complete.  No wall-clock value ever feeds a decision, so
:func:`replay` over the file reproduces the online fleet's choices exactly
— including which worker reclaimed a crashed peer's unit, and when.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.core.jsonl import append_jsonl_atomic

QUEUE_KINDS = frozenset({"unit", "claim", "renew", "complete", "abandon",
                         "tick"})

#: unit lifecycle states derived from the record fold
OPEN, CLAIMED, DONE, FAILED = "open", "claimed", "done", "failed"


@dataclasses.dataclass(frozen=True)
class WorkUnit:
    """One claimable piece of validation work: one checkpoint x one task.

    ``requires`` maps capability names to minima a worker must meet
    (numeric: worker value >= requirement; otherwise: equality) — e.g.
    ``{"mesh_size": 8}`` keeps a full-corpus sharded task away from a CPU
    smoke worker."""

    step: int
    task: str = "default"
    requires: Tuple[Tuple[str, Any], ...] = ()

    @classmethod
    def make(cls, step: int, task: str = "default",
             requires: Optional[Mapping[str, Any]] = None) -> "WorkUnit":
        return cls(step=int(step), task=str(task),
                   requires=tuple(sorted((requires or {}).items())))

    @property
    def key(self) -> Tuple[int, str]:
        return (self.step, self.task)

    @property
    def requires_dict(self) -> Dict[str, Any]:
        return dict(self.requires)


def meets(capabilities: Mapping[str, Any],
          requires: Mapping[str, Any]) -> bool:
    """True when a worker's capability tags satisfy a unit's requirements:
    numeric requirements are minima, everything else must match exactly; a
    capability the worker does not declare fails the unit."""
    for key, need in (requires or {}).items():
        have = (capabilities or {}).get(key)
        if have is None:
            return False
        if isinstance(need, (int, float)) and isinstance(have, (int, float)):
            if have < need:
                return False
        elif have != need:
            return False
    return True


@dataclasses.dataclass
class UnitState:
    """Fold state of one (step, task) unit."""

    unit: WorkUnit
    status: str = OPEN
    holder: Optional[str] = None        # claiming worker while CLAIMED
    touch_seq: int = -1                 # seq of the claim/latest renew
    claim_seq: int = -1                 # seq of the winning claim
    abandons: int = 0                   # distributed retry counter
    completed_by: Optional[str] = None
    source: str = ""                    # publish route ("snapshot" | "")

    def lease_live(self, head_seq: int, ttl: int) -> bool:
        return self.status == CLAIMED and head_seq - self.touch_seq <= ttl


class QueueState:
    """Deterministic fold of the ledger's record sequence into fleet state.

    Every reader (worker claim loops, the supervisor's control pump,
    offline :func:`replay`) folds the SAME records with the SAME rules, so
    all of them agree on unit ownership without any channel beyond the
    ledger file.  ``events`` is the decision trace (claims won/lost/
    reclaimed, completions, expiry-reclaims) for offline audit."""

    def __init__(self, lease_ttl: int = 16, max_abandons: int = 2):
        self.lease_ttl = int(lease_ttl)
        self.max_abandons = int(max_abandons)
        self.units: Dict[Tuple[int, str], UnitState] = {}
        self.result_rows: List[dict] = []   # schema-v2 rows, seq order
        self.events: List[dict] = []        # fleet decision trace
        self.head_seq = -1                  # seq of the last folded record

    # -- folding -------------------------------------------------------------
    def fold(self, rec: dict) -> None:
        self.head_seq += 1
        seq = self.head_seq
        kind = rec.get("kind")
        if kind is None:                    # schema-v2 result row
            self.result_rows.append(rec)
            key = (int(rec["step"]), str(rec.get("task", "default")))
            st = self.units.get(key)
            if st is not None and st.status != DONE:
                st.status = DONE
                st.completed_by = rec.get("worker_id") or st.holder
            return
        if kind == "tick":                  # seq progress only
            return
        key = (int(rec["step"]), str(rec.get("task", "default")))
        worker = str(rec.get("worker", ""))
        st = self.units.get(key)
        if kind == "unit":
            if st is None:
                unit = WorkUnit.make(key[0], key[1],
                                     rec.get("requires") or {})
                self.units[key] = UnitState(
                    unit=unit, source=str(rec.get("source", "")))
                self.events.append({"seq": seq, "event": "publish",
                                    "step": key[0], "task": key[1]})
            return                          # re-publish: no-op
        if st is None:
            # claim/renew/... for a unit never published: tolerate by
            # materializing it (a worker may enqueue ad-hoc units, e.g.
            # soup-candidate scoring fanned out without a supervisor)
            st = self.units[key] = UnitState(unit=WorkUnit.make(*key))
        if kind == "claim":
            self._fold_claim(st, worker, seq)
        elif kind == "renew":
            if st.status == CLAIMED and st.holder == worker:
                st.touch_seq = seq
        elif kind == "complete":
            if st.status != DONE:
                st.status, st.completed_by = DONE, worker
                self.events.append({"seq": seq, "event": "complete",
                                    "step": key[0], "task": key[1],
                                    "worker": worker})
        elif kind == "abandon":
            if st.status == CLAIMED and st.holder == worker:
                st.abandons += 1
                st.status, st.holder = OPEN, None
                if st.abandons > self.max_abandons:
                    st.status = FAILED      # retry budget exhausted
                self.events.append({"seq": seq, "event": "abandon",
                                    "step": key[0], "task": key[1],
                                    "worker": worker,
                                    "abandons": st.abandons,
                                    "failed": st.status == FAILED})

    def _fold_claim(self, st: UnitState, worker: str, seq: int) -> None:
        key = st.unit.key
        if st.status == DONE or st.status == FAILED:
            return                          # late claim: silently lost
        if st.status == CLAIMED and st.holder == worker:
            st.touch_seq = seq              # self-claim acts as a renew
            return
        if st.status == CLAIMED:
            if seq - st.touch_seq <= self.lease_ttl:
                self.events.append({"seq": seq, "event": "claim_lost",
                                    "step": key[0], "task": key[1],
                                    "worker": worker, "holder": st.holder})
                return                      # live lease: bid loses
            # expired lease: crash-safe reclaim
            self.events.append({"seq": seq, "event": "reclaim",
                                "step": key[0], "task": key[1],
                                "worker": worker, "from": st.holder,
                                "expired_touch": st.touch_seq})
        else:
            self.events.append({"seq": seq, "event": "claim",
                                "step": key[0], "task": key[1],
                                "worker": worker})
        st.status, st.holder = CLAIMED, worker
        st.claim_seq = st.touch_seq = seq

    # -- queries -------------------------------------------------------------
    def get(self, step: int, task: str = "default") -> Optional[UnitState]:
        return self.units.get((int(step), str(task)))

    def holder(self, step: int, task: str = "default") -> Optional[str]:
        st = self.get(step, task)
        return st.holder if st is not None and st.status == CLAIMED else None

    def claimable(self, capabilities: Optional[Mapping[str, Any]] = None
                  ) -> List[WorkUnit]:
        """Units a worker with ``capabilities`` may bid on NOW: open, or
        held under an expired lease — sorted (step, task) so every worker
        walks the backlog in the same order."""
        out = []
        for st in self.units.values():
            if st.status == OPEN or (
                    st.status == CLAIMED
                    and not st.lease_live(self.head_seq, self.lease_ttl)):
                if meets(capabilities or {}, st.unit.requires_dict):
                    out.append(st.unit)
        return sorted(out, key=lambda u: u.key)

    def blocked(self) -> List[WorkUnit]:
        """Units held by live leases of OTHER workers (pending, not ours to
        take yet) — a worker seeing only these appends a tick so a dead
        holder's lease can age out."""
        return sorted((st.unit for st in self.units.values()
                       if st.lease_live(self.head_seq, self.lease_ttl)),
                      key=lambda u: u.key)

    def claimed_steps(self) -> set:
        """Steps with at least one LIVE claim — GC protection for work in
        flight on other workers."""
        return {st.unit.step for st in self.units.values()
                if st.lease_live(self.head_seq, self.lease_ttl)}

    def incomplete_steps(self) -> set:
        return {st.unit.step for st in self.units.values()
                if st.status not in (DONE,)}

    def completed_units(self) -> List[Tuple[int, str]]:
        return sorted(k for k, st in self.units.items() if st.status == DONE)

    def step_complete(self, step: int,
                      expected_tasks: Iterable[str]) -> bool:
        return all((st := self.units.get((int(step), t))) is not None
                   and st.status == DONE for t in expected_tasks)


class WorkQueue:
    """One worker's (or the supervisor's) handle on the shared ledger queue.

    All mutation is append-only through
    :func:`~repro.core.jsonl.append_jsonl_atomic`; all state is derived by
    re-folding the file (incrementally — the file is append-only, so
    :meth:`refresh` reads only the bytes appended since the last call).
    ``worker_id`` names this participant in every record it appends;
    ``capabilities`` are its tags matched against unit requirements."""

    def __init__(self, path: str, worker_id: str = "worker-0", *,
                 capabilities: Optional[Mapping[str, Any]] = None,
                 lease_ttl: int = 16, max_abandons: int = 2,
                 telemetry=None):
        self.path = path
        self.worker_id = str(worker_id)
        self.capabilities = dict(capabilities or {})
        self.lease_ttl = int(lease_ttl)
        self.max_abandons = int(max_abandons)
        self._offset = 0            # first unconsumed byte of the file
        self.state = QueueState(lease_ttl=lease_ttl,
                                max_abandons=max_abandons)
        # telemetry mirrors the fold's decision events into fleet.* counters
        # (publish/claim/claim_lost/reclaim/complete/abandon, as observed by
        # THIS handle's fold) and emits published/claimed lifecycle events.
        # It reads the decision trace; it never feeds it — replay() stays
        # byte-identical with telemetry on or off.
        self.telemetry = telemetry
        self._events_counted = 0    # fold-events watermark for the mirrors
        self._rows_counted = 0      # result-rows watermark (completions)

    # -- reading -------------------------------------------------------------
    def refresh(self) -> QueueState:
        """Fold any newly appended records and return the current state."""
        try:
            size = os.path.getsize(self.path)
        except OSError:
            return self.state
        rebuilt = False
        if size < self._offset:
            # the file shrank: a restarting appender repaired a torn tail
            # below our read offset — refold from scratch
            self._offset = 0
            self.state = QueueState(lease_ttl=self.lease_ttl,
                                    max_abandons=self.max_abandons)
            rebuilt = True
        if size == self._offset:
            return self.state
        with open(self.path, "rb") as f:
            f.seek(self._offset)
            data = f.read()
        # only complete lines are folded; a trailing fragment (a concurrent
        # append in flight, or a crashed writer's torn tail) is NOT consumed
        # — the offset stays at its start, so the next refresh re-reads it
        # whole (or past its repair)
        lines = data.split(b"\n")
        fragment = lines.pop()
        self._offset += len(data) - len(fragment)
        for ln in lines:
            if not ln.strip():
                continue
            try:
                rec = json.loads(ln)
            except ValueError:
                # an interior unparseable line can only be a crashed
                # writer's fragment that a later appender newline-guarded;
                # every reader skips it identically, so determinism holds
                continue
            self.state.fold(rec)
        tel = self.telemetry
        if tel is not None:
            events = self.state.events
            rows = self.state.result_rows
            if rebuilt:
                # the refold replayed history this handle already mirrored;
                # resync the watermarks instead of double-counting
                self._events_counted = len(events)
                self._rows_counted = len(rows)
            else:
                for ev in events[self._events_counted:]:
                    tel.metrics.counter(f"fleet.{ev['event']}").inc()
                self._events_counted = len(events)
                # in the repo flow the verdict ROW marks a unit DONE (the
                # explicit complete record then folds as a no-op, emitting
                # no event), so rows are the global completion count
                fresh_rows = len(rows) - self._rows_counted
                if fresh_rows:
                    tel.metrics.counter("fleet.complete").inc(fresh_rows)
                self._rows_counted = len(rows)
        return self.state

    # -- appending -----------------------------------------------------------
    def _append(self, recs: List[dict]) -> None:
        append_jsonl_atomic(self.path, recs)

    def publish(self, units: Iterable[WorkUnit], *,
                source: str = "") -> List[WorkUnit]:
        """Publish not-yet-known units (the watcher layer: discovered steps
        become claimable work).  Already-published units are skipped, so
        re-publishing after a supervisor restart is idempotent — a step
        spilled by the hand-off spool (``source="snapshot"``) and later
        discovered durable by the watcher publishes exactly once, keeping
        first-route-wins dedupe in the fold itself.  ``source`` stamps the
        unit record for audit; omitted when empty, so pre-handoff ledgers
        stay byte-identical."""
        self.refresh()
        fresh = [u for u in units if u.key not in self.state.units]
        if fresh:
            recs = []
            for u in fresh:
                rec = {"kind": "unit", "step": u.step, "task": u.task,
                       "requires": u.requires_dict}
                if source:
                    rec["source"] = source
                recs.append(rec)
            self._append(recs)
            self.refresh()
            tel = self.telemetry
            if tel is not None:
                for u in fresh:
                    tel.event("published", step=u.step, task=u.task,
                              **({"source": source} if source else {}))
        return fresh

    def try_claim(self, unit: WorkUnit) -> bool:
        """Bid for ``unit``; True iff OUR claim won (we now hold the lease).
        The winner is decided by the fold over the totally-ordered record
        sequence, never locally — so two workers bidding concurrently agree
        on the outcome by construction."""
        self._append([{"kind": "claim", "step": unit.step, "task": unit.task,
                       "worker": self.worker_id}])
        st = self.refresh().get(unit.step, unit.task)
        won = st is not None and st.status == CLAIMED \
            and st.holder == self.worker_id
        tel = self.telemetry
        if tel is not None and won:
            tel.event("claimed", step=unit.step, task=unit.task)
        return won

    def renew(self, unit: WorkUnit) -> None:
        """Heartbeat: re-stamp our lease so it cannot expire while the
        engine run is still in flight."""
        self._append([{"kind": "renew", "step": unit.step, "task": unit.task,
                       "worker": self.worker_id}])
        tel = self.telemetry
        if tel is not None:
            tel.metrics.counter("fleet.renew").inc()

    def complete(self, unit: WorkUnit) -> None:
        self._append([{"kind": "complete", "step": unit.step,
                       "task": unit.task, "worker": self.worker_id}])
        self.refresh()

    def abandon(self, unit: WorkUnit, error: str = "") -> None:
        self._append([{"kind": "abandon", "step": unit.step,
                       "task": unit.task, "worker": self.worker_id,
                       "error": error}])
        self.refresh()

    def tick(self) -> None:
        """Seq-only heartbeat: appended when this worker is blocked behind
        other workers' live leases, so a DEAD holder's lease ages out (seq
        is the clock — without progress, no lease ever expires)."""
        self._append([{"kind": "tick", "worker": self.worker_id}])

    def claimable(self) -> List[WorkUnit]:
        return self.refresh().claimable(self.capabilities)


def replay(path_or_records, *, lease_ttl: int = 16,
           max_abandons: int = 2) -> QueueState:
    """Offline fleet replay: fold a ledger file (or an iterable of decoded
    records) and return the terminal :class:`QueueState` — ``state.events``
    is the decision trace the online fleet actually made, because online
    workers decide by exactly this fold over exactly these records.
    ``lease_ttl``/``max_abandons`` must match the online fleet's."""
    state = QueueState(lease_ttl=lease_ttl, max_abandons=max_abandons)
    if isinstance(path_or_records, str):
        from repro.core.jsonl import read_jsonl_tolerant
        records, _ = read_jsonl_tolerant(path_or_records, kind="ledger row")
    else:
        records = path_or_records
    for rec in records:
        state.fold(rec)
    return state


def parse_capabilities(spec: Optional[str]) -> Dict[str, Any]:
    """Parse a CLI capability string (``"mesh_size=8,max_depth=100"``) into
    typed tags: ints/floats where they parse, strings otherwise."""
    out: Dict[str, Any] = {}
    for part in (spec or "").split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"capability {part!r} must be name=value")
        k, v = part.split("=", 1)
        k, v = k.strip(), v.strip()
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out
