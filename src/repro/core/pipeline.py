"""ValidationPipeline — DEPRECATED single-task shim over the ValidationSuite.

The public validation API now lives in :mod:`repro.core.suite`: a
:class:`~repro.core.suite.ValidationSuite` validates checkpoints against N
:class:`~repro.core.suite.ValidationTask`\\ s in one pass, sharing TokenStores
between tasks and building engines through the pluggable component
registries (:mod:`repro.core.registry`).  This module keeps the original
one-corpus/one-queries/one-qrels constructor working, bit for bit: a
``ValidationPipeline`` is exactly a one-task suite whose task is named
``"default"``, and ``validate_params`` returns that task's
:class:`~repro.core.suite.ValidationResult` unchanged.

New code should construct the suite directly::

    from repro.core.suite import (ValidationConfig, ValidationSuite,
                                  ValidationTask)
    suite = ValidationSuite(spec, [ValidationTask("default", corpus,
                                                  queries, qrels,
                                                  sampler=sampler)], vcfg)

``ValidationConfig`` / ``ValidationResult`` / ``params_from_checkpoint``
are re-exported here unchanged for backward compatibility.
"""

from __future__ import annotations

import warnings
from typing import Any, Dict, Optional

from repro.core.suite import (SuiteResult, ValidationConfig, ValidationResult,
                              ValidationSuite, ValidationTask,
                              params_from_checkpoint)

__all__ = ["ValidationConfig", "ValidationResult", "ValidationPipeline",
           "params_from_checkpoint"]

_DEPRECATION_MSG = (
    "ValidationPipeline is deprecated; build a ValidationSuite with a "
    "single ValidationTask instead (repro.core.suite).")
_warned = False


class ValidationPipeline:
    """Deprecated façade: one validation task, suite underneath.

    Emits a :class:`DeprecationWarning` exactly once per process (the shim
    is a migration aid, not a nag).  All documented legacy attributes —
    ``engine``, ``subset``, ``doc_ids``, ``doc_texts``, ``query_ids``,
    ``query_texts``, ``sampler_name`` — keep working.
    """

    def __init__(self, spec, corpus: Dict[str, list],
                 queries: Dict[str, list], qrels: Dict[str, Dict[str, int]],
                 vcfg: ValidationConfig, *, sampler=None,
                 baseline_run: Optional[Dict[str, list]] = None,
                 engine=None):
        global _warned
        if not _warned:
            _warned = True
            warnings.warn(_DEPRECATION_MSG, DeprecationWarning, stacklevel=2)
        task = ValidationTask("default", corpus, queries, qrels,
                              mode=vcfg.mode, sampler=sampler,
                              baseline_run=baseline_run,
                              metrics=tuple(vcfg.metrics), k=vcfg.k)
        self.suite = ValidationSuite(spec, [task], vcfg)
        self.spec = spec
        self.vcfg = vcfg
        self.qrels = qrels
        self._engine_override = engine
        data = self.suite._data["default"]
        self.query_ids = data.query_ids
        self.query_texts = data.query_texts
        self.sampler_name = self.suite.sampler_names["default"]
        self.subset = self.suite.subsets["default"]
        self.doc_ids = data.doc_ids
        self.doc_texts = data.doc_texts
        if engine is None:
            # legacy behaviour: the engine (and every config error it can
            # raise — bad staging, mmap without a dir) surfaces at
            # construction time, not at the first validate_params
            self.suite.engine("default")

    # validator-facing surface (same duck type as ValidationSuite) ----------
    task_names = ("default",)

    @property
    def engine(self):
        return self._engine_override if self._engine_override is not None \
            else self.suite.engine("default")

    # -- one checkpoint ----------------------------------------------------
    def validate_params(self, params, step: int = 0, *,
                        engine=None) -> ValidationResult:
        """Validate one checkpoint.  ``engine`` overrides the pipeline's
        engine for this call only (the AsyncValidator injection path) —
        the pipeline itself is never mutated."""
        eng = engine if engine is not None else self._engine_override
        res: SuiteResult = self.suite.validate_params(params, step=step,
                                                      engine=eng)
        return res.tasks["default"]
