"""ValidationPipeline — thin façade over the streaming ValidationEngine.

One validation of one checkpoint = encode (subset of) corpus + queries with
the checkpoint's weights, retrieve, score.  Modes:

  * ``retrieval``     — full (or subset) corpus top-k retrieval (paper default)
  * ``rerank``        — RocketQA-style per-query candidate re-ranking
  * ``average_rank``  — DPR-style pooled average-rank validation

The corpus subset is computed ONCE (the sampler depends only on the baseline
run + qrels, not the checkpoint) and the pre-tokenized texts are padded once
into the engine's TokenStore — both costs amortize across checkpoints,
exactly as the paper's pre-tokenization argument (§3) prescribes.

The data path itself lives in :mod:`repro.core.engine`: by default a fused
encode→top-k streaming loop that never materializes the ``(N, D)`` corpus
embedding matrix (``ValidationConfig.engine = "streaming"``); set
``engine="materialized"`` for the legacy encode-all-then-retrieve path.
``token_backing="mmap"`` (+ ``mmap_dir``) spills the pre-padded corpus
tokens to memory-mapped files so even the tokens can exceed host RAM
(``token_fingerprint="full"`` opts the cache key into a full content hash),
``staging`` selects double-buffered (default) vs synchronous host→device
chunk staging with a configurable prefetch depth (``staging_depth``) — all
bit-for-bit identical to the in-memory sync path.  Every mode shards over
``mesh``, rerank included (the sharded streaming rerank stage), and the
materialized rerank path gathers candidates in query blocks
(``rerank_block``) so its peak memory no longer scales with Q.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core import metrics as metrics_lib
from repro.core.engine import make_engine
from repro.core.samplers import FullCorpus, SubsetResult
from repro.models.biencoder import EncoderSpec


@dataclasses.dataclass
class ValidationConfig:
    metrics: tuple = ("MRR@10",)
    mode: str = "retrieval"          # retrieval | rerank | average_rank
    k: int = 100                     # retrieval cut-off
    batch_size: int = 64
    impl: str = "xla"                # xla | pallas
    mesh: Any = None                 # optional sharded retrieval mesh
    engine: str = "streaming"        # streaming | materialized (legacy)
    chunk_size: Optional[int] = None  # streaming chunk rows; None -> batch_size
    scan_window: int = 8             # chunks folded per dispatch (xla stage)
    staging: str = "double_buffered"  # double_buffered | sync host->device
    staging_depth: int = 2           # prefetch depth (2 = double buffer;
                                     # deeper for remote-storage stores)
    token_backing: str = "memory"    # memory | mmap (out-of-core TokenStore)
    mmap_dir: Optional[str] = None   # cache dir for token_backing="mmap"
    token_fingerprint: str = "fast"  # fast (O(1)) | full (content hash)
    rerank_block: Optional[int] = None  # queries per materialized rerank
                                     # candidate gather (None = auto budget)
    write_run: bool = False
    output_dir: Optional[str] = None
    run_tag: str = "asyncval"


@dataclasses.dataclass
class ValidationResult:
    step: int
    metrics: Dict[str, float]
    timings: Dict[str, float]
    subset_size: int
    # which data path produced the numbers ("streaming"/"materialized"/...);
    # recorded in the validator ledger so cross-mode parity can be audited
    # after the fact.
    engine: str = ""


class ValidationPipeline:
    def __init__(self, spec: EncoderSpec, corpus: Dict[str, list],
                 queries: Dict[str, list], qrels: Dict[str, Dict[str, int]],
                 vcfg: ValidationConfig, *, sampler=None,
                 baseline_run: Optional[Dict[str, list]] = None,
                 engine=None):
        self.spec = spec
        self.vcfg = vcfg
        self.qrels = qrels
        self.query_ids = list(queries)
        self.query_texts = [queries[q] for q in self.query_ids]
        sampler = sampler or FullCorpus()
        self.sampler_name = sampler.name
        self.subset: SubsetResult = sampler.sample(list(corpus), baseline_run,
                                                   qrels)
        self.doc_ids = self.subset.doc_ids
        self.doc_texts = [corpus[d] for d in self.doc_ids]
        self.engine = engine if engine is not None else make_engine(
            spec, self.doc_texts, self.query_texts, engine=vcfg.engine,
            mode=vcfg.mode, k=vcfg.k, impl=vcfg.impl,
            batch_size=vcfg.batch_size, chunk_size=vcfg.chunk_size,
            query_ids=self.query_ids, doc_ids=self.doc_ids,
            per_query=self.subset.per_query, mesh=vcfg.mesh,
            scan_window=vcfg.scan_window, staging=vcfg.staging,
            staging_depth=vcfg.staging_depth,
            token_backing=vcfg.token_backing, mmap_dir=vcfg.mmap_dir,
            token_fingerprint=vcfg.token_fingerprint,
            rerank_block=vcfg.rerank_block)

    # -- one checkpoint ----------------------------------------------------
    def validate_params(self, params, step: int = 0, *,
                        engine=None) -> ValidationResult:
        """Validate one checkpoint.  ``engine`` overrides the pipeline's
        engine for this call only (the AsyncValidator injection path) —
        the pipeline itself is never mutated."""
        v = self.vcfg
        eng = engine or self.engine
        run, scores, timings = eng.run(params)

        names = list(v.metrics)
        if v.mode == "average_rank" and "AverageRank" not in names:
            names.append("AverageRank")
        m = metrics_lib.compute_metrics(run, self.qrels, names)

        if v.write_run and v.output_dir:
            import os
            os.makedirs(v.output_dir, exist_ok=True)
            metrics_lib.write_trec_run(
                f"{v.output_dir}/{v.run_tag}_step{step}.trec", run, scores,
                tag=v.run_tag)

        return ValidationResult(step=step, metrics=m, timings=timings,
                                subset_size=len(self.doc_ids),
                                engine=getattr(eng, "name", ""))


def params_from_checkpoint(state: Any) -> Any:
    """Default extractor: trainer saves {"params":..., "opt_state":...}."""
    return state["params"] if isinstance(state, dict) and "params" in state \
        else state
