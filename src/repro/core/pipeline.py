"""ValidationPipeline — the closed loop the paper's users never implement.

One validation of one checkpoint = encode (subset of) corpus + queries with
the checkpoint's weights, retrieve, score.  Modes:

  * ``retrieval``     — full (or subset) corpus top-k retrieval (paper default)
  * ``rerank``        — RocketQA-style per-query candidate re-ranking
  * ``average_rank``  — DPR-style pooled average-rank validation

The corpus subset is computed ONCE (the sampler depends only on the baseline
run + qrels, not the checkpoint), and the pre-tokenized texts are padded
once — both costs amortize across checkpoints, exactly as the paper's
pre-tokenization argument (§3) prescribes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core import metrics as metrics_lib
from repro.core import retrieval as retrieval_lib
from repro.core.encoder import encode_texts
from repro.core.samplers import FullCorpus, SubsetResult
from repro.models.biencoder import EncoderSpec


@dataclasses.dataclass
class ValidationConfig:
    metrics: tuple = ("MRR@10",)
    mode: str = "retrieval"          # retrieval | rerank | average_rank
    k: int = 100                     # retrieval cut-off
    batch_size: int = 64
    impl: str = "xla"                # xla | pallas
    mesh: Any = None                 # optional sharded retrieval mesh
    write_run: bool = False
    output_dir: Optional[str] = None
    run_tag: str = "asyncval"


@dataclasses.dataclass
class ValidationResult:
    step: int
    metrics: Dict[str, float]
    timings: Dict[str, float]
    subset_size: int


class ValidationPipeline:
    def __init__(self, spec: EncoderSpec, corpus: Dict[str, list],
                 queries: Dict[str, list], qrels: Dict[str, Dict[str, int]],
                 vcfg: ValidationConfig, *, sampler=None,
                 baseline_run: Optional[Dict[str, list]] = None):
        self.spec = spec
        self.vcfg = vcfg
        self.qrels = qrels
        self.query_ids = list(queries)
        self.query_texts = [queries[q] for q in self.query_ids]
        sampler = sampler or FullCorpus()
        self.sampler_name = sampler.name
        self.subset: SubsetResult = sampler.sample(list(corpus), baseline_run,
                                                   qrels)
        self.doc_ids = self.subset.doc_ids
        self.doc_texts = [corpus[d] for d in self.doc_ids]

    # -- one checkpoint ----------------------------------------------------
    def validate_params(self, params, step: int = 0) -> ValidationResult:
        v = self.vcfg
        t0 = time.time()
        c_emb, c_stats = encode_texts(self.spec.encode_passage, params,
                                      self.doc_texts,
                                      max_len=self.spec.p_max_len,
                                      batch_size=v.batch_size)
        t_corpus = time.time() - t0
        t0 = time.time()
        q_emb, _ = encode_texts(self.spec.encode_query, params,
                                self.query_texts,
                                max_len=self.spec.q_max_len,
                                batch_size=v.batch_size)
        t_query = time.time() - t0

        t0 = time.time()
        if v.mode in ("rerank", "average_rank") and self.subset.per_query:
            run, scores = retrieval_lib.rerank_run(
                self.query_ids, q_emb, self.doc_ids, c_emb,
                self.subset.per_query, k=max(v.k, 1000))
        else:
            run, scores = retrieval_lib.retrieve_run(
                self.query_ids, q_emb, self.doc_ids, c_emb, k=v.k,
                impl=v.impl, mesh=v.mesh)
        t_retrieve = time.time() - t0

        names = list(v.metrics)
        if v.mode == "average_rank" and "AverageRank" not in names:
            names.append("AverageRank")
        m = metrics_lib.compute_metrics(run, self.qrels, names)

        if v.write_run and v.output_dir:
            import os
            os.makedirs(v.output_dir, exist_ok=True)
            metrics_lib.write_trec_run(
                f"{v.output_dir}/{v.run_tag}_step{step}.trec", run, scores,
                tag=v.run_tag)

        timings = {"encode_corpus_s": t_corpus, "encode_query_s": t_query,
                   "retrieve_s": t_retrieve,
                   "total_s": t_corpus + t_query + t_retrieve}
        return ValidationResult(step=step, metrics=m, timings=timings,
                                subset_size=len(self.doc_ids))


def params_from_checkpoint(state: Any) -> Any:
    """Default extractor: trainer saves {"params":..., "opt_state":...}."""
    return state["params"] if isinstance(state, dict) and "params" in state \
        else state
