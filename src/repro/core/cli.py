"""The paper's command-line surface (§3), JAX-native.

    python -m repro.core.cli \\
        --query_file q.jsonl --candidate_dir corpus_dir \\
        --ckpts_dir ckpts/ --qrel_file qrels.txt \\
        --q_max_len 32 --p_max_len 128 \\
        --metrics MRR@10 Recall@100 --report_to csv jsonl \\
        --run_name myrun --write_run --output_dir runs/ \\
        --max_num_valid 10 --logging_dir logs/ \\
        --encoder repro.models.biencoder:biencoder_spec_from_cli \\
        --arch dr-bert-base [--watch]

Differences from the torch original, by design (DESIGN.md §2.2):
  * ``--encoder`` names a ``module:function`` returning an
    :class:`~repro.models.biencoder.EncoderSpec` — the pure-function twin
    of subclassing ``asyncval.modelling.Encoder``; ``--arch`` picks a
    registry architecture for the default builder.
  * ``--tokenizer_name_or_path`` is accepted and ignored (corpus/queries
    are pre-tokenized JSONL exactly as the paper prescribes; no HF here).
  * ``--report_to tensorboard|wandb`` map to the CSV/JSONL file reporters.
  * checkpoints are this repo's two-phase-commit directories; ``--watch``
    keeps polling (the paper's async mode) vs one-shot validate-existing
    (the paper's single-GPU mode).
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import importlib
import os
import sys
import time
from typing import Optional

from repro.core.registry import (ENCODERS, ENGINES, IMPLS, MODES, SAMPLERS,
                                 ensure_builtins, register_encoder)


@register_encoder("arch")
def _arch_encoder(args):
    """Default builder: a ``--arch`` registry architecture wrapped as a
    bi-encoder.  Third-party encoders register alongside it and are then
    selectable as ``--encoder NAME`` (no ``module:function`` needed)."""
    from repro.configs import registry
    from repro.models.biencoder import biencoder_spec
    arch = registry.get(args.arch)
    cfg = arch.smoke_config() if args.smoke else arch.full_config()
    return biencoder_spec(cfg, q_max_len=args.q_max_len,
                          p_max_len=args.p_max_len)


def build_encoder(args):
    if args.encoder:
        if ":" in args.encoder:            # module:function -> EncoderSpec
            mod_name, fn_name = args.encoder.split(":")
            fn = getattr(importlib.import_module(mod_name), fn_name)
            return fn(args)
        return ENCODERS.get(args.encoder)(args)   # registered encoder name
    return ENCODERS.get("arch")(args)


def load_texts(paths):
    from repro.data.corpus import read_jsonl
    out = {}
    for p in paths:
        out.update(read_jsonl(p))
    return out


def _obs_finish(args, tel) -> None:
    """Flush the trace buffer and emit the ``--obs_report`` /
    ``--obs_metrics`` outputs: the registry summary table plus the
    headline checkpoint-to-verdict latency percentiles."""
    if tel is None:
        return
    tel.flush()
    if args.obs_metrics:
        tel.metrics.dump(args.obs_metrics)
    if args.obs_report:
        from repro.core.validator import CKPT_TO_VERDICT_METRIC
        print(tel.metrics.render())
        hist = tel.metrics.get(CKPT_TO_VERDICT_METRIC)
        if hist is not None and hist.count:
            print(f"[obs] checkpoint-to-verdict: "
                  f"p50={hist.percentile(50):.3f}s "
                  f"p99={hist.percentile(99):.3f}s "
                  f"over {hist.count} verdicts")
        else:
            print("[obs] checkpoint-to-verdict: no verdicts observed")


def _worker_main(args, suite, logger, ledger_path) -> int:
    """Fleet worker mode (``--worker``): claim (step, task) units from the
    shared ledger work queue until the backlog drains (or forever, with
    ``--watch``).

    Any worker may also DISCOVER checkpoints and publish their units —
    publishing is idempotent, so a fleet of bare CLI workers needs no
    dedicated supervisor (``repro.launch.fleet`` provides one that
    additionally runs the control plane)."""
    import jax

    from repro.core.validator import ValidationLedger, ValidatorWorker
    from repro.core.watcher import CheckpointWatcher
    from repro.core.workqueue import WorkQueue, parse_capabilities

    caps = parse_capabilities(args.capabilities)
    caps.setdefault("mesh_size", jax.device_count())
    worker_id = args.worker_id or f"worker-{os.getpid()}"
    # the worker's telemetry rides in on the suite's ValidationConfig (set
    # in main()); every hook below shares its registry and trace file
    tel = getattr(suite.vcfg, "telemetry", None)
    queue = WorkQueue(ledger_path, worker_id, capabilities=caps,
                      lease_ttl=args.lease_ttl,
                      max_abandons=args.max_abandons, telemetry=tel)
    spool = None
    if args.handoff_spool:
        from repro.handoff import SnapshotSpool
        spool = SnapshotSpool(args.handoff_spool)
    worker = ValidatorWorker(
        args.ckpts_dir, suite,
        ledger=ValidationLedger(ledger_path,
                                expected_tasks=suite.task_names,
                                telemetry=tel),
        queue=queue, logger=logger, worker_id=worker_id, telemetry=tel,
        snapshots=spool)
    watcher = CheckpointWatcher(args.ckpts_dir, telemetry=tel)
    print(f"[asyncval] worker {worker_id} caps={caps} queue={ledger_path}",
          file=sys.stderr)
    done = 0
    try:
        while True:
            if spool is not None:
                # pre-durable snapshots publish their units immediately;
                # the (step, task) key dedupes against the later watcher
                # discovery in the queue fold itself
                for step in spool.poll():
                    queue.publish(suite.plan_units(step), source="snapshot")
                    watcher.mark_seen(step)
            for step in watcher.poll():
                queue.publish(suite.plan_units(step))
            if worker.run_once():
                unit = worker.completed[-1]
                done += 1
                print(f"[asyncval] {worker_id} completed step {unit.step} "
                      f"task {unit.task}", file=sys.stderr)
                continue
            state = queue.refresh()
            if not args.watch and not state.claimable(caps) \
                    and not state.blocked():
                break               # backlog drained, nothing in flight
            time.sleep(args.poll_interval if args.watch else 0.05)
    except KeyboardInterrupt:
        pass
    print(f"[asyncval] worker {worker_id}: {done} units, "
          f"{len(worker.errors)} errors", file=sys.stderr)
    _obs_finish(args, tel)
    return 0 if not worker.errors else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.core.cli")
    ap.add_argument("--query_file", nargs="+", required=True)
    ap.add_argument("--candidate_dir", required=True)
    ap.add_argument("--ckpts_dir", required=True)
    ap.add_argument("--tokenizer_name_or_path", default=None,
                    help="accepted for CLI compatibility; unused "
                         "(inputs are pre-tokenized)")
    ap.add_argument("--q_max_len", type=int, default=32)
    ap.add_argument("--p_max_len", type=int, default=128)
    ap.add_argument("--qrel_file", required=True)
    ap.add_argument("--run_name", default="asyncval")
    ap.add_argument("--write_run", action="store_true")
    ap.add_argument("--output_dir", default="asyncval_out")
    ap.add_argument("--max_num_valid", type=int, default=None)
    ap.add_argument("--logging_dir", default=None)
    ap.add_argument("--metrics", nargs="+", default=["MRR@10"])
    ap.add_argument("--report_to", nargs="+", default=["csv"],
                    choices=["csv", "jsonl", "tensorboard", "wandb"])
    ap.add_argument("--batch_size", type=int, default=64)
    ap.add_argument("--engine", default="streaming",
                    help="validation data path: 'streaming' fused "
                         "encode->top-k (default), 'materialized' legacy "
                         "encode-all-then-retrieve, or any "
                         "@register_engine name (validated against the "
                         "registry right after parsing)")
    ap.add_argument("--impl", default="xla",
                    help="retrieval top-k implementation: 'xla' (default), "
                         "'pallas' (the chunk-carry kernel), or any "
                         "@register_impl name")
    ap.add_argument("--chunk_size", type=int, default=None,
                    help="streaming chunk rows (default: batch_size)")
    ap.add_argument("--scan_window", type=int, default=8,
                    help="chunks folded per dispatch in the streaming "
                         "engine's scan-window fast path")
    ap.add_argument("--staging", default="double_buffered",
                    choices=["double_buffered", "sync"],
                    help="host->device chunk staging: overlap the copy of "
                         "chunk i+1 with chunk i's compute (default) or "
                         "copy synchronously")
    ap.add_argument("--staging_depth", type=int, default=2,
                    help="prefetch depth of the staging pipeline: 2 "
                         "(default) is the classic double buffer; deeper "
                         "values keep more device_puts in flight to hide "
                         "the burstier latency of remote-storage (S3/GCS-"
                         "backed mmap) TokenStores, at O(depth x chunk) "
                         "host token memory")
    ap.add_argument("--token_backing", default="memory",
                    choices=["memory", "mmap"],
                    help="TokenStore backing: host RAM (default) or "
                         "memory-mapped files for corpora whose tokens "
                         "exceed host RAM")
    ap.add_argument("--mmap_dir", default=None,
                    help="cache dir for --token_backing mmap (default: "
                         "<output_dir>/token_cache); built once, reused "
                         "across checkpoints and restarts")
    ap.add_argument("--token_fingerprint", default="fast",
                    choices=["fast", "full"],
                    help="mmap cache key: 'fast' (default) is O(1) in "
                         "corpus size but misses in-place mutations of the "
                         "corpus middle; 'full' hashes every text so any "
                         "mutation rebuilds the cache")
    ap.add_argument("--rerank_block", type=int, default=None,
                    help="materialized rerank only: queries per candidate-"
                         "embedding gather block — peak gather memory is "
                         "O(rerank_block x Cmax x D) instead of "
                         "O(Q x Cmax x D), bit-identical results (default: "
                         "auto-sized from a 256 MiB budget)")
    ap.add_argument("--fp16", action="store_true",
                    help="bf16 compute (TPU-native half precision)")
    ap.add_argument("--score_dtype", default="f32",
                    choices=["f32", "bf16", "int8"],
                    help="scoring precision of the MIPS/rerank data path: "
                         "'f32' (default, bit-for-bit legacy), 'bf16' "
                         "(inputs cast to bf16, f32 MXU accumulation — "
                         "half the embedding bytes, ~2x MXU throughput) or "
                         "'int8' (symmetric per-row quantization, exact "
                         "int32 accumulation — quarter the bytes).  "
                         "Precision is a FIDELITY knob like --depth subset "
                         "sampling: it is recorded in every ledger row and "
                         "control event, and benchmarks/bench_fidelity.py "
                         "sweeps its rank correlation vs the f32 full run")
    ap.add_argument("--mode", default="retrieval",
                    help="'retrieval' (default), 'rerank', 'average_rank', "
                         "or any @register_mode name")
    ap.add_argument("--sampler", default="auto",
                    help="corpus subset strategy (default 'auto': inferred "
                         "from --mode/--depth exactly as before); any "
                         "@register_sampler name is selectable ('full', "
                         "'run_topk', 'qrel_pool', 'random', "
                         "'rerank_topk', ...), with --depth as its subset "
                         "depth")
    ap.add_argument("--depth", type=int, default=0,
                    help="subset depth (0 = full corpus); needs --run_file")
    ap.add_argument("--run_file", default=None,
                    help="baseline TREC run for subset sampling")
    ap.add_argument("--retrieve_k", type=int, default=100)
    ap.add_argument("--encoder", default=None,
                    help="module:function -> EncoderSpec")
    ap.add_argument("--arch", default="dr-bert-base")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--watch", action="store_true",
                    help="keep polling for new checkpoints (async mode)")
    ap.add_argument("--poll_interval", type=float, default=5.0)
    ap.add_argument("--handoff_spool", default=None,
                    help="lazy snapshot hand-off: also validate pre-durable "
                         "param snapshots a trainer spills to this "
                         "directory (point it at the trainer's "
                         "--handoff-spool, e.g. under /dev/shm) — verdicts "
                         "land before the durable checkpoint commits, "
                         "bit-identical to durable-restore validation; the "
                         "--ckpts_dir watcher stays the fallback")
    # -- validator fleet (repro.core.workqueue) -----------------------------
    ap.add_argument("--worker", action="store_true",
                    help="fleet worker mode: claim (step, task) work units "
                         "from the shared ledger work queue instead of "
                         "validating whole checkpoints — run N of these "
                         "against one --ckpts_dir + ledger to scale "
                         "validation out (see repro.launch.fleet for a "
                         "supervisor that also runs the control plane)")
    ap.add_argument("--worker_id", default=None,
                    help="this worker's name in claim records and ledger "
                         "rows (default: worker-<pid>)")
    ap.add_argument("--capabilities", default="",
                    help="capability tags matched against unit requirements"
                         ", as 'name=value,...' (e.g. 'mesh_size=8,"
                         "max_depth=100'); mesh_size defaults to the "
                         "process's jax.device_count()")
    ap.add_argument("--lease_ttl", type=int, default=16,
                    help="claim lease time-to-live in ledger RECORDS (not "
                         "seconds — no wall clock feeds fleet decisions); "
                         "must match across the fleet")
    ap.add_argument("--max_abandons", type=int, default=2,
                    help="distributed retry budget: abandons of one unit "
                         "before the fleet marks it failed; must match "
                         "across the fleet")
    # -- convergence control plane (repro.control) --------------------------
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "latest_first", "stride", "budget"],
                    help="checkpoint scheduling: validate every checkpoint "
                         "in order (fifo), only the newest (latest_first), "
                         "every --stride-th step (stride), or let the "
                         "budget policy adapt the stride automatically from "
                         "observed validation latency vs checkpoint cadence "
                         "(queue depth) so staleness stays bounded")
    ap.add_argument("--stride", type=int, default=1,
                    help="step modulus for --policy stride")
    ap.add_argument("--keep_top_k", type=int, default=0,
                    help="quality-aware checkpoint GC: after each "
                         "validation keep only the top-k checkpoints by the "
                         "control metric plus anything not yet validated "
                         "(0 = GC disabled, keep everything)")
    ap.add_argument("--ema", type=float, default=0.0,
                    help="EMA smoothing factor for the selection metric "
                         "(0 = raw values; 0<ema<1 de-noises subset "
                         "validation before ranking/early-stop decisions)")
    ap.add_argument("--early_stop", action="store_true",
                    help="enable asynchronous early stopping: when the "
                         "control metric plateaus, an atomic STOP marker "
                         "file is published for the trainer to poll "
                         "(training halts without ever blocking on "
                         "validation)")
    ap.add_argument("--early_stop_metric", default=None,
                    help="control-plane metric spec (default: first "
                         "--metrics entry; AverageRank is minimized, others "
                         "maximized).  Accepts composite specs over a "
                         "multi-task suite: 'task:metric' or a weighted "
                         "'0.5*a:MRR@10 + 0.5*b:MRR@10' aggregate")
    ap.add_argument("--early_stop_patience", type=int, default=3,
                    help="evaluations without >= --early_stop_min_delta "
                         "improvement before stopping")
    ap.add_argument("--early_stop_min_delta", type=float, default=0.0,
                    help="improvement below this counts as a plateau "
                         "evaluation")
    ap.add_argument("--early_stop_window", type=int, default=0,
                    help="history-based overfit detector: sliding window "
                         "(>= 3) over which a worsening validation trend "
                         "with a still-improving train loss triggers a "
                         "stop; needs a train-loss feed, so it only "
                         "activates in-process (repro.launch.train), not in "
                         "this validator-only CLI (0 = off)")
    ap.add_argument("--stop_file", default=None,
                    help="STOP marker path (default: <logging_dir>/STOP)")
    # -- retrieval serving tier (repro.serve) -------------------------------
    ap.add_argument("--serve", action="store_true",
                    help="serve queries against promoted checkpoints "
                         "through the validator's exact scoring path: "
                         "one-shot mode answers --query_file once after "
                         "validation; --watch keeps a promoter hot-"
                         "swapping the live index on every control-plane "
                         "'select' (zero downtime, and the serving "
                         "checkpoint is GC-protected)")
    ap.add_argument("--serve_k", type=int, default=10,
                    help="results per served query")
    ap.add_argument("--serve_batch", type=int, default=8,
                    help="query micro-batch size (one fixed-shape "
                         "compiled encode program)")
    ap.add_argument("--serve_flush_ms", type=float, default=4.0,
                    help="max-latency flush for partial micro-batches")
    ap.add_argument("--serve_pending", type=int, default=256,
                    help="admission bound on in-flight requests (beyond "
                         "it submits fail fast instead of queueing)")
    ap.add_argument("--serve_events", default=None,
                    help="replayable swap-event JSONL (default: "
                         "<logging_dir>/<run_name>_serve.jsonl)")
    # -- checkpoint-lifecycle telemetry (repro.obs) --------------------------
    ap.add_argument("--obs_trace", default=None,
                    help="append lifecycle spans/events to this JSONL trace "
                         "file (monotonic-clock; export to Chrome/Perfetto "
                         "with python -m repro.obs.export)")
    ap.add_argument("--obs_report", action="store_true",
                    help="print the metrics-registry summary table at exit "
                         "(checkpoint-to-verdict p50/p99, discovery lag, "
                         "staging idle ratio, fleet/serve counters)")
    ap.add_argument("--obs_metrics", default=None,
                    help="dump the metrics-registry snapshot as JSON to "
                         "this path at exit")
    ap.add_argument("--ensemble_top_k", type=int, default=0,
                    help="after validation ends, greedy-soup the top-k "
                         "checkpoints by the control metric into a virtual "
                         "checkpoint, commit it via two-phase ckpt.save and "
                         "re-validate it through the normal path (0 = off)")
    args = ap.parse_args(argv)

    # component names validate against the registries immediately after
    # parsing, BEFORE any corpus IO: a typo fails instantly with the
    # registered alternatives (+ did-you-mean) listed.  Deferring this past
    # parse_args keeps --help and argparse usage errors free of the heavy
    # jax import the component modules pull in.
    ensure_builtins()
    for reg, value in ((ENGINES, args.engine), (IMPLS, args.impl),
                       (MODES, args.mode)):
        try:
            reg.get(value)
        except ValueError as e:
            ap.error(str(e))
    if args.sampler != "auto":
        try:
            SAMPLERS.get(args.sampler)
        except ValueError as e:
            ap.error(str(e))

    # sampler choice + its run-file dependency, at parse time, BEFORE any
    # corpus IO: run-subsetting samplers without --run_file would otherwise
    # fail deep in .sample() after the whole corpus had been loaded.
    # (--sampler random / qrel_pool use --depth without a run file.)
    if args.sampler != "auto":
        chosen_sampler = args.sampler
    elif args.mode == "rerank":
        chosen_sampler = "rerank_topk"
    elif args.mode == "average_rank":
        chosen_sampler = "qrel_pool"
    else:
        chosen_sampler = "run_topk" if args.depth else "full"
    if chosen_sampler in ("run_topk", "rerank_topk") and not args.run_file:
        ap.error(f"sampler {chosen_sampler!r} subsets from a baseline run "
                 "(--depth picks its depth); pass --run_file")

    # control-metric spec validation at parse time, BEFORE any corpus IO: a
    # typo'd metric or an alien task name in a composite spec would
    # otherwise KeyError inside every controller invocation, silently
    # disabling GC/early-stop/ensembling for the whole run.
    cmetric = None
    if args.keep_top_k or args.early_stop or args.ensemble_top_k:
        from repro.control import MetricSpec
        cmetric = args.early_stop_metric or args.metrics[0]
        computed = set(args.metrics) | ({"AverageRank"}
                                        if args.mode == "average_rank"
                                        else set())
        # this CLI validates one task named "default": bare and
        # default-qualified keys are both addressable
        computed |= {f"default:{m}" for m in set(computed)}
        try:
            spec_keys = MetricSpec.parse(cmetric).keys()
        except ValueError as e:
            ap.error(str(e))
        missing = [k for k in spec_keys if k not in computed]
        if missing:
            ap.error(f"--early_stop_metric {cmetric!r} references "
                     f"{missing} not computed by this run; choose from "
                     f"{sorted(computed)}")

    from repro.core.metrics import read_trec_qrels, read_trec_run
    from repro.core.reporting import CSVLogger, JSONLLogger, MultiLogger
    from repro.core.suite import (ValidationConfig, ValidationSuite,
                                  ValidationTask)
    from repro.core.validator import AsyncValidator
    from repro.core.watcher import BudgetPolicy, Policy

    spec = build_encoder(args)
    corpus = load_texts(sorted(
        glob.glob(os.path.join(args.candidate_dir, "*.json*"))))
    queries = load_texts(args.query_file)
    qrels = read_trec_qrels(args.qrel_file)
    print(f"[asyncval] corpus={len(corpus)} queries={len(queries)} "
          f"qrels={len(qrels)}", file=sys.stderr)

    baseline_run = read_trec_run(args.run_file) if args.run_file else None
    sampler = SAMPLERS.get(chosen_sampler)(depth=args.depth)

    # telemetry is observation only: with none of the --obs_* flags set
    # every path below runs its legacy clock-free code byte-for-byte
    tel = None
    if args.obs_trace or args.obs_report or args.obs_metrics:
        from repro.obs import Telemetry
        tel = Telemetry(args.obs_trace,
                        process=(args.worker_id or f"cli-{os.getpid()}")
                        if args.worker else "cli",
                        attrs={"run": args.run_name})

    mmap_dir = args.mmap_dir
    if args.token_backing == "mmap" and not mmap_dir:
        mmap_dir = os.path.join(args.output_dir, "token_cache")
    vcfg = ValidationConfig(metrics=tuple(args.metrics), mode=args.mode,
                            k=args.retrieve_k, batch_size=args.batch_size,
                            impl=args.impl,
                            engine=args.engine, chunk_size=args.chunk_size,
                            scan_window=args.scan_window,
                            staging=args.staging,
                            staging_depth=args.staging_depth,
                            token_backing=args.token_backing,
                            mmap_dir=mmap_dir,
                            token_fingerprint=args.token_fingerprint,
                            rerank_block=args.rerank_block,
                            score_dtype=args.score_dtype,
                            write_run=args.write_run,
                            output_dir=args.output_dir,
                            run_tag=args.run_name,
                            telemetry=tel)
    # the validator-facing object is a (single-task) ValidationSuite — the
    # CLI validates one task named "default", so its ledger rows, metric
    # names, and control specs are exactly the legacy pipeline's.
    suite = ValidationSuite(spec, [
        ValidationTask("default", corpus, queries, qrels,
                       sampler=sampler, baseline_run=baseline_run),
    ], vcfg)
    # fail fast on deterministic engine-config errors (bad staging depth,
    # broken third-party factory) instead of per-checkpoint swallowing
    suite.build_engines()

    logdir = args.logging_dir or args.output_dir
    loggers = []
    for r in args.report_to:
        if r in ("csv", "tensorboard"):      # tensorboard -> CSV twin
            loggers.append(CSVLogger(os.path.join(
                logdir, f"{args.run_name}_metrics.csv")))
        else:                                # wandb -> JSONL twin
            loggers.append(JSONLLogger(os.path.join(
                logdir, f"{args.run_name}_metrics.jsonl")))
    policy = BudgetPolicy() if args.policy == "budget" \
        else Policy(kind=args.policy, stride=args.stride)

    if args.worker:
        return _worker_main(args, suite, MultiLogger(*loggers),
                            os.path.join(logdir,
                                         f"{args.run_name}_ledger.jsonl"))

    control = None
    if cmetric is not None:
        from repro.control import ControlConfig, ControlPlane, metric_mode
        ccfg = ControlConfig(
            metric=cmetric,
            mode=metric_mode(cmetric),
            keep_top_k=args.keep_top_k, ema=args.ema,
            early_stop=args.early_stop,
            patience=args.early_stop_patience,
            min_delta=args.early_stop_min_delta,
            overfit_window=args.early_stop_window,
            ensemble_top_k=args.ensemble_top_k)
        stop_path = None
        if args.early_stop:
            stop_path = args.stop_file or os.path.join(logdir, "STOP")
            if os.path.exists(stop_path):
                # stale verdict from a previous session: a trainer polling
                # this path must not halt before we decide anything.
                os.remove(stop_path)
        control = ControlPlane(
            args.ckpts_dir, ccfg, stop_path=stop_path,
            event_path=os.path.join(logdir, f"{args.run_name}_control.jsonl"),
            telemetry=tel)

    serve = None
    if args.serve:
        from repro.serve import (AdmissionController, IndexBuilder,
                                 Promoter, QueryService, ServeConfig)
        # the serving tier reuses the validator's exact scoring knobs —
        # same score_dtype, same impl, same token-store geometry — so the
        # answers it hands out are bitwise the numbers the ledger records
        scfg = ServeConfig(k=args.serve_k, score_dtype=args.score_dtype,
                           impl=args.impl, batch_size=args.batch_size,
                           chunk_size=args.chunk_size,
                           max_batch=args.serve_batch,
                           flush_ms=args.serve_flush_ms,
                           max_pending=args.serve_pending,
                           token_backing=args.token_backing,
                           mmap_dir=mmap_dir,
                           token_fingerprint=args.token_fingerprint)
        serve_service = QueryService(
            spec, k=args.serve_k, max_batch=args.serve_batch,
            flush_ms=args.serve_flush_ms,
            admission=AdmissionController(args.serve_pending),
            telemetry=tel)
        serve_promoter = Promoter(
            IndexBuilder(spec, corpus, scfg), serve_service,
            args.ckpts_dir, telemetry=tel,
            # in-process control plane: promote its live best pick; without
            # one, follow the latest committed checkpoint (promoter default)
            target_fn=((lambda: control.selector.best_step)
                       if control is not None else None),
            log=args.serve_events or os.path.join(
                logdir, f"{args.run_name}_serve.jsonl"))
        serve = (serve_service, serve_promoter)

    snapshots = None
    if args.handoff_spool:
        from repro.handoff import SnapshotSpool
        snapshots = SnapshotSpool(args.handoff_spool)
    validator = AsyncValidator(
        args.ckpts_dir, suite, logger=MultiLogger(*loggers),
        policy=policy, controller=control,
        max_num_valid=args.max_num_valid,
        ledger_path=os.path.join(logdir, f"{args.run_name}_ledger.jsonl"),
        poll_interval_s=args.poll_interval,
        telemetry=tel,
        # pre-durable snapshots spilled by a --handoff trainer validate
        # ahead of their checkpoint's COMMIT; watcher stays the fallback
        snapshots=snapshots,
        # quality GC must never delete the checkpoint backing the live
        # (or mid-promotion) serving index
        extra_protect=serve[1].protect_set if serve is not None else None)
    if control is not None:
        # restart: warm the ranking from the prior session's ledger rows —
        # old steps are never re-validated (idempotency), and a cold
        # selector would GC the previous session's best checkpoints.
        control.rehydrate(validator.ledger.rows(),
                          expected_tasks=suite.task_names)

    if args.watch:
        print("[asyncval] watching", args.ckpts_dir, file=sys.stderr)
        try:
            while args.max_num_valid is None \
                    or len(validator.results) < args.max_num_valid:
                n = validator.validate_pending()
                if n:
                    for r in validator.results[-n:]:
                        print(f"[asyncval] step {r.step}: "
                              f"{getattr(r, 'log_metrics', r.metrics)} "
                              f"({r.timings['total_s']:.1f}s)")
                if serve is not None and serve[1].poll_once():
                    # zero-downtime promotion: old index answered every
                    # query while this build/verify ran
                    print(f"[serve] hot-swap -> step "
                          f"{serve[0].live_step()}", file=sys.stderr)
                if control is not None and control.stopped and n == 0:
                    # trainer-side STOP is published; the backlog is drained
                    print("[asyncval] early stop "
                          f"({control.earlystop.reason}) — exiting watch",
                          file=sys.stderr)
                    break
                time.sleep(args.poll_interval)
        except KeyboardInterrupt:
            pass
    else:
        validator.validate_all_existing()
        for r in validator.results:
            print(f"[asyncval] step {r.step}: "
                  f"{getattr(r, 'log_metrics', r.metrics)} "
                  f"({r.timings['total_s']:.1f}s)")

    if serve is not None:
        serve_service, serve_promoter = serve
        serve_promoter.poll_once()       # one-shot: promote the final pick
        if serve_service.live is None:
            print("[serve] no promotable checkpoint; skipping serve pass",
                  file=sys.stderr)
        else:
            resp = serve_service.answer(sorted(queries.items()))
            lat = sorted(r.latency_s for r in resp)
            p50 = lat[len(lat) // 2] * 1e3
            p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3
            print(f"[serve] answered {len(resp)} queries: "
                  f"p50={p50:.2f}ms p99={p99:.2f}ms "
                  f"step={serve_service.live_step()}")

    if control is not None and args.ensemble_top_k:
        from repro.control import MetricSpec
        cspec = MetricSpec.parse(control.cfg.metric)
        # scoring passes must not write TREC runs: each soup candidate would
        # otherwise clobber the real step-0 checkpoint's run file
        vstep = control.build_ensemble(
            lambda p: cspec.value(
                suite.validate_params(p, write_runs=False).metrics))
        if vstep is not None:
            # score the soup through the normal restore->pipeline->ledger
            # path, bypassing the watcher policy (under stride/budget the
            # soup's step id may never be policy-selected).
            validator.validate_step(vstep)
            res = next((r for r in validator.results if r.step == vstep),
                       None)
            if res is not None:
                print(f"[asyncval] ensemble step {vstep} "
                      f"(soup of {control.ensemble_members}): "
                      f"{getattr(res, 'log_metrics', res.metrics)}")
    _obs_finish(args, tel)
    return 0 if not validator.errors else 1


if __name__ == "__main__":
    sys.exit(main())
