"""IR evaluation metrics for checkpoint validation (paper §3 ``--metrics``).

A *run* is ``{qid: [docid, ...]}`` (rank order); *qrels* is
``{qid: {docid: gain}}`` (TREC format, gain >= 1 means relevant).

Supported metric strings (paper default is MRR@10 on MS MARCO):
  MRR@k, Recall@k, nDCG@k, Success@k, AverageRank (the DPR §2 strategy:
  mean rank of the first gold within the candidate pool; lower = better).
"""

from __future__ import annotations

import math
import re
from typing import Dict, List

Run = Dict[str, List[str]]
Qrels = Dict[str, Dict[str, int]]

_METRIC_RE = re.compile(r"^(MRR|Recall|nDCG|Success)@(\d+)$|^(AverageRank)$")


def parse_metric(name: str):
    m = _METRIC_RE.match(name)
    if not m:
        raise ValueError(f"unknown metric {name!r}")
    if m.group(3):
        return ("AverageRank", None)
    return (m.group(1), int(m.group(2)))


def _relevant(qrels: Qrels, qid: str) -> set:
    return {d for d, g in qrels.get(qid, {}).items() if g > 0}


def mrr_at_k(run: Run, qrels: Qrels, k: int) -> float:
    total, n = 0.0, 0
    for qid, docs in run.items():
        rel = _relevant(qrels, qid)
        if not rel:
            continue
        n += 1
        for rank, d in enumerate(docs[:k], start=1):
            if d in rel:
                total += 1.0 / rank
                break
    return total / max(n, 1)


def recall_at_k(run: Run, qrels: Qrels, k: int) -> float:
    total, n = 0.0, 0
    for qid, docs in run.items():
        rel = _relevant(qrels, qid)
        if not rel:
            continue
        n += 1
        total += len(rel.intersection(docs[:k])) / len(rel)
    return total / max(n, 1)


def success_at_k(run: Run, qrels: Qrels, k: int) -> float:
    total, n = 0.0, 0
    for qid, docs in run.items():
        rel = _relevant(qrels, qid)
        if not rel:
            continue
        n += 1
        total += 1.0 if rel.intersection(docs[:k]) else 0.0
    return total / max(n, 1)


def ndcg_at_k(run: Run, qrels: Qrels, k: int) -> float:
    total, n = 0.0, 0
    for qid, docs in run.items():
        gains = qrels.get(qid, {})
        if not any(g > 0 for g in gains.values()):
            continue
        n += 1
        dcg = sum((2 ** gains.get(d, 0) - 1) / math.log2(r + 1)
                  for r, d in enumerate(docs[:k], start=1))
        ideal = sorted((g for g in gains.values() if g > 0), reverse=True)[:k]
        idcg = sum((2 ** g - 1) / math.log2(r + 1)
                   for r, g in enumerate(ideal, start=1))
        total += dcg / idcg if idcg > 0 else 0.0
    return total / max(n, 1)


def average_rank(run: Run, qrels: Qrels) -> float:
    """DPR-style: mean rank (1-based) of the first relevant doc; queries whose
    gold is absent from the candidate list count as rank len(list)+1."""
    total, n = 0.0, 0
    for qid, docs in run.items():
        rel = _relevant(qrels, qid)
        if not rel:
            continue
        n += 1
        rank = len(docs) + 1
        for r, d in enumerate(docs, start=1):
            if d in rel:
                rank = r
                break
        total += rank
    return total / max(n, 1)


def compute_metrics(run: Run, qrels: Qrels, names: List[str]) -> Dict[str, float]:
    out = {}
    for name in names:
        kind, k = parse_metric(name)
        if kind == "MRR":
            out[name] = mrr_at_k(run, qrels, k)
        elif kind == "Recall":
            out[name] = recall_at_k(run, qrels, k)
        elif kind == "nDCG":
            out[name] = ndcg_at_k(run, qrels, k)
        elif kind == "Success":
            out[name] = success_at_k(run, qrels, k)
        else:
            out[name] = average_rank(run, qrels)
    return out


def write_trec_run(path: str, run: Run, scores=None, tag: str = "asyncval"):
    """TREC 6-column run file (paper's --write_run)."""
    with open(path, "w") as f:
        for qid, docs in run.items():
            for rank, d in enumerate(docs, start=1):
                s = scores[qid][rank - 1] if scores else 1.0 / rank
                f.write(f"{qid} Q0 {d} {rank} {s:.6f} {tag}\n")


def read_trec_run(path: str) -> Dict[str, List[tuple]]:
    """Returns {qid: [(docid, score) ...]} sorted by score desc."""
    runs: Dict[str, list] = {}
    with open(path) as f:
        for line in f:
            parts = line.split()
            if len(parts) < 6:
                continue
            qid, _, did, _, score = parts[:5]
            runs.setdefault(qid, []).append((did, float(score)))
    return {q: sorted(v, key=lambda x: -x[1]) for q, v in runs.items()}


def read_trec_qrels(path: str) -> Qrels:
    qrels: Qrels = {}
    with open(path) as f:
        for line in f:
            parts = line.split()
            if len(parts) < 4:
                continue
            qid, _, did, gain = parts[:4]
            qrels.setdefault(qid, {})[did] = int(gain)
    return qrels
