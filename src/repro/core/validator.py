"""AsyncValidator — the paper's contribution: validation decoupled from training.

Runs on its own mesh/pod (here: its own thread), watches the checkpoint
directory, validates every new committed checkpoint, and reports metrics.
Training NEVER blocks on it.

Crash tolerance (beyond-paper, required at scale): every completed validation
is appended to a ledger file; on restart the validator skips ledgered steps,
making validation idempotent.  The ledger also feeds checkpoint GC
protection (a checkpoint is deletable only once validated).
"""

from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, \
    Tuple

from repro.ckpt import checkpoint as ckpt
from repro.core.jsonl import append_jsonl_atomic, read_jsonl_tolerant
from repro.core.reporting import BaseLogger
from repro.core.suite import (SuiteResult, ValidationResult,
                              params_from_checkpoint)
from repro.core.watcher import CheckpointWatcher, Policy
from repro.core.workqueue import WorkQueue, WorkUnit

CKPT_TO_VERDICT_METRIC = "validate.ckpt_to_verdict_s"


class ErrorRing:
    """Bounded fault list — a drop-in for the validator's ``errors``.

    A long-running fleet worker that keeps hitting a poisoned unit would
    grow an unbounded ``List[tuple]``; this ring keeps the newest
    ``maxlen`` faults and counts the overflow in ``dropped`` (mirrored to
    the ``validator.errors_dropped`` counter when telemetry is bound).
    Supports the list surface existing callers use: ``append``, ``len``,
    iteration, indexing, and truthiness."""

    def __init__(self, maxlen: int = 256):
        self.maxlen = int(maxlen)
        self.dropped = 0
        self._ring: collections.deque = collections.deque(maxlen=self.maxlen)
        self._counter = None            # repro.obs.metrics.Counter, if bound

    def bind_counter(self, counter) -> None:
        if self.dropped and counter is not None:
            counter.inc(self.dropped)   # count drops from before binding
        self._counter = counter

    def append(self, item) -> None:
        if len(self._ring) == self.maxlen:
            self.dropped += 1
            if self._counter is not None:
                self._counter.inc()
        self._ring.append(item)

    def clear(self) -> None:
        self._ring.clear()

    def __len__(self) -> int:
        return len(self._ring)

    def __iter__(self) -> Iterator:
        return iter(list(self._ring))

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._ring)[i]
        return self._ring[i]

    def __bool__(self) -> bool:
        return bool(self._ring)

    def __repr__(self) -> str:
        return (f"ErrorRing({list(self._ring)!r}, maxlen={self.maxlen}, "
                f"dropped={self.dropped})")


class ValidationLedger:
    """Append-only record of validated (step, task) pairs (idempotent
    restarts).

    Schema v2: one JSONL row per (step, task) — a multi-task
    :class:`~repro.core.suite.ValidationSuite` appends one row per task for
    every checkpoint pass.  Schema-v1 rows (no ``"task"`` key) migrate on
    load as task ``"default"``, so pre-suite ledgers load and replay
    identically.

    ``expected_tasks`` (the suite's task names, wired by the validator)
    defines step completion: a step counts as validated only when EVERY
    expected task has a row — a crash between task rows re-validates the
    step instead of silently dropping the missing tasks.  Without it, any
    row completes the step (v1 semantics).

    Crash tolerance: a process killed mid-append leaves a torn final line;
    load ignores exactly that (the unledgered step is simply re-validated).
    A torn line anywhere ELSE means real corruption and still raises.

    Fleet sibling records: a validator fleet stores its work-queue claim
    protocol in this SAME file as ``"kind"``-keyed sibling records —
    ``unit`` / ``claim`` / ``renew`` / ``complete`` / ``abandon`` /
    ``tick`` (full schema documented in :mod:`repro.core.workqueue`).
    Result rows never carry a ``"kind"`` key, so this loader (and every
    pre-fleet consumer) skips claim records by that single test; a
    solo validator writes none, keeping its ledger byte-identical to the
    pre-fleet format.  Fleet rows additionally carry ``"worker_id"``
    attribution — omitted when empty, so solo rows are unchanged.

    Concurrency-safe: the control plane (selector / early-stop / GC) reads
    this ledger from the validator thread while ``record`` may run — a lock
    guards the row state, appends are flushed + fsync'd so no consumer (in
    this process or a crash-restarted one) can observe a torn row, and
    :meth:`rows` hands out a snapshot instead of live dicts."""

    def __init__(self, path: Optional[str],
                 expected_tasks: Optional[Sequence[str]] = None,
                 telemetry=None):
        self.path = path
        self.expected_tasks: Optional[Tuple[str, ...]] = \
            tuple(expected_tasks) if expected_tasks is not None else None
        # observation only: a `recorded` span around each fsync'd append.
        # The ledger's bytes are identical with telemetry on or off.
        self.telemetry = telemetry
        self._lock = threading.Lock()
        self._rows: List[dict] = []                    # record order
        self._index: Dict[Tuple[int, str], int] = {}   # (step, task) -> row
        self._by_step: Dict[int, set] = {}             # step -> task names
        self._torn_offset: Optional[int] = None
        if path and os.path.exists(path):
            # torn FINAL line (crash mid-append) is dropped — that step
            # simply re-validates; interior corruption raises.  Loading
            # never mutates the file (an audit may be reading a live
            # ledger); the fragment is truncated just before OUR first
            # append, by the writer that owns the file.
            rows, self._torn_offset = read_jsonl_tolerant(path,
                                                          kind="ledger row")
            for rec in rows:
                # fleet claim records (see repro.core.workqueue) live in the
                # same file as sibling record types; only kind-less rows are
                # validation results
                if "kind" not in rec:
                    self._ingest(rec)

    def _ingest(self, rec: dict) -> None:
        step = int(rec["step"])
        task = str(rec.get("task", "default"))    # v1 rows migrate here
        rec = {**rec, "step": step, "task": task}
        key = (step, task)
        if key in self._index:
            # re-record (a partially-recorded step re-validated after a
            # crash): supersede the stale row and append the fresh one at
            # the END, where its sibling task rows land too — replay groups
            # CONSECUTIVE same-step rows into one observation, so the
            # re-validated step must appear as one fresh consecutive block,
            # exactly when the online decision was made.
            self._rows[self._index[key]] = None
        self._index[key] = len(self._rows)
        self._rows.append(rec)
        self._by_step.setdefault(step, set()).add(task)

    def _completed(self, step: int) -> bool:
        tasks = self._by_step.get(step)
        if not tasks:
            return False
        if self.expected_tasks is None:
            return True                           # v1 semantics: any row
        return all(t in tasks for t in self.expected_tasks)

    def completed(self, step: int) -> bool:
        """True when every expected task has a row for ``step``."""
        with self._lock:
            return self._completed(step)

    def __contains__(self, step: int) -> bool:
        return self.completed(step)

    def tasks_for(self, step: int) -> List[str]:
        with self._lock:
            return sorted(self._by_step.get(step, ()))

    @property
    def validated_steps(self) -> List[int]:
        with self._lock:
            return sorted(s for s in self._by_step if self._completed(s))

    def rows(self) -> List[dict]:
        """Snapshot of all live rows in RECORD order (the order decisions
        were made in — offline replay of the control plane depends on it).
        Rows superseded by a re-record are omitted."""
        with self._lock:
            return [dict(rec) for rec in self._rows if rec is not None]

    def record(self, result) -> None:
        """Append one row per task: a :class:`SuiteResult` contributes every
        task's row (consecutively, so replay groups them back into one
        observation); a plain :class:`ValidationResult` contributes its own
        (task ``"default"`` unless set)."""
        results = list(result.tasks.values()) \
            if isinstance(result, SuiteResult) or hasattr(result, "tasks") \
            else [result]
        recs = []
        for r in results:
            rec = {"step": r.step,
                   "task": str(getattr(r, "task", "default")),
                   "metrics": r.metrics, "timings": r.timings,
                   "subset_size": r.subset_size,
                   # which data path scored this step — lets a cross-mode
                   # parity audit (streaming vs materialized vs sharded)
                   # attribute every ledger row long after the run.
                   "engine": getattr(r, "engine", ""),
                   # scoring precision of the row, recorded like `engine` so
                   # replay_ledger and cross-precision audits work offline.
                   "score_dtype": str(getattr(r, "score_dtype", "f32"))}
            # fleet provenance: which worker scored the row.  Only present
            # when a worker stamped it — single-process ledgers stay
            # byte-identical to pre-fleet ones.
            wid = str(getattr(r, "worker_id", "") or "")
            if wid:
                rec["worker_id"] = wid
            # hand-off provenance: present only when the row was scored from
            # a pre-durable snapshot (repro.handoff) — durable-restore rows
            # omit the key, so pre-handoff ledgers stay byte-identical.
            hand = str(getattr(r, "handoff", "") or "")
            if hand == "snapshot":
                rec["handoff"] = hand
            recs.append(rec)
        tel = self.telemetry
        with self._lock:
            for rec in recs:
                self._ingest(rec)
            if self.path:
                # atomic multi-writer append: fleet workers share one ledger
                # file, and append_jsonl_atomic also performs the writer-side
                # torn-tail repair the explicit truncate used to do
                self._torn_offset = None
                if tel is None:
                    append_jsonl_atomic(self.path, recs)
                else:
                    with tel.span("recorded", step=recs[0]["step"],
                                  task=recs[0]["task"], n_rows=len(recs)):
                        append_jsonl_atomic(self.path, recs)


class ValidatorWorker:
    """Executes validation work — the fleet's unit of scale.

    One worker = one process (or thread) with its own restore shardings,
    engine override, and capability tags.  Two modes share one execution
    body, so solo and fleet validation are the same code path:

      * **whole-step** (:meth:`run_step`): restore → every suite task →
        ledger rows.  The single-process :class:`AsyncValidator` is a thin
        instantiation over this.
      * **fleet** (:meth:`run_once` / :meth:`run_forever`): claim ONE
        (step, task) unit from the shared :class:`~repro.core.workqueue.
        WorkQueue`, heartbeat the lease while the engine runs, append the
        result row, mark the unit complete.  Failures abandon the unit so a
        peer retries it — the queue's abandon count is the DISTRIBUTED
        retry budget, derived from the ledger, never from worker state.

    ``worker_id`` stamps every ledger row this worker appends (omitted when
    empty, keeping single-process ledgers byte-identical to pre-fleet
    ones)."""

    def __init__(self, ckpt_root: str, pipeline, *,
                 ledger: Optional[ValidationLedger] = None,
                 queue: Optional[WorkQueue] = None,
                 logger: Optional[BaseLogger] = None,
                 params_extractor: Callable = params_from_checkpoint,
                 shardings: Any = None,
                 engine: Any = None,
                 worker_id: str = "",
                 heartbeat_interval_s: float = 0.25,
                 telemetry=None,
                 max_errors: int = 256,
                 snapshots: Any = None):
        self.ckpt_root = ckpt_root
        self.pipeline = pipeline
        self.queue = queue
        # lazy snapshot hand-off source (repro.handoff SnapshotChannel or
        # SnapshotSpool — anything with get(step) -> ParamSnapshot|None):
        # consulted BEFORE the durable restore, so a step can be scored
        # while its ckpt.save is still racing in the background.
        self.snapshots = snapshots
        self.logger = logger
        self.params_extractor = params_extractor
        self.shardings = shardings
        self.engine = engine
        self.worker_id = str(worker_id
                             or (queue.worker_id if queue is not None
                                 else ""))
        self.heartbeat_interval_s = heartbeat_interval_s
        expected = tuple(getattr(pipeline, "task_names", ())
                         or ("default",))
        self.ledger = ledger if ledger is not None \
            else ValidationLedger(None, expected_tasks=expected,
                                  telemetry=telemetry)
        self.telemetry = telemetry
        self.errors = ErrorRing(max_errors)
        if telemetry is not None:
            self.errors.bind_counter(
                telemetry.metrics.counter("validator.errors_dropped"))
            if self.ledger.telemetry is None:
                self.ledger.telemetry = telemetry
        self.completed: List[WorkUnit] = []
        # last restored checkpoint, so the N units of one step (and the
        # whole-step path) pay the restore cost once
        self._params_step: Optional[int] = None
        self._params: Any = None
        self._params_handoff = ""   # "snapshot" | "" for the cached params

    # -- shared execution body ---------------------------------------------
    def load_params(self, step: int):
        if self._params_step != step:
            snap = self.snapshots.get(step) \
                if self.snapshots is not None else None
            if snap is not None:
                # pre-durable hand-off: reconstruct the exact state tree the
                # durable restore would produce (same treedef, same leaf
                # bytes, same shardings placement) — bit-parity is the
                # contract, provenance is the only observable difference
                self._params = self.params_extractor(
                    snap.state(shardings=self.shardings))
                self._params_handoff = "snapshot"
            else:
                state, _ = ckpt.restore(self.ckpt_root, step,
                                        shardings=self.shardings)
                self._params = self.params_extractor(state)
                self._params_handoff = ""
            self._params_step = step
        return self._params

    @property
    def last_handoff(self) -> str:
        """``"snapshot"`` when the cached params came from the hand-off
        channel, ``""`` for a durable restore."""
        return self._params_handoff

    def invalidate_params_cache(self) -> None:
        """Drop the cached restore.  Called on validation failure: the
        cached tree may be the fault (a poisoned snapshot), and the retry —
        which reaches the worker AFTER the validator discards the snapshot —
        must re-resolve its source (then the durable checkpoint) instead of
        re-scoring the cached copy."""
        self._params_step = None
        self._params = None
        self._params_handoff = ""

    def _stamp(self, result):
        """Attach this worker's id and hand-off provenance to every row of
        ``result`` (no-op for anonymous single-process durable-restore
        workers: rows stay bit-identical)."""
        updates = {}
        if self.worker_id:
            updates["worker_id"] = self.worker_id
        if self._params_handoff:
            updates["handoff"] = self._params_handoff
        if not updates:
            return result
        if hasattr(result, "tasks"):            # SuiteResult
            return dataclasses.replace(result, tasks={
                n: dataclasses.replace(r, **updates)
                for n, r in result.tasks.items()})
        return dataclasses.replace(result, **updates)

    def log_result(self, result) -> None:
        if self.logger is None:
            return
        # reporter schema: bare names for the default task, task-qualified
        # for the rest (no default: duplicates)
        logmet = getattr(result, "log_metrics", result.metrics)
        self.logger.log(result.step,
                        {**logmet, **result.timings,
                         "subset_size": result.subset_size,
                         "engine": getattr(result, "engine", ""),
                         "score_dtype": getattr(result, "score_dtype",
                                                "f32")})

    def run_step(self, step: int):
        """Whole-checkpoint validation: restore, run EVERY suite task
        in-line, append the ledger rows.  Raises on failure with nothing
        recorded — retry policy belongs to the caller (the AsyncValidator's
        watcher requeue, or the fleet's abandon budget)."""
        params = self.load_params(step)
        try:
            result = self._stamp(self.pipeline.validate_params(
                params, step=step, engine=self.engine))
        except BaseException:
            self.invalidate_params_cache()
            raise
        self.ledger.record(result)
        if self.telemetry is not None:
            self._observe_verdict(step)
        return result

    def _observe_verdict(self, step: int) -> None:
        """Checkpoint-to-verdict latency, from the earliest mark available:
        ``produced`` (the trainer handed the state to the save path — the
        edge the lazy hand-off shortens) → ``snapshotted`` (the hand-off
        publish) → ``discovered`` (watcher poll) → COMMIT-marker mtime
        (wall clock; covers commit→verdict for cross-process fleets).
        Metrics only — never a scheduling input."""
        tel = self.telemetry
        lag = None
        for mark in ("produced", "snapshotted", "discovered"):
            lag = tel.since(mark, step)
            if lag is not None:
                break
        if lag is None:
            marker = os.path.join(ckpt._step_dir(self.ckpt_root, step),
                                  ckpt.COMMIT_MARKER)
            try:
                lag = max(0.0, time.time() - os.path.getmtime(marker))
            except OSError:
                return
        tel.metrics.histogram(CKPT_TO_VERDICT_METRIC).observe(lag)

    # -- fleet claim loop ---------------------------------------------------
    def execute_unit(self, unit: WorkUnit) -> ValidationResult:
        """Run ONE claimed (step, task) unit, heartbeating the lease (renew
        records) while the engine runs so it cannot expire mid-flight."""
        params = self.load_params(unit.step)
        stop_hb = threading.Event()
        hb = threading.Thread(target=self._heartbeat, args=(unit, stop_hb),
                              daemon=True)
        hb.start()
        try:
            result = self._stamp(self.pipeline.run_unit(
                params, unit, engine=self.engine))
        except BaseException:
            self.invalidate_params_cache()
            raise
        finally:
            stop_hb.set()
            hb.join()
        self.ledger.record(result)
        self.queue.complete(unit)   # after the row: a complete has a result
        if self.telemetry is not None:
            self._observe_verdict(unit.step)
        self.log_result(result)
        self.completed.append(unit)
        return result

    def _heartbeat(self, unit: WorkUnit, stop_evt: threading.Event) -> None:
        while not stop_evt.wait(self.heartbeat_interval_s):
            try:
                self.queue.renew(unit)
            except Exception:   # a failed heartbeat must not kill the run
                pass

    def run_once(self) -> int:
        """One scheduling round: claim and execute at most one unit.
        Returns 1 when a unit completed, 0 otherwise (appending a tick when
        peers hold live leases, so a DEAD peer's lease can age out — seq is
        the clock)."""
        if self.queue is None:
            raise RuntimeError("fleet mode requires a WorkQueue")
        state = self.queue.refresh()
        for unit in state.claimable(self.queue.capabilities):
            if not self.queue.try_claim(unit):
                continue                    # raced a peer and lost
            try:
                self.execute_unit(unit)
            except Exception as e:          # release it for a peer to retry
                self.errors.append((unit.step, f"{unit.task}: {e!r}"))
                self.queue.abandon(unit, error=repr(e))
                return 0
            return 1
        if state.blocked():
            self.queue.tick()
        return 0

    def run_forever(self, stop_event: threading.Event, *,
                    idle_wait_s: float = 0.05,
                    drained: Optional[Callable[[], bool]] = None) -> None:
        """Claim loop until ``stop_event`` is set, or ``drained()`` reports
        the backlog empty during an idle round."""
        while not stop_event.is_set():
            if self.run_once() == 0:
                if drained is not None and drained():
                    return
                stop_event.wait(idle_wait_s)


class AsyncValidator:
    """Watches ``ckpt_root`` and validates every committed checkpoint.

    ``pipeline`` is anything with ``validate_params(params, step=, engine=)``
    — a :class:`~repro.core.suite.ValidationSuite` (per-task ledger rows),
    the deprecated single-task ``ValidationPipeline`` shim, or a custom
    object.  Its optional ``task_names`` attribute defines ledger-completion
    semantics (absent -> the single ``"default"`` task).

    Since the fleet refactor this is a THIN single-worker instantiation of
    :class:`ValidatorWorker`: the watcher/retry/cap/controller loop lives
    here, execution (restore → validate → ledger) lives on ``self.worker``.
    Pass ``workqueue`` to make GC respect in-flight claims from OTHER
    workers sharing the ledger (``worker_id`` then stamps this validator's
    rows); without one, behaviour — including ledger bytes — is identical
    to the pre-fleet validator."""

    def __init__(self, ckpt_root: str, pipeline, *,
                 logger: Optional[BaseLogger] = None,
                 policy: Optional[Policy] = None,
                 max_num_valid: Optional[int] = None,
                 ledger_path: Optional[str] = None,
                 poll_interval_s: float = 0.2,
                 params_extractor: Callable = params_from_checkpoint,
                 shardings: Any = None,
                 engine: Any = None,
                 max_retries: int = 2,
                 controller: Any = None,
                 workqueue: Optional[WorkQueue] = None,
                 worker_id: str = "",
                 extra_protect: Optional[Callable[[], set]] = None,
                 telemetry=None,
                 snapshots: Any = None):
        self.ckpt_root = ckpt_root
        self.telemetry = telemetry
        self.watcher = CheckpointWatcher(ckpt_root, policy=policy,
                                         telemetry=telemetry)
        # lazy snapshot hand-off (repro.handoff.SnapshotChannel): pending
        # snapshots are validated BEFORE the watcher poll, and a publish
        # wakes the loop immediately instead of waiting out poll_interval_s.
        # The watcher remains the fallback + dedupe authority: snapshot-
        # scored steps are mark_seen'd so their eventual durable discovery
        # is consumed, and dropped/failed snapshots fall back to the
        # watcher path untouched.
        self.snapshots = snapshots
        self._wake = threading.Event()
        if snapshots is not None and hasattr(snapshots, "subscribe"):
            snapshots.subscribe(lambda step: self._wake.set())
        self.max_num_valid = max_num_valid
        # completion = a row for every suite task (single-task pipelines and
        # doubles fall back to the one "default" task = v1 semantics)
        expected = tuple(getattr(pipeline, "task_names", ()) or ("default",))
        self.workqueue = workqueue
        if telemetry is not None:
            # single-attachment convenience: thread the handle through the
            # suite config (engine spans) and queue if the caller didn't
            if workqueue is not None and workqueue.telemetry is None:
                workqueue.telemetry = telemetry
            vcfg = getattr(pipeline, "vcfg", None)
            if vcfg is not None \
                    and getattr(vcfg, "telemetry", None) is None:
                vcfg.telemetry = telemetry
        # engine injection (the `engine` kwarg): swap the validation data
        # path (streaming / materialized / custom) for THIS validator's runs
        # without rebuilding — or mutating — the pipeline's subset, stores,
        # or metric plumbing.
        self.worker = ValidatorWorker(
            ckpt_root, pipeline,
            ledger=ValidationLedger(ledger_path, expected_tasks=expected,
                                    telemetry=telemetry),
            queue=workqueue, logger=logger,
            params_extractor=params_extractor, shardings=shardings,
            engine=engine, worker_id=worker_id, telemetry=telemetry,
            snapshots=snapshots)
        self.poll_interval_s = poll_interval_s
        self.results: List[ValidationResult] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # one shared fault ring (bounded; see ErrorRing): worker execution
        # faults and loop-level faults (retry exhaustion, controller bugs)
        # land together
        self.errors = self.worker.errors
        # failed-step retry budget: a checkpoint that fails validation is
        # requeued (the watcher marked it seen when poll() handed it out, so
        # without this it would be permanently swallowed); after max_retries
        # re-attempts it is given up on and stays in ``errors``.
        self.max_retries = max_retries
        self._failures: Dict[int, int] = {}
        # control-plane hook: an object with on_result(result, validator),
        # invoked after every ledger append (selection / early stopping /
        # quality-aware GC — see repro.control.plane.ControlPlane).  Runs on
        # the validator thread; controller faults are captured in ``errors``
        # so a control bug can never take validation down.
        self.controller = controller
        # additional GC protections beyond validation state — e.g. the
        # serving tier passes Promoter.protect_set so quality GC can never
        # delete the checkpoint backing the LIVE index (or one mid-swap)
        self.extra_protect = extra_protect

    # -- thin-instantiation aliases (execution state lives on the worker) --
    @property
    def pipeline(self):
        return self.worker.pipeline

    @pipeline.setter
    def pipeline(self, value):
        self.worker.pipeline = value

    @property
    def engine(self):
        return self.worker.engine

    @engine.setter
    def engine(self, value):
        self.worker.engine = value

    @property
    def logger(self):
        return self.worker.logger

    @logger.setter
    def logger(self, value):
        self.worker.logger = value

    @property
    def ledger(self) -> ValidationLedger:
        return self.worker.ledger

    @property
    def params_extractor(self):
        return self.worker.params_extractor

    @params_extractor.setter
    def params_extractor(self, value):
        self.worker.params_extractor = value

    @property
    def shardings(self):
        return self.worker.shardings    # validator-mesh layout (elastic)

    @shardings.setter
    def shardings(self, value):
        self.worker.shardings = value

    # -- core single-pass --------------------------------------------------
    def validate_pending(self) -> int:
        n = self._validate(self._snapshot_pending())
        return n + self._validate(self.watcher.poll())

    def _snapshot_pending(self) -> List[int]:
        """Claim the hand-off channel's unvalidated snapshots (ascending).
        Ledgered steps are marked validated without a claim — the channel
        can then retire them once durable."""
        if self.snapshots is None:
            return []
        steps = []
        for step in self.snapshots.pending():
            if step in self.ledger:
                self.snapshots.mark_validated(step)
                continue
            if self.snapshots.claim(step) is not None:
                steps.append(step)
        return steps

    def validate_step(self, step: int) -> int:
        """Validate one specific committed step NOW, bypassing the watcher
        policy (still ledger-idempotent, still running the full logger /
        controller path).  The control plane uses this to score a virtual
        ensemble checkpoint: under a skipping policy (stride/budget/
        latest_first) the soup's step id may never be policy-selected, and
        it must not end up policy-skipped and unscored."""
        self.watcher.mark_seen(step)           # claimed: not pending, and
        return self._validate([step],          # not counted as skipped
                              ignore_cap=True)

    def _validate(self, steps, *, ignore_cap: bool = False) -> int:
        n = 0
        for step in steps:
            # max_num_valid caps the watcher-driven loop only; an explicit
            # validate_step (the soup's scoring path) must not be silently
            # swallowed by it, or the committed ensemble stays unledgered.
            if not ignore_cap and self.max_num_valid is not None \
                    and len(self.results) >= self.max_num_valid:
                break
            if step in self.ledger:
                continue
            try:
                # restore → validate → ledger rows, on the worker
                result = self.worker.run_step(step)
            except Exception as e:      # validation must never kill training
                self.errors.append((step, repr(e)))
                if self.snapshots is not None:
                    # drop the (possibly poisoned) host copy: the retry goes
                    # through the watcher + durable restore once committed
                    self.snapshots.discard(step)
                n_fail = self._failures.get(step, 0) + 1
                self._failures[step] = n_fail
                if n_fail <= self.max_retries:
                    self.watcher.requeue(step)   # retry on a later poll
                else:
                    self.watcher.mark_seen(step)
                continue
            self._failures.pop(step, None)
            if self.snapshots is not None \
                    and self.worker.last_handoff == "snapshot":
                # verdict landed from the hand-off path: free the snapshot
                # (once durable) and consume the step's eventual watcher
                # discovery so it is never validated twice
                self.snapshots.mark_validated(step)
                self.watcher.mark_seen(step)
            self.results.append(result)
            # adaptive scheduling feedback (BudgetPolicy): observed
            # validation latency drives the stride controller.
            self.watcher.policy.observe_latency(
                float(result.timings.get("total_s", 0.0)))
            self.worker.log_result(result)
            if self.controller is not None:
                try:
                    self.controller.on_result(result, self)
                except Exception as e:
                    self.errors.append((step, f"controller: {e!r}"))
            n += 1
        return n

    # -- async (thread) mode -----------------------------------------------
    def start(self) -> None:
        assert self._thread is None

        def loop():
            while not self._stop.is_set():
                self._wake.clear()
                self.validate_pending()
                if self.max_num_valid is not None \
                        and len(self.results) >= self.max_num_valid:
                    return
                # a snapshot publish sets _wake and cuts the sleep short —
                # the hand-off path never waits out the watcher interval
                self._wake.wait(self.poll_interval_s)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self, *, drain: bool = True,
             drain_timeout: Optional[float] = None) -> None:
        """Signal shutdown; with drain=True validate whatever is committed.

        ``drain_timeout`` (seconds) bounds the WHOLE shutdown — the loop
        join and the final drain pass — so a wedged engine run cannot hang
        it forever.  On expiry the timeout is surfaced in ``errors`` (key
        ``"stop"``) and the wedged daemon thread is abandoned; whatever it
        eventually ledgers is still idempotent on restart."""
        self._stop.set()
        self._wake.set()                # unblock a loop mid-sleep
        deadline = None if drain_timeout is None \
            else time.monotonic() + drain_timeout
        if self._thread is not None:
            self._thread.join(timeout=drain_timeout)
            if self._thread.is_alive():
                self.errors.append(
                    ("stop", f"drain timed out after {drain_timeout}s "
                             "waiting for the validation loop"))
                self._thread = None
                return
            self._thread = None
        if not drain:
            return
        if deadline is None:
            self.validate_pending()
            return
        t = threading.Thread(target=self._drain_guarded, daemon=True)
        t.start()
        t.join(timeout=max(0.0, deadline - time.monotonic()))
        if t.is_alive():
            self.errors.append(
                ("stop", f"drain timed out after {drain_timeout}s"))

    def _drain_guarded(self) -> None:
        try:
            self.validate_pending()
        except Exception as e:          # surfaced, never raised at shutdown
            self.errors.append(("stop", f"drain: {e!r}"))

    # -- single-GPU mode (paper: run after training completes) -------------
    def validate_all_existing(self) -> List[ValidationResult]:
        self.validate_pending()
        return self.results

    def protect_set(self) -> set:
        """Steps GC must keep: committed with a *pending* quality claim —
        not yet validated and not deliberately passed over by the watcher
        policy.  Failed-but-retrying (and given-up) steps stay protected;
        policy-skipped ones (stale/off-stride/over-budget) will never be
        validated, so protecting them would leak storage forever under
        skipping policies.

        With a fleet ``workqueue`` attached, steps under a LIVE lease held
        by ANY worker are additionally protected: a peer may be mid-restore
        on that checkpoint, and GC'ing it would turn its crash-safe claim
        into a spurious failure.

        ``extra_protect`` (constructor hook) unions in protections outside
        validation's own state — the serving tier's live/promoting
        checkpoints being the canonical case."""
        committed = set(ckpt.list_steps(self.ckpt_root))
        protected = committed - set(self.ledger.validated_steps) \
            - self.watcher.skipped
        if self.workqueue is not None:
            protected |= committed & self.workqueue.refresh().claimed_steps()
        if self.extra_protect is not None:
            protected |= set(self.extra_protect())
        return protected
