"""AsyncValidator — the paper's contribution: validation decoupled from training.

Runs on its own mesh/pod (here: its own thread), watches the checkpoint
directory, validates every new committed checkpoint, and reports metrics.
Training NEVER blocks on it.

Crash tolerance (beyond-paper, required at scale): every completed validation
is appended to a ledger file; on restart the validator skips ledgered steps,
making validation idempotent.  The ledger also feeds checkpoint GC
protection (a checkpoint is deletable only once validated).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.ckpt import checkpoint as ckpt
from repro.core.jsonl import read_jsonl_tolerant, truncate_torn_tail
from repro.core.reporting import BaseLogger
from repro.core.suite import (SuiteResult, ValidationResult,
                              params_from_checkpoint)
from repro.core.watcher import CheckpointWatcher, Policy


class ValidationLedger:
    """Append-only record of validated (step, task) pairs (idempotent
    restarts).

    Schema v2: one JSONL row per (step, task) — a multi-task
    :class:`~repro.core.suite.ValidationSuite` appends one row per task for
    every checkpoint pass.  Schema-v1 rows (no ``"task"`` key) migrate on
    load as task ``"default"``, so pre-suite ledgers load and replay
    identically.

    ``expected_tasks`` (the suite's task names, wired by the validator)
    defines step completion: a step counts as validated only when EVERY
    expected task has a row — a crash between task rows re-validates the
    step instead of silently dropping the missing tasks.  Without it, any
    row completes the step (v1 semantics).

    Crash tolerance: a process killed mid-append leaves a torn final line;
    load ignores exactly that (the unledgered step is simply re-validated).
    A torn line anywhere ELSE means real corruption and still raises.

    Concurrency-safe: the control plane (selector / early-stop / GC) reads
    this ledger from the validator thread while ``record`` may run — a lock
    guards the row state, appends are flushed + fsync'd so no consumer (in
    this process or a crash-restarted one) can observe a torn row, and
    :meth:`rows` hands out a snapshot instead of live dicts."""

    def __init__(self, path: Optional[str],
                 expected_tasks: Optional[Sequence[str]] = None):
        self.path = path
        self.expected_tasks: Optional[Tuple[str, ...]] = \
            tuple(expected_tasks) if expected_tasks is not None else None
        self._lock = threading.Lock()
        self._rows: List[dict] = []                    # record order
        self._index: Dict[Tuple[int, str], int] = {}   # (step, task) -> row
        self._by_step: Dict[int, set] = {}             # step -> task names
        self._torn_offset: Optional[int] = None
        if path and os.path.exists(path):
            # torn FINAL line (crash mid-append) is dropped — that step
            # simply re-validates; interior corruption raises.  Loading
            # never mutates the file (an audit may be reading a live
            # ledger); the fragment is truncated just before OUR first
            # append, by the writer that owns the file.
            rows, self._torn_offset = read_jsonl_tolerant(path,
                                                          kind="ledger row")
            for rec in rows:
                self._ingest(rec)

    def _ingest(self, rec: dict) -> None:
        step = int(rec["step"])
        task = str(rec.get("task", "default"))    # v1 rows migrate here
        rec = {**rec, "step": step, "task": task}
        key = (step, task)
        if key in self._index:
            # re-record (a partially-recorded step re-validated after a
            # crash): supersede the stale row and append the fresh one at
            # the END, where its sibling task rows land too — replay groups
            # CONSECUTIVE same-step rows into one observation, so the
            # re-validated step must appear as one fresh consecutive block,
            # exactly when the online decision was made.
            self._rows[self._index[key]] = None
        self._index[key] = len(self._rows)
        self._rows.append(rec)
        self._by_step.setdefault(step, set()).add(task)

    def _completed(self, step: int) -> bool:
        tasks = self._by_step.get(step)
        if not tasks:
            return False
        if self.expected_tasks is None:
            return True                           # v1 semantics: any row
        return all(t in tasks for t in self.expected_tasks)

    def completed(self, step: int) -> bool:
        """True when every expected task has a row for ``step``."""
        with self._lock:
            return self._completed(step)

    def __contains__(self, step: int) -> bool:
        return self.completed(step)

    def tasks_for(self, step: int) -> List[str]:
        with self._lock:
            return sorted(self._by_step.get(step, ()))

    @property
    def validated_steps(self) -> List[int]:
        with self._lock:
            return sorted(s for s in self._by_step if self._completed(s))

    def rows(self) -> List[dict]:
        """Snapshot of all live rows in RECORD order (the order decisions
        were made in — offline replay of the control plane depends on it).
        Rows superseded by a re-record are omitted."""
        with self._lock:
            return [dict(rec) for rec in self._rows if rec is not None]

    def record(self, result) -> None:
        """Append one row per task: a :class:`SuiteResult` contributes every
        task's row (consecutively, so replay groups them back into one
        observation); a plain :class:`ValidationResult` contributes its own
        (task ``"default"`` unless set)."""
        results = list(result.tasks.values()) \
            if isinstance(result, SuiteResult) or hasattr(result, "tasks") \
            else [result]
        recs = [{"step": r.step,
                 "task": str(getattr(r, "task", "default")),
                 "metrics": r.metrics, "timings": r.timings,
                 "subset_size": r.subset_size,
                 # which data path scored this step — lets a cross-mode
                 # parity audit (streaming vs materialized vs sharded)
                 # attribute every ledger row long after the run.
                 "engine": getattr(r, "engine", ""),
                 # scoring precision of the row, recorded like `engine` so
                 # replay_ledger and cross-precision audits work offline.
                 "score_dtype": str(getattr(r, "score_dtype", "f32"))}
                for r in results]
        with self._lock:
            for rec in recs:
                self._ingest(rec)
            if self.path:
                if self._torn_offset is not None:   # writer-side repair
                    truncate_torn_tail(self.path, self._torn_offset)
                    self._torn_offset = None
                with open(self.path, "a") as f:
                    for rec in recs:
                        f.write(json.dumps(rec) + "\n")
                    f.flush()
                    os.fsync(f.fileno())


class AsyncValidator:
    """Watches ``ckpt_root`` and validates every committed checkpoint.

    ``pipeline`` is anything with ``validate_params(params, step=, engine=)``
    — a :class:`~repro.core.suite.ValidationSuite` (per-task ledger rows),
    the deprecated single-task ``ValidationPipeline`` shim, or a custom
    object.  Its optional ``task_names`` attribute defines ledger-completion
    semantics (absent -> the single ``"default"`` task)."""

    def __init__(self, ckpt_root: str, pipeline, *,
                 logger: Optional[BaseLogger] = None,
                 policy: Optional[Policy] = None,
                 max_num_valid: Optional[int] = None,
                 ledger_path: Optional[str] = None,
                 poll_interval_s: float = 0.2,
                 params_extractor: Callable = params_from_checkpoint,
                 shardings: Any = None,
                 engine: Any = None,
                 max_retries: int = 2,
                 controller: Any = None):
        self.ckpt_root = ckpt_root
        self.pipeline = pipeline
        # engine injection: swap the validation data path (streaming /
        # materialized / custom) for THIS validator's runs without rebuilding
        # — or mutating — the pipeline's subset, stores, or metric plumbing.
        self.engine = engine
        self.logger = logger
        self.watcher = CheckpointWatcher(ckpt_root, policy=policy)
        self.max_num_valid = max_num_valid
        # completion = a row for every suite task (single-task pipelines and
        # doubles fall back to the one "default" task = v1 semantics)
        expected = tuple(getattr(pipeline, "task_names", ()) or ("default",))
        self.ledger = ValidationLedger(ledger_path, expected_tasks=expected)
        self.poll_interval_s = poll_interval_s
        self.params_extractor = params_extractor
        self.shardings = shardings      # validator-mesh layout (elastic)
        self.results: List[ValidationResult] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.errors: List[tuple] = []
        # failed-step retry budget: a checkpoint that fails validation is
        # requeued (the watcher marked it seen when poll() handed it out, so
        # without this it would be permanently swallowed); after max_retries
        # re-attempts it is given up on and stays in ``errors``.
        self.max_retries = max_retries
        self._failures: Dict[int, int] = {}
        # control-plane hook: an object with on_result(result, validator),
        # invoked after every ledger append (selection / early stopping /
        # quality-aware GC — see repro.control.plane.ControlPlane).  Runs on
        # the validator thread; controller faults are captured in ``errors``
        # so a control bug can never take validation down.
        self.controller = controller

    # -- core single-pass --------------------------------------------------
    def validate_pending(self) -> int:
        return self._validate(self.watcher.poll())

    def validate_step(self, step: int) -> int:
        """Validate one specific committed step NOW, bypassing the watcher
        policy (still ledger-idempotent, still running the full logger /
        controller path).  The control plane uses this to score a virtual
        ensemble checkpoint: under a skipping policy (stride/budget/
        latest_first) the soup's step id may never be policy-selected, and
        it must not end up policy-skipped and unscored."""
        self.watcher.mark_seen(step)           # claimed: not pending, and
        return self._validate([step],          # not counted as skipped
                              ignore_cap=True)

    def _validate(self, steps, *, ignore_cap: bool = False) -> int:
        n = 0
        for step in steps:
            # max_num_valid caps the watcher-driven loop only; an explicit
            # validate_step (the soup's scoring path) must not be silently
            # swallowed by it, or the committed ensemble stays unledgered.
            if not ignore_cap and self.max_num_valid is not None \
                    and len(self.results) >= self.max_num_valid:
                break
            if step in self.ledger:
                continue
            try:
                state, _ = ckpt.restore(self.ckpt_root, step,
                                        shardings=self.shardings)
                params = self.params_extractor(state)
                result = self.pipeline.validate_params(params, step=step,
                                                       engine=self.engine)
            except Exception as e:      # validation must never kill training
                self.errors.append((step, repr(e)))
                n_fail = self._failures.get(step, 0) + 1
                self._failures[step] = n_fail
                if n_fail <= self.max_retries:
                    self.watcher.requeue(step)   # retry on a later poll
                else:
                    self.watcher.mark_seen(step)
                continue
            self._failures.pop(step, None)
            self.ledger.record(result)
            self.results.append(result)
            # adaptive scheduling feedback (BudgetPolicy): observed
            # validation latency drives the stride controller.
            self.watcher.policy.observe_latency(
                float(result.timings.get("total_s", 0.0)))
            if self.logger is not None:
                # reporter schema: bare names for the default task, task-
                # qualified for the rest (no default: duplicates)
                logmet = getattr(result, "log_metrics", result.metrics)
                self.logger.log(step, {**logmet, **result.timings,
                                       "subset_size": result.subset_size,
                                       "engine": getattr(result, "engine",
                                                         ""),
                                       "score_dtype": getattr(result,
                                                              "score_dtype",
                                                              "f32")})
            if self.controller is not None:
                try:
                    self.controller.on_result(result, self)
                except Exception as e:
                    self.errors.append((step, f"controller: {e!r}"))
            n += 1
        return n

    # -- async (thread) mode -----------------------------------------------
    def start(self) -> None:
        assert self._thread is None

        def loop():
            while not self._stop.is_set():
                self.validate_pending()
                if self.max_num_valid is not None \
                        and len(self.results) >= self.max_num_valid:
                    return
                self._stop.wait(self.poll_interval_s)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self, *, drain: bool = True) -> None:
        """Signal shutdown; with drain=True validate whatever is committed."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.validate_pending()

    # -- single-GPU mode (paper: run after training completes) -------------
    def validate_all_existing(self) -> List[ValidationResult]:
        self.validate_pending()
        return self.results

    def protect_set(self) -> set:
        """Steps GC must keep: committed with a *pending* quality claim —
        not yet validated and not deliberately passed over by the watcher
        policy.  Failed-but-retrying (and given-up) steps stay protected;
        policy-skipped ones (stale/off-stride/over-budget) will never be
        validated, so protecting them would leak storage forever under
        skipping policies."""
        committed = set(ckpt.list_steps(self.ckpt_root))
        return committed - set(self.ledger.validated_steps) \
            - self.watcher.skipped
