"""AsyncValidator — the paper's contribution: validation decoupled from training.

Runs on its own mesh/pod (here: its own thread), watches the checkpoint
directory, validates every new committed checkpoint, and reports metrics.
Training NEVER blocks on it.

Crash tolerance (beyond-paper, required at scale): every completed validation
is appended to a ledger file; on restart the validator skips ledgered steps,
making validation idempotent.  The ledger also feeds checkpoint GC
protection (a checkpoint is deletable only once validated).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.ckpt import checkpoint as ckpt
from repro.core.pipeline import (ValidationPipeline, ValidationResult,
                                 params_from_checkpoint)
from repro.core.reporting import BaseLogger
from repro.core.watcher import CheckpointWatcher, Policy


class ValidationLedger:
    """Append-only record of validated steps (idempotent restarts).

    Concurrency-safe: the control plane (selector / early-stop / GC) reads
    this ledger from the validator thread while ``record`` may run — a lock
    guards the row map, appends are flushed + fsync'd so no consumer (in
    this process or a crash-restarted one) can observe a torn row, and
    :meth:`rows` hands out a snapshot instead of the live dict."""

    def __init__(self, path: Optional[str]):
        self.path = path
        self._lock = threading.Lock()
        self._done: Dict[int, dict] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                for line in f:
                    if line.strip():
                        rec = json.loads(line)
                        self._done[int(rec["step"])] = rec

    def __contains__(self, step: int) -> bool:
        with self._lock:
            return step in self._done

    @property
    def validated_steps(self) -> List[int]:
        with self._lock:
            return sorted(self._done)

    def rows(self) -> List[dict]:
        """Snapshot of all rows in RECORD order (the order decisions were
        made in — offline replay of the control plane depends on it)."""
        with self._lock:
            return [dict(rec) for rec in self._done.values()]

    def record(self, result: ValidationResult) -> None:
        rec = {"step": result.step, "metrics": result.metrics,
               "timings": result.timings, "subset_size": result.subset_size,
               # which data path scored this step — lets a cross-mode parity
               # audit (streaming vs materialized vs sharded) attribute every
               # ledger row long after the run.
               "engine": getattr(result, "engine", "")}
        with self._lock:
            self._done[result.step] = rec
            if self.path:
                with open(self.path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    os.fsync(f.fileno())


class AsyncValidator:
    def __init__(self, ckpt_root: str, pipeline: ValidationPipeline, *,
                 logger: Optional[BaseLogger] = None,
                 policy: Optional[Policy] = None,
                 max_num_valid: Optional[int] = None,
                 ledger_path: Optional[str] = None,
                 poll_interval_s: float = 0.2,
                 params_extractor: Callable = params_from_checkpoint,
                 shardings: Any = None,
                 engine: Any = None,
                 max_retries: int = 2,
                 controller: Any = None):
        self.ckpt_root = ckpt_root
        self.pipeline = pipeline
        # engine injection: swap the validation data path (streaming /
        # materialized / custom) for THIS validator's runs without rebuilding
        # — or mutating — the pipeline's subset, stores, or metric plumbing.
        self.engine = engine
        self.logger = logger
        self.watcher = CheckpointWatcher(ckpt_root, policy=policy)
        self.max_num_valid = max_num_valid
        self.ledger = ValidationLedger(ledger_path)
        self.poll_interval_s = poll_interval_s
        self.params_extractor = params_extractor
        self.shardings = shardings      # validator-mesh layout (elastic)
        self.results: List[ValidationResult] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.errors: List[tuple] = []
        # failed-step retry budget: a checkpoint that fails validation is
        # requeued (the watcher marked it seen when poll() handed it out, so
        # without this it would be permanently swallowed); after max_retries
        # re-attempts it is given up on and stays in ``errors``.
        self.max_retries = max_retries
        self._failures: Dict[int, int] = {}
        # control-plane hook: an object with on_result(result, validator),
        # invoked after every ledger append (selection / early stopping /
        # quality-aware GC — see repro.control.plane.ControlPlane).  Runs on
        # the validator thread; controller faults are captured in ``errors``
        # so a control bug can never take validation down.
        self.controller = controller

    # -- core single-pass --------------------------------------------------
    def validate_pending(self) -> int:
        return self._validate(self.watcher.poll())

    def validate_step(self, step: int) -> int:
        """Validate one specific committed step NOW, bypassing the watcher
        policy (still ledger-idempotent, still running the full logger /
        controller path).  The control plane uses this to score a virtual
        ensemble checkpoint: under a skipping policy (stride/budget/
        latest_first) the soup's step id may never be policy-selected, and
        it must not end up policy-skipped and unscored."""
        self.watcher.mark_seen(step)           # claimed: not pending, and
        return self._validate([step])          # not counted as skipped

    def _validate(self, steps) -> int:
        n = 0
        for step in steps:
            if self.max_num_valid is not None \
                    and len(self.results) >= self.max_num_valid:
                break
            if step in self.ledger:
                continue
            try:
                state, _ = ckpt.restore(self.ckpt_root, step,
                                        shardings=self.shardings)
                params = self.params_extractor(state)
                result = self.pipeline.validate_params(params, step=step,
                                                       engine=self.engine)
            except Exception as e:      # validation must never kill training
                self.errors.append((step, repr(e)))
                n_fail = self._failures.get(step, 0) + 1
                self._failures[step] = n_fail
                if n_fail <= self.max_retries:
                    self.watcher.requeue(step)   # retry on a later poll
                else:
                    self.watcher.mark_seen(step)
                continue
            self._failures.pop(step, None)
            self.ledger.record(result)
            self.results.append(result)
            # adaptive scheduling feedback (BudgetPolicy): observed
            # validation latency drives the stride controller.
            self.watcher.policy.observe_latency(
                float(result.timings.get("total_s", 0.0)))
            if self.logger is not None:
                self.logger.log(step, {**result.metrics, **result.timings,
                                       "subset_size": result.subset_size})
            if self.controller is not None:
                try:
                    self.controller.on_result(result, self)
                except Exception as e:
                    self.errors.append((step, f"controller: {e!r}"))
            n += 1
        return n

    # -- async (thread) mode -----------------------------------------------
    def start(self) -> None:
        assert self._thread is None

        def loop():
            while not self._stop.is_set():
                self.validate_pending()
                if self.max_num_valid is not None \
                        and len(self.results) >= self.max_num_valid:
                    return
                self._stop.wait(self.poll_interval_s)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self, *, drain: bool = True) -> None:
        """Signal shutdown; with drain=True validate whatever is committed."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.validate_pending()

    # -- single-GPU mode (paper: run after training completes) -------------
    def validate_all_existing(self) -> List[ValidationResult]:
        self.validate_pending()
        return self.results

    def protect_set(self) -> set:
        """Steps GC must keep: committed with a *pending* quality claim —
        not yet validated and not deliberately passed over by the watcher
        policy.  Failed-but-retrying (and given-up) steps stay protected;
        policy-skipped ones (stale/off-stride/over-budget) will never be
        validated, so protecting them would leak storage forever under
        skipping policies."""
        committed = set(ckpt.list_steps(self.ckpt_root))
        return committed - set(self.ledger.validated_steps) \
            - self.watcher.skipped
