"""Distributed corpus encoding — the expensive step Asyncval parallelizes.

The corpus (millions of pre-tokenized passages) is padded into fixed-shape
batches and pushed through a jit'd ``encode_fn`` whose batch axis is sharded
over the validator mesh (``("data","model")`` jointly for pure data
parallelism — encoding has no cross-example dependence).

Straggler mitigation (DESIGN.md §2.8): the corpus is over-decomposed into
~4x more chunks than workers and scheduled through
``repro.distributed.fault.WorkQueue`` with speculative re-execution — on this
CPU box the multi-worker path is exercised by the simulation tests; the
single-process path below is what examples use.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.data.corpus import Tokens, pad_batch


@dataclasses.dataclass
class EncodeStats:
    n_texts: int
    n_batches: int
    wall_time_s: float


# Jit-wrapper cache keyed on encode_fn identity.  ``jax.jit`` gives every
# wrapper its own trace cache, so re-wrapping per call (the old behaviour)
# retraced + recompiled the encoder for every checkpoint.  LRU-bounded: the
# jit wrapper strongly references its function, so weak keys would never be
# collectable anyway; the bound caps what callers that mint a fresh closure
# per checkpoint can leak.
_JIT_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_JIT_CACHE_MAX = 32


def cached_compiled(cache: "collections.OrderedDict", key,
                    build: Callable[[], Callable],
                    max_entries: int = _JIT_CACHE_MAX) -> Callable:
    """Bounded-LRU memoization for compiled wrappers.

    Shared by ``jitted_encoder`` and the streaming engine's sharded-encoder
    cache so the eviction/unhashable-fallback policy lives in one place.
    Unhashable keys get a fresh (uncached) build.
    """
    try:
        fn = cache.get(key)
    except TypeError:
        return build()
    if fn is None:
        fn = cache[key] = build()
        if len(cache) > max_entries:
            cache.popitem(last=False)
    else:
        cache.move_to_end(key)
    return fn


def jitted_encoder(encode_fn: Callable) -> Callable:
    """Return the (cached) jitted wrapper for ``encode_fn``.

    One compiled executable per encoder function, shared across checkpoints
    and across the legacy/streaming paths.  Falls back to a fresh wrapper for
    unhashable callables.
    """
    return cached_compiled(_JIT_CACHE, encode_fn,
                           lambda: jax.jit(encode_fn))


def encode_texts(encode_fn: Callable, params, texts: Sequence[Tokens], *,
                 max_len: int, batch_size: int,
                 donate: bool = False) -> tuple[np.ndarray, EncodeStats]:
    """Encode a list of token sequences -> (N, D) float32 embeddings.

    ``encode_fn(params, tokens (B,L) int32, mask (B,L) bool) -> (B, D)``.
    The final ragged batch is padded (and the padding rows dropped), so the
    jitted function sees exactly one shape — no recompilation.
    """
    t0 = time.time()
    n = len(texts)
    fn = jitted_encoder(encode_fn)
    out: List[np.ndarray] = []
    n_batches = 0
    for start in range(0, n, batch_size):
        chunk = list(texts[start:start + batch_size])
        real = len(chunk)
        if real < batch_size:
            chunk = chunk + [[0]] * (batch_size - real)
        toks, mask = pad_batch(chunk, max_len)
        emb = np.asarray(fn(params, toks, mask))
        out.append(emb[:real])
        n_batches += 1
    embs = (np.concatenate(out, axis=0) if out
            else np.zeros((0, 1), np.float32))
    return embs, EncodeStats(n_texts=n, n_batches=n_batches,
                             wall_time_s=time.time() - t0)


def encode_corpus_dict(encode_fn, params, corpus: Dict[str, Tokens], *,
                       max_len: int, batch_size: int,
                       subset_ids: Optional[Sequence[str]] = None):
    """Encode (a subset of) a corpus dict -> (ids, embeddings, stats)."""
    ids = list(subset_ids) if subset_ids is not None else list(corpus)
    texts = [corpus[i] for i in ids]
    embs, stats = encode_texts(encode_fn, params, texts,
                               max_len=max_len, batch_size=batch_size)
    return ids, embs, stats
