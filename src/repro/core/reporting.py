"""Metric reporters (paper §3 --report_to). tensorboard/wandb are replaced by
file-backed reporters with the same ``log(step, metrics)`` interface."""

from __future__ import annotations

import csv
import json
import os
import threading
from typing import Dict, List, Optional


class BaseLogger:
    def log(self, step: int, metrics: Dict[str, float]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class CSVLogger(BaseLogger):
    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._fields: Optional[List[str]] = None
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # restart safety: adopt an existing file's header so the first log()
        # of a fresh process APPENDS instead of truncating the history a
        # prior run (and the control plane's consumers) already wrote.
        if os.path.exists(path):
            with open(path, newline="") as f:
                header = next(csv.reader(f), None)
            if header:
                self._fields = list(header)

    def log(self, step: int, metrics: Dict[str, float]) -> None:
        with self._lock:
            row = {"step": step, **metrics}
            new_fields = sorted(row)
            if self._fields is None or any(f not in self._fields
                                           for f in new_fields):
                old_rows = []
                if os.path.exists(self.path):
                    with open(self.path) as f:
                        old_rows = list(csv.DictReader(f))
                self._fields = sorted(set(new_fields)
                                      | set(self._fields or []))
                with open(self.path, "w", newline="") as f:
                    w = csv.DictWriter(f, fieldnames=self._fields)
                    w.writeheader()
                    for r in old_rows:
                        w.writerow(r)
            with open(self.path, "a", newline="") as f:
                csv.DictWriter(f, fieldnames=self._fields).writerow(row)


class JSONLLogger(BaseLogger):
    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def log(self, step: int, metrics: Dict[str, float]) -> None:
        with self._lock:
            with open(self.path, "a") as f:
                f.write(json.dumps({"step": step, **metrics}) + "\n")

    def read(self) -> List[dict]:
        if not os.path.exists(self.path):
            return []
        with open(self.path) as f:
            return [json.loads(l) for l in f if l.strip()]


class MultiLogger(BaseLogger):
    def __init__(self, *loggers: BaseLogger):
        self.loggers = loggers

    def log(self, step: int, metrics: Dict[str, float]) -> None:
        for lg in self.loggers:
            lg.log(step, metrics)


class MemoryLogger(BaseLogger):
    def __init__(self):
        self.records: List[tuple] = []
        self._lock = threading.Lock()

    def log(self, step: int, metrics: Dict[str, float]) -> None:
        with self._lock:
            self.records.append((step, dict(metrics)))
