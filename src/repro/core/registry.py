"""Pluggable component registries — the toolkit's extension surface.

Asyncval's pitch is a *toolkit*: users plug their own dense-retriever model
and validation sets into an asynchronous validation loop.  Every string-
dispatched component in the validation path — engines (``streaming`` /
``materialized``), stages (the fused encode→fold strategies), samplers (the
paper's splitter variants), encoders, validation modes, and retrieval impls
— resolves through one of the registries below, so third-party code extends
the toolkit by *registering*, never by editing ``repro`` internals:

    from repro.core.registry import register_engine

    @register_engine("my_engine")
    def make_my_engine(spec, store, vcfg):
        return MyEngine(...)

    ValidationConfig(engine="my_engine")      # now just works

Unknown names raise immediately with the sorted list of registered
alternatives (and a did-you-mean hint), both inside the library and at CLI
parse time — a typo'd ``--engine`` fails before any corpus is padded.

Registration is import-time (decorators at module scope), so a registry's
contents reflect which component modules have been imported.  The built-in
components live in :mod:`repro.core.engine` (engines, stages, modes, impls)
and :mod:`repro.core.samplers` (samplers); importing either populates the
corresponding registries.
"""

from __future__ import annotations

import difflib
from typing import Any, Callable, Dict, List, Optional


class Registry:
    """A named string→component table with helpful unknown-name errors.

    Components are arbitrary objects (classes, factory functions, route
    hints).  ``register`` is usable as a decorator or a direct call;
    re-registering a *different* object under a taken name is an error
    unless ``overwrite=True`` (re-importing a module that registers the
    same object is always fine).
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, Any] = {}

    # -- registration --------------------------------------------------------
    def register(self, name: str, obj: Any = None, *,
                 overwrite: bool = False):
        """``register("name")`` (decorator) or ``register("name", obj)``."""
        if not name or not isinstance(name, str):
            raise ValueError(f"{self.kind} name must be a non-empty string, "
                             f"got {name!r}")

        def add(o):
            prev = self._items.get(name)
            if prev is not None and prev is not o and not overwrite:
                raise ValueError(
                    f"{self.kind} {name!r} is already registered "
                    f"(pass overwrite=True to replace it)")
            self._items[name] = o
            return o

        return add if obj is None else add(obj)

    # -- lookup --------------------------------------------------------------
    def get(self, name: str) -> Any:
        try:
            return self._items[name]
        except KeyError:
            raise ValueError(self._unknown(name)) from None

    def _unknown(self, name) -> str:
        names = self.names()
        msg = (f"unknown {self.kind} {name!r} "
               f"(registered {self.kind}s: {', '.join(names) or 'none'})")
        close = difflib.get_close_matches(str(name), names, n=1)
        if close:
            msg += f" — did you mean {close[0]!r}?"
        return msg

    def names(self) -> List[str]:
        return sorted(self._items)

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def __len__(self) -> int:
        return len(self._items)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {self.names()})"


# ---------------------------------------------------------------------------
# The toolkit's registries.  Built-ins register at import of their defining
# module; `ensure_builtins()` imports those modules for callers (the CLI)
# that need fully-populated name lists before touching the components.
# ---------------------------------------------------------------------------

ENGINES = Registry("engine")      # name -> factory(spec, store, vcfg)
STAGES = Registry("stage")        # name -> factory(encode_fn, **kw) -> Stage
SAMPLERS = Registry("sampler")    # name -> factory(depth=...) -> sampler
ENCODERS = Registry("encoder")    # name -> factory(args) -> EncoderSpec
MODES = Registry("mode")          # name -> route(impl=, mesh=, per_query=)
IMPLS = Registry("impl")          # name -> route(mesh=) -> stage name

register_engine = ENGINES.register
register_stage = STAGES.register
register_sampler = SAMPLERS.register
register_encoder = ENCODERS.register
register_mode = MODES.register
register_impl = IMPLS.register


def ensure_builtins() -> None:
    """Import the modules whose decorators populate the registries with the
    built-in components (idempotent; cheap after the first call)."""
    import repro.core.engine      # noqa: F401  engines, stages, modes, impls
    import repro.core.samplers    # noqa: F401  samplers


def resolve_sampler(sampler: Any, *, depth: int = 0) -> Any:
    """Accept a sampler instance, a registered sampler name, or ``None``
    (→ the ``full`` no-subset sampler).  Names resolve through
    :data:`SAMPLERS`, whose factories take the subset ``depth``."""
    import repro.core.samplers    # noqa: F401  populate SAMPLERS
    if sampler is None:
        return SAMPLERS.get("full")(depth=depth)
    if isinstance(sampler, str):
        return SAMPLERS.get(sampler)(depth=depth)
    return sampler
