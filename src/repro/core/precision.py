"""Scoring precision as a first-class, measured fidelity axis.

The paper cuts validation cost by shrinking the *data* (corpus subset
sampling); ``score_dtype`` applies the same idea to the *compute*: score the
corpus against the queries in bf16 or int8 instead of f32, halving or
quartering the embedding bytes the MIPS stage moves, and treat the fidelity
loss exactly like subset fidelity — recorded in every ledger row, swept in
``benchmarks/bench_fidelity.py`` as rank correlation vs the f32 full run,
never a silent default.

One helper, :func:`chunk_scores`, computes the quantized ``(Q, rows)`` score
block for every engine path (streaming XLA, sharded shard_map locals, the
rerank stages, and the materialized scan), so all of them see *identical*
quantized numerics:

  * ``bf16`` — inputs cast to bf16, MXU accumulation forced to f32
    (``preferred_element_type``); the running carries stay f32.
  * ``int8`` — symmetric per-ROW quantization (scale = max|row| / 127,
    ``repro.kernels.topk_mips.ops.quantize_int8``): a row's int8 image is
    independent of chunking and sharding, the int8 x int8 -> int32
    accumulation is exact, and the two per-row scale vectors are folded into
    the scores as an outer product BEFORE any ``-inf`` masking or carry
    merge — narrow dtypes never touch a merge or a mask.

``"f32"`` is deliberately NOT routed through here: every stage keeps its
original literal f32 expression behind a static branch, so the default path
compiles to the bit-for-bit identical program it always was.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_mips.ops import SCORE_DTYPES, quantize_int8

__all__ = ["SCORE_DTYPES", "quantize_int8", "validate_score_dtype",
           "chunk_scores", "itemsize", "quantize_rows_np"]

# contraction dims for q (Q, D) x emb (rows, D) -> (Q, rows)
_DIMS = (((1,), (1,)), ((), ()))


def validate_score_dtype(score_dtype: str) -> str:
    if score_dtype not in SCORE_DTYPES:
        raise ValueError(f"unknown score_dtype {score_dtype!r} "
                         f"(expected one of {SCORE_DTYPES})")
    return score_dtype


def itemsize(score_dtype: str) -> int:
    """Bytes per embedding element at this scoring precision (the analytic
    byte-shrink the benchmarks gate on)."""
    return {"f32": 4, "bf16": 2, "int8": 1}[validate_score_dtype(score_dtype)]


def chunk_scores(q_emb: jnp.ndarray, emb: jnp.ndarray,
                 score_dtype: str) -> jnp.ndarray:
    """Quantized scores for one chunk: (Q, D) x (rows, D) -> (Q, rows) f32.

    Traceable (used inside the stages' jitted folds; ``score_dtype`` is a
    Python-static attribute, so each stage compiles exactly one branch).
    """
    if score_dtype == "f32":
        return (q_emb @ emb.T).astype(jnp.float32)
    if score_dtype == "bf16":
        return jax.lax.dot_general(
            jnp.asarray(q_emb, jnp.bfloat16), jnp.asarray(emb, jnp.bfloat16),
            _DIMS, preferred_element_type=jnp.float32)
    if score_dtype == "int8":
        qv, qs = quantize_int8(q_emb)
        cv, cs = quantize_int8(emb)
        raw = jax.lax.dot_general(qv, cv, _DIMS,
                                  preferred_element_type=jnp.int32)
        # dequantize with the per-row scale outer product; same formula as
        # the Pallas int8 kernel — the exact int32 raw scores match, the two
        # f32 scale multiplies may reassociate, so impls agree to ~1 ulp
        # with identical top-k rank SETS (tests gate on exactly that)
        return raw.astype(jnp.float32) * qs * cs.reshape(1, -1)
    raise ValueError(f"unknown score_dtype {score_dtype!r} "
                     f"(expected one of {SCORE_DTYPES})")


def quantize_rows_np(x):
    """Host-side twin of :func:`quantize_int8` for the materialized rerank
    path (numpy in, numpy out; same formula, so the quantized images
    match)."""
    import numpy as np
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=-1, keepdims=True)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    vals = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return vals, scale
