"""Promotion plane: control-plane select events -> zero-downtime hot-swap.

The promoter closes the loop the control plane opens: the selector emits
fsync'd ``select`` events naming the best checkpoint so far; the promoter
tails them and swaps the live serving index in two phases mirroring
``ckpt.save``'s commit discipline:

  1. build   — restore the checkpoint, encode the corpus into a fresh
     :class:`~repro.serve.index.ServingIndex` OFF to the side (queries
     keep answering on the old index the whole time);
  2. verify + flip — probe the candidate (shape/finiteness/canary
     search, which also pre-warms the compiled search program), then
     atomically flip the service's live pointer.  A failure anywhere
     leaves the old index serving and is recorded as ``swap_failed``.

Every swap appends a ``swap`` actuation event (checkpoint step, previous
step, engine, ``score_dtype``, corpus size, build seconds) to an
append-only fsync'd :class:`~repro.control.events.ControlEventLog`, so
the live-step timeline is replayable offline (:func:`replay_swaps`).

Desired-step sources, in precedence order: an injected ``target_fn``
(in-process control planes pass ``lambda: selector.best_step``), tailing
a control event JSONL file for ``select`` events, else the latest
committed checkpoint.  The promoter re-reads the LATEST desired step each
poll, so a select event arriving during an in-flight build coalesces —
the next poll jumps straight to the newest winner instead of queueing
intermediate swaps.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Any, Callable, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.control.events import ControlEventLog
from repro.core.suite import params_from_checkpoint
from repro.serve.index import IndexBuilder, ServingIndex
from repro.serve.service import QueryService


class Promoter:
    """Two-phase hot-swapper between a builder and a query service."""

    def __init__(self, builder: IndexBuilder, service: QueryService,
                 ckpt_root: str, *,
                 target_fn: Optional[Callable[[], Optional[int]]] = None,
                 control_events: Optional[str] = None,
                 log: Union[ControlEventLog, str, None] = None,
                 params_extractor: Callable = params_from_checkpoint,
                 shardings: Any = None,
                 poll_interval_s: float = 0.2,
                 build_hook: Optional[Callable[[int], None]] = None,
                 telemetry=None):
        # observation only: a `promoted` span per successful swap (restore →
        # build → verify → flip) and a serve.promote_s histogram; swap
        # decisions and the event log are identical with telemetry off
        self.telemetry = telemetry
        self.builder = builder
        self.service = service
        self.ckpt_root = ckpt_root
        self.target_fn = target_fn
        self.control_events = control_events
        self.log = log if isinstance(log, ControlEventLog) \
            else ControlEventLog(log)
        self.params_extractor = params_extractor
        self.shardings = shardings
        self.poll_interval_s = poll_interval_s
        self.build_hook = build_hook     # test seam: runs post-build,
                                         # pre-verify (inject faults/events)
        self.swaps: List[Tuple[Optional[int], int]] = []
        self.failures: List[Tuple[int, BaseException]] = []
        self._promoting: Optional[int] = None
        self._consumed = 0               # control-event rows already read
        self._last_select: Optional[int] = None
        self._thread: Optional[threading.Thread] = None
        self._stopping = False

    # -- desired step -------------------------------------------------------
    def desired_step(self) -> Optional[int]:
        """The newest target, re-derived every call — which is exactly the
        coalescing rule: N select events between two polls collapse into
        one swap to the final winner."""
        if self.target_fn is not None:
            return self.target_fn()
        if self.control_events:
            if os.path.exists(self.control_events):
                from repro.core.jsonl import read_jsonl_tolerant
                recs, _ = read_jsonl_tolerant(self.control_events,
                                              kind="control event")
                for rec in recs[self._consumed:]:
                    self._consumed += 1
                    if rec.get("kind") != "select":
                        continue
                    best = rec.get("best_step", rec.get("step"))
                    if best is not None:
                        self._last_select = int(best)
            return self._last_select
        return ckpt.latest_step(self.ckpt_root)

    # -- GC contract --------------------------------------------------------
    def protect_set(self) -> set:
        """Steps quality GC must never delete: the checkpoint BACKING the
        live index (rollback target + restart source) and the one an
        in-flight promotion is building from.  Plug into
        ``AsyncValidator``/``FleetSupervisor`` ``extra_protect``."""
        out = set()
        live = self.service.live_step()
        if live is not None:
            out.add(live)
        if self._promoting is not None:
            out.add(self._promoting)
        return out

    # -- two-phase swap -----------------------------------------------------
    def verify(self, index: ServingIndex) -> None:
        """Phase-two gate, BEFORE the flip: structural checks plus a
        canary search that also pre-warms the compiled search program so
        the first real post-swap batch never pays a compile."""
        if index.n_docs < 1:
            raise ValueError("candidate index is empty")
        if index.n_docs != len(index.doc_ids):
            raise ValueError(
                f"candidate index rows ({index.n_docs}) != doc ids "
                f"({len(index.doc_ids)})")
        emb32 = jnp.asarray(index.emb, jnp.float32)
        if not bool(jnp.all(jnp.isfinite(emb32))):
            raise ValueError("candidate index has non-finite embeddings")
        canary = np.zeros((1, int(index.emb.shape[1])), np.float32)
        ids, _ = index.search(canary,
                              k=min(self.service.k, index.n_docs))
        if not ids or not ids[0]:
            raise ValueError("candidate index answered an empty canary")

    def poll_once(self) -> bool:
        """One promotion attempt; True iff the live index was swapped.
        Single-threaded by design — the poll loop is the swap mutex, and
        a failed build leaves the previous index serving untouched."""
        want = self.desired_step()
        live = self.service.live_step()
        if want is None or want == live:
            return False
        if want not in ckpt.list_steps(self.ckpt_root):
            return False                 # selected but not yet durable
        self._promoting = want
        tel = self.telemetry
        m0 = time.monotonic() if tel is not None else 0.0
        try:
            state, _ = ckpt.restore(self.ckpt_root, want,
                                    shardings=self.shardings)
            params = self.params_extractor(state)
            index = self.builder.build(params, want)
            if self.build_hook is not None:
                self.build_hook(want)
            self.verify(index)
            prev = self.service.install(index)
            self.log.emit("swap", want,
                          prev_step=prev if prev is not None else -1,
                          engine="serve",
                          score_dtype=index.score_dtype,
                          impl=index.impl, n_docs=index.n_docs,
                          build_s=round(index.build_s, 6))
            self.swaps.append((prev, want))
            if tel is not None:
                dur = time.monotonic() - m0
                tel.record("promoted", m0, dur, step=want,
                           prev=prev if prev is not None else -1,
                           n_docs=index.n_docs,
                           build_s=round(index.build_s, 6))
                tel.metrics.histogram("serve.promote_s").observe(dur)
            return True
        except BaseException as e:       # noqa: BLE001 — old index serves on
            self.failures.append((want, e))
            self.log.emit("swap_failed", want,
                          error=f"{type(e).__name__}: {e}",
                          engine="serve",
                          score_dtype=self.builder.cfg.score_dtype,
                          live_step=live if live is not None else -1)
            return False
        finally:
            self._promoting = None

    # -- background loop ----------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stopping = False
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-promoter", daemon=True)
        self._thread.start()

    def stop(self, *, timeout: float = 30.0) -> None:
        t = self._thread
        if t is None:
            return
        self._stopping = True
        t.join(timeout)
        self._thread = None

    def _loop(self) -> None:
        while not self._stopping:
            try:
                self.poll_once()
            except BaseException:        # noqa: BLE001 — never kill serving
                pass
            time.sleep(self.poll_interval_s)


def replay_swaps(path: str) -> List[dict]:
    """Re-derive the live-step timeline from a serve event log: one row
    per successful swap, ``{"seq", "step", "prev_step"}`` in order.  An
    auditor can join this against response attributions to prove every
    answer came from a then-live promoted checkpoint."""
    log = ControlEventLog(path)
    out = []
    for ev in log.events():
        if ev.kind == "swap":
            out.append({"seq": ev.seq, "step": ev.step,
                        "prev_step": ev.payload.get("prev_step", -1)})
    return out
