"""Retrieval serving tier — the third leg of train -> validate -> serve.

The control plane (repro.control) knows the best checkpoint at every
moment; this package puts it behind a query endpoint without ever forking
the scoring math.  Three planes:

  * index   — :class:`~repro.serve.index.IndexBuilder` encodes the corpus
    once per promoted checkpoint through the SAME ``TokenStore`` /
    ``encode_store`` machinery the validator streams through, into a
    device-resident (optionally sharded, optionally ``score_dtype``-
    quantized) :class:`~repro.serve.index.ServingIndex`.
  * request — :class:`~repro.serve.service.QueryService` micro-batches
    queries (max-latency flush), encodes them with the same cached
    encoder, and scores through the same ``topk_exact`` / ``topk_sharded``
    / pallas ``topk_mips`` dispatch the validator uses — so serving
    numbers ARE validation numbers, bit for bit (Kim et al. 2022's
    training-inference gap, closed by construction and locked by
    tests/test_serve_parity.py).
  * promotion — :class:`~repro.serve.promoter.Promoter` tails the control
    plane's fsync'd ``select`` events and hot-swaps the live index with a
    zero-downtime two-phase flip (build -> verify -> atomic pointer swap,
    mirroring ``ckpt.save``'s commit discipline), each swap recorded as a
    replayable JSONL event with checkpoint/engine/``score_dtype``
    provenance.

:class:`~repro.serve.admission.AdmissionController` bounds in-flight
requests so overload degrades by rejection, never by unbounded queueing.
"""

from repro.serve.admission import AdmissionController, ServeOverloaded
from repro.serve.index import IndexBuilder, ServeConfig, ServingIndex
from repro.serve.promoter import Promoter, replay_swaps
from repro.serve.service import QueryService, ServeResponse

__all__ = [
    "AdmissionController", "IndexBuilder", "Promoter", "QueryService",
    "ServeConfig", "ServeOverloaded", "ServeResponse", "ServingIndex",
    "replay_swaps",
]
