"""Device-resident serving index, built through the validator's data path.

The whole serve<->validate bit-parity story lives here: the corpus is
tokenized into the SAME pre-padded :class:`~repro.core.engine.TokenStore`
geometry the validation engines use (``chunk_geometry``), encoded with the
SAME cached encoder (``encode_store``), and searched through the SAME
top-k dispatch ``retrieve_run`` uses (``topk_exact`` / ``topk_sharded`` /
pallas ``topk_mips``), with the SAME ``score_dtype`` semantics
(:mod:`repro.core.precision`).  Because encoders are row-independent and
the streaming fold is bit-for-bit equal to the materialized kernels
(locked since PR 1), a query answered here scores exactly what the
validator scored for the promoted checkpoint.

Storage follows the ``MaterializedEngine`` precedent: ``bf16`` stores the
resident ``(N, D)`` matrix in bfloat16 (half the bytes; scoring casts are
then no-ops, value-identical to the validator's f32->bf16 cast), ``int8``
keeps the f32 matrix and quantizes per-row at score time (per-row scales
are chunk/shard-independent, so quantized scores match the streaming
path's exactly).

Sharded corpora whose row count doesn't divide the mesh are zero-padded
(pads land in the LAST shard only) and searches over-request
``k + n_pad`` before a host-side pad filter — every shard's real top-k
survives its local cut, so the filtered prefix equals the unpadded
answer.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (TokenStore, chunk_geometry, doc_cache_dir,
                               encode_store)
from repro.core.precision import validate_score_dtype
from repro.core.retrieval import topk_exact, topk_sharded


@dataclasses.dataclass
class ServeConfig:
    """Serving-tier knobs.  The scoring fields (``score_dtype`` / ``impl``
    / ``mesh`` / ``block``) deliberately mirror
    :class:`~repro.core.suite.ValidationConfig` — an index built with the
    validator's values serves bit-identical answers; ``chunk_size`` /
    ``batch_size`` feed the same :func:`chunk_geometry` so the corpus
    TokenStore is padded exactly like the validator's."""

    k: int = 10                       # results per query
    score_dtype: str = "f32"          # f32 | bf16 | int8 (resident storage
                                      # + scoring precision, see module doc)
    impl: str = "xla"                 # xla | pallas top-k kernel
    mesh: Any = None                  # shard corpus rows over this mesh
    block: int = 4096                 # topk scan block rows
    batch_size: int = 64              # corpus encode rows (chunk geometry)
    chunk_size: Optional[int] = None  # override: TokenStore chunk rows
    max_batch: int = 8                # query micro-batch (QueryService)
    flush_ms: float = 4.0             # max-latency flush (QueryService)
    max_pending: int = 256            # admission bound (QueryService)
    token_backing: str = "memory"     # memory | mmap TokenStore backing
    mmap_dir: Optional[str] = None
    token_fingerprint: str = "fast"


@dataclasses.dataclass
class ServingIndex:
    """One checkpoint's immutable serving state: the device-resident
    corpus embeddings PLUS the checkpoint params (queries must be encoded
    by the same checkpoint the corpus was), swapped as a unit by the
    promoter's atomic pointer flip."""

    step: int
    params: Any
    doc_ids: List[str]
    emb: jnp.ndarray                  # (N + n_pad, D) device-resident
    n_docs: int                       # real rows (pads excluded)
    score_dtype: str
    impl: str
    mesh: Any
    axis_names: Optional[Tuple[str, ...]]
    block: int
    build_s: float

    @property
    def n_pad(self) -> int:
        return int(self.emb.shape[0]) - self.n_docs

    def topk(self, q_emb, *, k: int):
        """Raw top-k over the resident matrix — the validator's
        ``retrieve_run`` dispatch verbatim, plus the pad over-request on
        the sharded path.  Returns host ``(scores, idx)`` truncated to
        ``k`` real rows per query."""
        kk = min(k + self.n_pad, int(self.emb.shape[0]))
        if self.impl == "pallas":
            from repro.kernels.topk_mips import ops as mips_ops
            s, i = mips_ops.topk_mips(jnp.asarray(q_emb), self.emb, k=kk,
                                      score_dtype=self.score_dtype)
        elif self.mesh is not None:
            s, i = topk_sharded(self.mesh, jnp.asarray(q_emb), self.emb,
                                k=kk, axis_names=self.axis_names,
                                block=self.block,
                                score_dtype=self.score_dtype)
        else:
            s, i = topk_exact(jnp.asarray(q_emb), self.emb, k=kk,
                              block=self.block,
                              score_dtype=self.score_dtype)
        s, i = np.asarray(s), np.asarray(i)
        if not self.n_pad:
            return s[:, :k], i[:, :k]
        out_s = np.empty((s.shape[0], k), s.dtype)
        out_i = np.empty((s.shape[0], k), i.dtype)
        for qi in range(s.shape[0]):
            keep = i[qi] < self.n_docs          # pads score 0; drop them
            out_s[qi] = s[qi, keep][:k]
            out_i[qi] = i[qi, keep][:k]
        return out_s, out_i

    def search(self, q_emb, *, k: int):
        """Per-row answers: ``(ids_rows, score_rows)`` lists — row ``r``
        of ``q_emb`` gets its top-``k`` doc ids and scores.  Positional
        (not a dict) so duplicate query ids inside one micro-batch can't
        collide."""
        s, i = self.topk(q_emb, k=k)
        ids = [[self.doc_ids[j] for j in row] for row in i]
        scores = [[float(v) for v in row] for row in s]
        return ids, scores

    def search_run(self, query_ids: Sequence[str], q_emb, *, k: int):
        """``retrieve_run``-shaped convenience: ``({qid: [docid...]},
        {qid: [score...]})`` for parity harnesses and TREC writers."""
        ids, scores = self.search(q_emb, k=k)
        return ({q: r for q, r in zip(query_ids, ids)},
                {q: r for q, r in zip(query_ids, scores)})


class IndexBuilder:
    """Builds a :class:`ServingIndex` per promoted checkpoint.

    The corpus TokenStore is padded ONCE at construction (the expensive,
    checkpoint-independent half) and reused across every build — the same
    built-once-shared-forever discipline as the suite's store cache; only
    the encode pass reruns per checkpoint, through the same jitted/sharded
    encoder the validator streams with."""

    def __init__(self, spec, corpus: Dict[str, Sequence[int]],
                 cfg: Optional[ServeConfig] = None):
        self.cfg = cfg if cfg is not None else ServeConfig()
        validate_score_dtype(self.cfg.score_dtype)
        self.spec = spec
        self.doc_ids = list(corpus)
        chunk, _ = chunk_geometry(self.cfg, len(self.doc_ids), self.cfg.mesh)
        self.store = TokenStore.build(
            [corpus[d] for d in self.doc_ids],
            max_len=spec.p_max_len, chunk=chunk,
            backing=self.cfg.token_backing,
            cache_dir=doc_cache_dir(self.cfg.mmap_dir),
            fingerprint=self.cfg.token_fingerprint)
        self.index_builds = 0

    def build(self, params, step: int) -> ServingIndex:
        cfg = self.cfg
        t0 = time.time()
        axis_names = (tuple(cfg.mesh.axis_names)
                      if cfg.mesh is not None else None)
        c_emb = encode_store(self.spec.encode_passage, params, self.store,
                             mesh=cfg.mesh, axis_names=axis_names)
        n_docs = int(c_emb.shape[0])
        if cfg.mesh is not None:
            n_shards = int(np.prod([cfg.mesh.shape[a] for a in axis_names]))
            pad = (-n_docs) % n_shards
            if pad:
                c_emb = jnp.concatenate(
                    [c_emb, jnp.zeros((pad, c_emb.shape[1]), c_emb.dtype)])
        if cfg.score_dtype == "bf16":
            # resident matrix shrinks 2x; scoring's bf16 cast becomes a
            # no-op over values the validator's f32->bf16 cast produced
            c_emb = jnp.asarray(c_emb, jnp.bfloat16)
        if cfg.mesh is not None:
            from repro.distributed.sharding import rows_sharding
            c_emb = jax.device_put(c_emb,
                                   rows_sharding(cfg.mesh, axis_names))
        c_emb.block_until_ready()
        self.index_builds += 1
        return ServingIndex(
            step=int(step), params=params, doc_ids=self.doc_ids, emb=c_emb,
            n_docs=n_docs, score_dtype=cfg.score_dtype, impl=cfg.impl,
            mesh=cfg.mesh, axis_names=axis_names, block=cfg.block,
            build_s=time.time() - t0)
