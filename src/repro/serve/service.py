"""Query request plane: micro-batching, shared encoder, atomic live index.

Requests are collected into micro-batches — a batch dispatches when it
reaches ``max_batch`` or when the oldest request has waited ``flush_ms``
(the classic throughput/latency trade) — padded to ONE fixed
``(max_batch, q_max_len)`` shape so the whole serving life runs a single
compiled encode program, and scored through the live
:class:`~repro.serve.index.ServingIndex`.

Two properties the tests lean on:

  * bit parity — queries are encoded by the same cached
    :func:`~repro.core.encoder.jitted_encoder` the validator uses, and
    encoders are row-independent, so a query's embedding (hence its
    scores, hence its ranking) is identical whether it arrives alone,
    in a full micro-batch, or inside the validator's big encode chunks.
  * exactly-one-step attribution — the live-index pointer is read ONCE
    per micro-batch and every response in the batch carries that index's
    checkpoint step; a concurrent hot-swap flips the pointer between
    batches, never inside one, so a torn read is structurally impossible.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.encoder import jitted_encoder
from repro.data.corpus import pad_batch
from repro.serve.admission import AdmissionController, ServeOverloaded
from repro.serve.index import ServingIndex


@dataclasses.dataclass
class ServeResponse:
    """One answered query, stamped with the exact checkpoint that scored
    it — the serving twin of a ledger row's provenance."""
    qid: str
    step: int
    doc_ids: List[str]
    scores: List[float]
    latency_s: float


class _Request:
    __slots__ = ("qid", "tokens", "event", "response", "error", "t0")

    def __init__(self, qid, tokens):
        self.qid = qid
        self.tokens = tokens
        self.event = threading.Event()
        self.response = None
        self.error: Optional[BaseException] = None
        self.t0 = time.time()


class QueryService:
    """Thread-safe query endpoint over a hot-swappable ServingIndex."""

    def __init__(self, spec, *, k: int = 10, max_batch: int = 8,
                 flush_ms: float = 4.0,
                 admission: Optional[AdmissionController] = None,
                 telemetry=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.spec = spec
        self.k = int(k)
        self.max_batch = int(max_batch)
        self.flush_s = float(flush_ms) / 1000.0
        self.admission = admission
        # observation only: `served` spans per micro-batch plus occupancy /
        # flush-window histograms; batching and scoring are unchanged
        self.telemetry = telemetry
        if telemetry is not None and admission is not None:
            admission.bind_metrics(telemetry.metrics)
        self._encode = jitted_encoder(spec.encode_query)
        self._cv = threading.Condition()
        self._queue: collections.deque = collections.deque()
        self._live: Optional[ServingIndex] = None
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self.served = 0
        self.batches = 0

    # -- live index (the promoter's flip target) ----------------------------
    def install(self, index: ServingIndex) -> Optional[int]:
        """Atomic pointer flip: in-flight micro-batches finish on the old
        index, the next batch reads the new one.  Returns the step that
        was live before (None on first install)."""
        prev = self._live
        self._live = index
        return prev.step if prev is not None else None

    @property
    def live(self) -> Optional[ServingIndex]:
        return self._live

    def live_step(self) -> Optional[int]:
        idx = self._live
        return idx.step if idx is not None else None

    # -- request path -------------------------------------------------------
    def submit(self, qid: str, tokens: Sequence[int], *,
               timeout: float = 30.0) -> ServeResponse:
        """Blocking submit (call from client threads): joins the current
        micro-batch and returns this query's response.  Raises
        :class:`ServeOverloaded` past the admission bound."""
        adm = self.admission
        if adm is not None and not adm.try_acquire():
            raise ServeOverloaded(
                f"{adm.pending} requests in flight (max {adm.max_pending})")
        try:
            req = _Request(qid, tokens)
            with self._cv:
                self._queue.append(req)
                self._cv.notify_all()
            if not req.event.wait(timeout):
                raise TimeoutError(f"query {qid!r} unanswered "
                                   f"after {timeout}s")
        finally:
            if adm is not None:
                adm.release()
        if req.error is not None:
            raise req.error
        return req.response

    def answer(self, items: Sequence[Tuple[str, Sequence[int]]]
               ) -> List[ServeResponse]:
        """Synchronous batch path (one-shot CLI / benches): slices
        ``items`` into ``max_batch`` micro-batches and scores them through
        the identical internals the background loop uses."""
        out: List[ServeResponse] = []
        for lo in range(0, len(items), self.max_batch):
            reqs = [_Request(q, t) for q, t in items[lo:lo + self.max_batch]]
            self._answer(reqs)
            for r in reqs:
                if r.error is not None:
                    raise r.error
                out.append(r.response)
        return out

    # -- micro-batcher ------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stopping = False
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-batcher", daemon=True)
        self._thread.start()

    def stop(self, *, timeout: float = 10.0) -> None:
        t = self._thread
        if t is None:
            return
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        t.join(timeout)
        self._thread = None

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stopping:
                    self._cv.wait(0.05)
                if not self._queue and self._stopping:
                    return
                # max-latency flush: dispatch at max_batch or when the
                # oldest request has waited flush_ms, whichever is first
                deadline = time.monotonic() + self.flush_s
                while len(self._queue) < self.max_batch \
                        and not self._stopping:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cv.wait(left)
                n = min(self.max_batch, len(self._queue))
                batch = [self._queue.popleft() for _ in range(n)]
            if batch:
                self._answer(batch)

    def _answer(self, reqs: List[_Request]) -> None:
        index = self._live          # read ONCE: one step per micro-batch
        if index is None:
            err = RuntimeError("no live index installed yet")
            for r in reqs:
                r.error = err
                r.event.set()
            return
        try:
            tel = self.telemetry
            m0 = time.monotonic() if tel is not None else 0.0
            ids, scores = self._score(index, [r.tokens for r in reqs])
            now = time.time()
            for r, d, s in zip(reqs, ids, scores):
                r.response = ServeResponse(qid=r.qid, step=index.step,
                                           doc_ids=d, scores=s,
                                           latency_s=now - r.t0)
            self.served += len(reqs)
            self.batches += 1
            if tel is not None:
                occupancy = len(reqs) / self.max_batch
                # flush-window utilization: how much of the max-latency
                # budget the oldest request actually waited (>1 = dispatch
                # overran the window, e.g. a slow prior batch)
                wait = now - min(r.t0 for r in reqs)
                flush_util = wait / self.flush_s if self.flush_s > 0 else 0.0
                tel.record("served", m0, time.monotonic() - m0,
                           step=index.step, n=len(reqs),
                           occupancy=occupancy)
                tel.metrics.histogram("serve.batch_occupancy").observe(
                    occupancy)
                tel.metrics.histogram("serve.flush_window_util").observe(
                    flush_util)
        except BaseException as e:     # noqa: BLE001 — fail the batch, not
            for r in reqs:             # the serving loop
                r.error = e
        finally:
            for r in reqs:
                r.event.set()

    def _score(self, index: ServingIndex, token_rows):
        B = len(token_rows)
        toks, mask = pad_batch(list(token_rows), self.spec.q_max_len)
        if B < self.max_batch:
            # fixed (max_batch, L) shape: one compiled program for every
            # batch size; pad rows are discarded below (row independence)
            pad = self.max_batch - B
            toks = np.concatenate(
                [toks, np.zeros((pad, toks.shape[1]), toks.dtype)])
            mask = np.concatenate(
                [mask, np.zeros((pad, mask.shape[1]), mask.dtype)])
        q_emb = self._encode(index.params, jnp.asarray(toks),
                             jnp.asarray(mask))[:B]
        return index.search(q_emb, k=self.k)
