"""Admission control — overload degrades by rejection, never by queueing.

A serving tier with an unbounded request queue converts overload into
unbounded latency (every queued request eventually answers, seconds late).
The controller caps in-flight requests instead: beyond ``max_pending`` a
submit fails fast with :class:`ServeOverloaded` and the client retries
against fresher state.  Counters are plain observability — the benchmark's
zero-drop gate reads ``rejected`` to prove the hot-swap path never sheds
load (bench_serve.py sizes ``max_pending`` above its offered concurrency,
so any rejection there means a real blackout, not admission working).
"""

from __future__ import annotations

import threading


class ServeOverloaded(RuntimeError):
    """Raised by submits past the in-flight bound; safe to retry later."""


class AdmissionController:
    """Bounded in-flight request counter (thread-safe)."""

    def __init__(self, max_pending: int = 256):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = int(max_pending)
        self._lock = threading.Lock()
        self.pending = 0
        self.peak = 0
        self.admitted = 0
        self.rejected = 0
        self._reject_counter = None     # repro.obs Counter, when bound

    def bind_metrics(self, registry) -> None:
        """Mirror rejections into ``serve.admission_rejects`` on a shared
        :class:`repro.obs.MetricsRegistry` (counting any pre-bind ones)."""
        counter = registry.counter("serve.admission_rejects")
        with self._lock:
            if self.rejected:
                counter.inc(self.rejected)
            self._reject_counter = counter

    def try_acquire(self) -> bool:
        with self._lock:
            if self.pending >= self.max_pending:
                self.rejected += 1
                if self._reject_counter is not None:
                    self._reject_counter.inc()
                return False
            self.pending += 1
            self.admitted += 1
            self.peak = max(self.peak, self.pending)
            return True

    def release(self) -> None:
        with self._lock:
            if self.pending <= 0:
                raise RuntimeError("release() without a matching acquire")
            self.pending -= 1
