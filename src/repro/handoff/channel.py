"""SnapshotChannel — the bounded hand-off ring between trainer and validator.

The trainer publishes a :class:`~repro.handoff.snapshot.ParamSnapshot` the
moment the host copy lands (from the async saver's background thread, see
``ckpt.AsyncSaver``); the validator claims pending snapshots and scores
them while the durable ``ckpt.save`` is still racing.  Three invariants:

  * **training never blocks** — :meth:`publish` applies drop-oldest-
    unvalidated backpressure: when the ring is full the oldest unclaimed
    snapshot is evicted (its step will be scored later from the durable
    checkpoint via the watcher fallback), and publish returns immediately;
  * **the watcher stays the dedupe authority** — the channel never records
    verdicts; the validator's ledger-idempotency plus
    ``watcher.mark_seen`` consume the eventual watcher discovery of a
    snapshot-scored step, so a step arriving via both routes is validated
    exactly once;
  * **durability is tracked, not assumed** — :meth:`mark_durable` /
    :meth:`mark_failed` (wired to the async saver's completion hooks)
    drive :meth:`durability`, which the control plane gates irreversible
    actions (quality GC, soup commit, serve promotion) on.  Selection and
    early stopping may act on snapshot-scored rows; nothing may promote
    or delete on the evidence of a step that could still fail to persist.

With a :class:`~repro.handoff.spool.SnapshotSpool` attached, every
publish/eviction is mirrored to the spill directory so cross-process
fleet workers see the same ring through mmap.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, List, Optional

from repro.handoff.snapshot import ParamSnapshot

#: durability states a published step moves through
PENDING, DURABLE, FAILED = "pending", "durable", "failed"


class SnapshotChannel:
    """Bounded ring of committed host-resident param snapshots."""

    def __init__(self, capacity: int = 2, *, spool: Any = None,
                 telemetry=None):
        self.capacity = max(1, int(capacity))
        self.spool = spool
        # observation only: a `snapshotted` lifecycle event/mark per publish
        # — the first edge of the snapshot path's ckpt-to-verdict latency.
        self.telemetry = telemetry
        self._lock = threading.Lock()
        self._ring: "OrderedDict[int, ParamSnapshot]" = OrderedDict()
        self._claimed: set = set()          # handed to a validator, in flight
        self._validated: set = set()
        self._state: dict = {}              # step -> PENDING|DURABLE|FAILED
        self._subscribers: List[Callable[[int], None]] = []
        self.dropped: List[int] = []        # backpressure evictions, in order

    # -- trainer side --------------------------------------------------------
    def subscribe(self, fn: Callable[[int], None]) -> None:
        """Register a publish listener (the validator's wake event): called
        with the step after every publish, on the publisher's thread — it
        must be cheap and non-blocking (an ``Event.set`` is the intended
        use)."""
        self._subscribers.append(fn)

    def publish(self, snapshot: ParamSnapshot) -> None:
        """Insert a snapshot; never blocks.  Over capacity, the oldest
        unclaimed-unvalidated snapshot is dropped — the watcher fallback
        owns its verdict from the durable checkpoint later."""
        evicted: List[int] = []
        with self._lock:
            self._ring[snapshot.step] = snapshot
            self._ring.move_to_end(snapshot.step)
            self._state.setdefault(snapshot.step, PENDING)
            while len(self._ring) > self.capacity:
                victim = next(
                    (s for s in self._ring
                     if s not in self._claimed and s != snapshot.step),
                    None)
                if victim is None:
                    # everything older is mid-validation; claimants hold
                    # their own references, so evicting the ring entry is
                    # safe and publish still never blocks
                    victim = next(iter(self._ring))
                self._ring.pop(victim)
                self._claimed.discard(victim)
                if victim not in self._validated:
                    self.dropped.append(victim)
                evicted.append(victim)
        if self.spool is not None:
            self.spool.publish(snapshot.step, snapshot.leaves,
                               snapshot.treedef_hex, extra=snapshot.extra)
            for step in evicted:
                self.spool.retire(step)
        tel = self.telemetry
        if tel is not None:
            tel.mark("snapshotted", snapshot.step)
            tel.event("snapshotted", step=snapshot.step,
                      nbytes=snapshot.nbytes, evicted=evicted or None)
        for fn in self._subscribers:
            fn(snapshot.step)

    def mark_durable(self, step: int) -> None:
        """The durable ``ckpt.save`` committed (async saver hook): the gate
        on irreversible actions opens, and a validated snapshot's host/spool
        copy is reclaimable."""
        with self._lock:
            self._state[step] = DURABLE
        self._maybe_retire(step)

    def mark_failed(self, step: int, error: Any = None) -> None:
        """The durable save failed: the snapshot is evicted (nothing may
        keep acting on evidence of a step that will never persist) and the
        step reports ``failed`` so deferred actions un-block instead of
        waiting forever."""
        with self._lock:
            self._state[step] = FAILED
            self._ring.pop(step, None)
            self._claimed.discard(step)
        if self.spool is not None:
            self.spool.retire(step)

    # -- validator side ------------------------------------------------------
    def pending(self) -> List[int]:
        """Unclaimed, unvalidated snapshot steps in publish order."""
        with self._lock:
            return [s for s in self._ring
                    if s not in self._claimed and s not in self._validated]

    def claim(self, step: int) -> Optional[ParamSnapshot]:
        """Take ``step``'s snapshot for validation (in-process ring first,
        then the spool for cross-process claimants)."""
        with self._lock:
            snap = self._ring.get(step)
            if snap is not None:
                self._claimed.add(step)
                return snap
        if self.spool is not None:
            return self.spool.get(step)
        return None

    def get(self, step: int) -> Optional[ParamSnapshot]:
        """Read-only lookup (the worker's params-view source): no claim
        bookkeeping, so retries and soup re-scores stay side-effect free."""
        with self._lock:
            snap = self._ring.get(step)
        if snap is not None:
            return snap
        if self.spool is not None:
            return self.spool.get(step)
        return None

    def mark_validated(self, step: int) -> None:
        """A verdict landed for ``step`` from the snapshot path."""
        with self._lock:
            self._validated.add(step)
            self._claimed.discard(step)
        self._maybe_retire(step)

    def discard(self, step: int) -> None:
        """Validator-side failure: drop the snapshot so the retry (via the
        watcher, once durable) restores from disk instead of re-reading a
        possibly-poisoned host copy.  Durability state is untouched."""
        with self._lock:
            self._ring.pop(step, None)
            self._claimed.discard(step)
        if self.spool is not None:
            self.spool.retire(step)

    # -- durability gate (control plane) -------------------------------------
    def durability(self, step: int) -> str:
        """``"pending" | "durable" | "failed"`` — steps this channel never
        published report ``durable`` (they were restored from a committed
        checkpoint by construction)."""
        with self._lock:
            return self._state.get(step, DURABLE)

    def is_durable(self, step: int) -> bool:
        return self.durability(step) == DURABLE

    # -- internal ------------------------------------------------------------
    def _maybe_retire(self, step: int) -> None:
        """Once a step is BOTH validated and durable its snapshot has no
        remaining consumer: free the host copy and the spool entry."""
        with self._lock:
            done = step in self._validated \
                and self._state.get(step) == DURABLE \
                and step not in self._claimed
            if done:
                self._ring.pop(step, None)
        if done and self.spool is not None:
            self.spool.retire(step)
