"""Cross-process snapshot spill — mmap-able, torn-write-safe, numpy-only.

The in-process :class:`~repro.handoff.channel.SnapshotChannel` hands
Python object references to a validator thread; fleet ``ValidatorWorker``
processes need a filesystem representation instead.  The spool writes one
directory per snapshot (point ``root`` at ``/dev/shm/...`` to keep the
spill in RAM)::

    <root>/snap_0000001000/
        arrays/00000.npy …   # one .npy per pytree leaf (treedef order)
        manifest.json        # step, treedef proto hex, per-leaf dtype
        COMMIT               # written LAST — readers ignore dirs without it
    <root>/announce.jsonl    # {"kind": "snapshot"|"retired", "step": N}

Torn-write safety reuses the two proven disciplines verbatim: the
``ckpt.save`` two-phase commit (tmp dir + fsync + rename + COMMIT marker)
means a trainer SIGKILLed mid-spill leaves a snapshot no reader will ever
claim, and the announce log goes through
:func:`repro.core.jsonl.append_jsonl_atomic` (O_APPEND + single write +
fsync + tail repair) so a torn announce line is dropped, never glued.

Readers map leaves with ``np.load(mmap_mode="r")`` — claiming a snapshot
costs page-table setup, not a copy; N workers validating the same step
share the page cache.

This module imports numpy only (no jax): the trainer-side crash tests and
lightweight consumers must be able to import it in subprocesses without
paying — or depending on — a jax initialization.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Set

import numpy as np

from repro.core.jsonl import append_jsonl_atomic, read_jsonl_tolerant

COMMIT_MARKER = "COMMIT"
SNAP_PREFIX = "snap_"
ANNOUNCE_LOG = "announce.jsonl"


def _snap_dir(root: str, step: int) -> str:
    return os.path.join(root, f"{SNAP_PREFIX}{step:010d}")


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class SnapshotSpool:
    """Commit-marker snapshot directories plus an announce log, under one
    root.  One writer (the trainer's hand-off channel), many readers
    (fleet workers, the supervisor's :meth:`poll`)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.announce_path = os.path.join(root, ANNOUNCE_LOG)
        self._polled: Set[int] = set()      # steps this handle announced
        self._pending: List[int] = []       # consumer surface: unclaimed

    # -- writer side ---------------------------------------------------------
    def publish(self, step: int, leaves: List[np.ndarray], treedef_hex: str,
                extra: Optional[dict] = None) -> str:
        """Two-phase spill: arrays + manifest into a tmp dir, fsync, rename,
        COMMIT marker last — then announce.  A crash at ANY point leaves
        either an ignorable uncommitted dir or a complete snapshot."""
        final = _snap_dir(self.root, step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        arrays_dir = os.path.join(tmp, "arrays")
        os.makedirs(arrays_dir)
        manifest = {"step": int(step), "treedef": treedef_hex,
                    "leaves": [], "extra": extra or {}}
        for i, arr in enumerate(leaves):
            arr = np.asarray(arr)
            with open(os.path.join(arrays_dir, f"{i:05d}.npy"), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append({"shape": list(arr.shape),
                                       "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if os.path.exists(final):           # idempotent re-publish
            shutil.rmtree(final)
        os.rename(tmp, final)
        cpath = os.path.join(final, COMMIT_MARKER)
        with open(cpath, "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(final)
        append_jsonl_atomic(self.announce_path,
                            [{"kind": "snapshot", "step": int(step)}])
        return final

    def retire(self, step: int) -> None:
        """Delete a snapshot no longer needed (validated + durable, dropped
        by backpressure, or failed).  Announced so pollers converge."""
        shutil.rmtree(_snap_dir(self.root, step), ignore_errors=True)
        append_jsonl_atomic(self.announce_path,
                            [{"kind": "retired", "step": int(step)}])

    # -- reader side ---------------------------------------------------------
    def has(self, step: int) -> bool:
        """True iff ``step``'s snapshot is fully committed (COMMIT marker
        present) — a torn spill is invisible, by construction."""
        return os.path.exists(os.path.join(_snap_dir(self.root, step),
                                           COMMIT_MARKER))

    def steps(self) -> List[int]:
        """Committed snapshot steps, ascending (directory scan — the
        markers, not the announce log, are the claim authority)."""
        if not os.path.isdir(self.root):
            return []
        out = []
        for name in os.listdir(self.root):
            if not name.startswith(SNAP_PREFIX) or name.endswith(".tmp"):
                continue
            try:
                step = int(name[len(SNAP_PREFIX):])
            except ValueError:
                continue
            if self.has(step):
                out.append(step)
        return sorted(out)

    def poll(self) -> List[int]:
        """Newly announced-and-committed steps since the last poll on this
        handle — the supervisor's discovery feed.  Tolerates a torn final
        announce line (dropped; the step surfaces on a later poll once the
        announce is re-appended or via the durable watcher path)."""
        if not os.path.exists(self.announce_path):
            return []
        rows, _ = read_jsonl_tolerant(self.announce_path, kind="announce")
        retired = {int(r["step"]) for r in rows if r.get("kind") == "retired"}
        fresh = []
        for r in rows:
            if r.get("kind") != "snapshot":
                continue
            step = int(r["step"])
            if step in self._polled or step in retired:
                continue
            if self.has(step):          # marker authority: skip torn spills
                self._polled.add(step)
                fresh.append(step)
        return fresh

    def load(self, step: int):
        """``(leaves, treedef_hex, extra)`` with leaves mmap'd read-only.
        Returns ``None`` when the snapshot is absent or uncommitted."""
        path = _snap_dir(self.root, step)
        if not self.has(step):
            return None
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = []
        for i, meta in enumerate(manifest["leaves"]):
            arr = np.load(os.path.join(path, "arrays", f"{i:05d}.npy"),
                          mmap_mode="r")
            if str(arr.dtype) != meta["dtype"]:
                # ml_dtypes leaves (bfloat16, float8_*) round-trip through
                # .npy as raw void records, exactly as in ckpt.restore
                import ml_dtypes  # noqa: F401  (registers the named dtypes)
                arr = arr.view(np.dtype(meta["dtype"]))
            leaves.append(arr)
        return leaves, manifest["treedef"], manifest.get("extra", {})

    def get(self, step: int):
        """The :class:`~repro.handoff.snapshot.ParamSnapshot` for ``step``
        backed by mmap'd leaves, or ``None`` — the fleet worker's
        params-view source (mirrors ``SnapshotChannel.get``)."""
        loaded = self.load(step)
        if loaded is None:
            return None
        from repro.handoff.snapshot import ParamSnapshot
        leaves, treedef_hex, extra = loaded
        return ParamSnapshot(step=int(step), leaves=leaves,
                             treedef_hex=treedef_hex, extra=extra)

    # -- channel-compatible consumer surface ---------------------------------
    # A solo AsyncValidator in ANOTHER process points snapshots= straight at
    # the spool: pending/claim/mark_validated/discard mirror the validator
    # half of SnapshotChannel.  All bookkeeping is LOCAL to this handle —
    # retirement (deleting the spill) stays with the writing channel, which
    # alone knows when a step is both validated and durable.
    def pending(self) -> List[int]:
        """Unclaimed announced-and-committed steps, in announce order."""
        self._pending.extend(s for s in self.poll()
                             if s not in self._pending)
        return [s for s in self._pending if self.has(s)]

    def claim(self, step: int):
        """Take ``step`` for validation (drops it from this handle's
        pending list); ``None`` if the snapshot is gone (retired by the
        writer — the watcher fallback owns the step then)."""
        snap = self.get(step)
        if step in self._pending:
            self._pending.remove(step)
        return snap

    def mark_validated(self, step: int) -> None:
        if step in self._pending:
            self._pending.remove(step)

    def discard(self, step: int) -> None:
        """Reader-side failure: forget the local claim only — the retry
        restores from the durable checkpoint; the spill stays owned by the
        writer."""
        if step in self._pending:
            self._pending.remove(step)
