"""Host-resident parameter snapshots — the unit the hand-off channel moves.

A :class:`ParamSnapshot` is one step's checkpoint *state tree* (params +
optimizer state, exactly what the trainer saves) flattened to numpy leaves
plus the serialized treedef — the same ``(leaves, treedef)`` encoding
``repro.ckpt.checkpoint`` writes to disk, minus the disk.  Because
:meth:`ParamSnapshot.state` reconstructs the tree the same way
``ckpt.restore`` does (unflatten host arrays, then ``jax.device_put`` per
leaf when shardings are given), validating from a snapshot is bit-for-bit
identical to validating the step restored from the durable checkpoint —
the parity contract the hand-off subsystem is built on.

jax is imported lazily (inside the methods that need it) so the spool's
cross-process consumers — and the SIGKILL crash tests — can import this
module with numpy alone.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class ParamSnapshot:
    """One step's host-resident checkpoint state.

    ``leaves`` are numpy arrays in treedef order (``np.asarray`` of the
    device arrays — the identical bytes ``ckpt.save``/``restore`` would
    round-trip through ``.npy`` files).  ``treedef_hex`` is the pytree
    structure serialized with the same proto encoding the checkpoint
    manifest uses, so a snapshot re-read from the spool in another
    process reconstructs the exact same tree."""

    step: int
    leaves: List[np.ndarray]
    treedef_hex: str
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_tree(cls, step: int, tree: Any,
                  extra: Optional[dict] = None) -> "ParamSnapshot":
        """Flatten ``tree`` (device or host arrays) into a snapshot.  The
        ``np.asarray`` per leaf blocks until that leaf's device→host copy
        lands — callers on the training hot path issue
        ``copy_to_host_async()`` first (see ``ckpt.AsyncSaver``) and build
        the snapshot on a background thread."""
        import jax
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return cls(step=int(step),
                   leaves=[np.asarray(x) for x in leaves],
                   treedef_hex=treedef.serialize_using_proto().hex(),
                   extra=dict(extra or {}))

    def state(self, *, shardings: Any = None) -> Any:
        """Reconstruct the checkpoint state tree — ``ckpt.restore``'s
        return value, without touching disk.  ``shardings`` (a pytree of
        Shardings, same structure) places leaves for an arbitrary
        validator mesh exactly as ``restore(..., shardings=)`` would."""
        import jax
        treedef = jax.tree_util.PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry, bytes.fromhex(self.treedef_hex))
        tree = jax.tree_util.tree_unflatten(treedef, list(self.leaves))
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    @property
    def nbytes(self) -> int:
        return sum(int(x.nbytes) for x in self.leaves)
