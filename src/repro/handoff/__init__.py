"""Lazy snapshot hand-off — validate checkpoints before they are durable.

Asyncval minimizes the lag between a checkpoint existing and a verdict on
it, yet the watcher path can only start after full durable serialization
plus a poll interval: checkpoint-to-verdict latency is dominated by an
O(serialize + poll) prefix that has nothing to do with validation itself.
Following DataStates-LLM's lazy-checkpointing model, the trainer hands the
validator a *host-resident parameter snapshot* the moment the device→host
copy lands, while the durable two-phase ``ckpt.save`` races in the
background — cutting the prefix to O(device→host copy).

Three pieces:

  * :class:`~repro.handoff.snapshot.ParamSnapshot` — one step's host
    pytree (numpy leaves + serialized treedef); ``state(shardings=)``
    reconstructs exactly what ``ckpt.restore`` would return, so snapshot
    validation is bit-for-bit identical to durable validation.
  * :class:`~repro.handoff.spool.SnapshotSpool` — the cross-process
    representation: mmap-able ``.npy`` arrays under a commit-marker
    directory (the ``ckpt.save`` two-phase discipline) plus an
    append-only fsync'd announce log (``core.jsonl``), so fleet
    ``ValidatorWorker`` processes can claim snapshots torn-write-safely.
    Point it at a ``/dev/shm`` path to keep the spill in memory.
  * :class:`~repro.handoff.channel.SnapshotChannel` — the bounded ring
    between trainer and validator: in-process handles for the solo
    ``AsyncValidator``, optional spill through a spool, drop-oldest-
    unvalidated backpressure (training never blocks), and the durability
    state (``pending``/``durable``/``failed``) the control plane gates
    irreversible actions on.

The watcher path remains the fallback and the dedupe authority: a step
that arrives via both routes is validated once (ledger idempotency), and
a snapshot lost to a crash or backpressure is simply scored later from
the durable checkpoint.
"""

from repro.handoff.channel import SnapshotChannel
from repro.handoff.snapshot import ParamSnapshot
from repro.handoff.spool import SnapshotSpool

__all__ = ["ParamSnapshot", "SnapshotChannel", "SnapshotSpool"]
