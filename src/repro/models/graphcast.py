"""GraphCast-style encoder-processor-decoder GNN [arXiv:2212.12794].

TPU/JAX adaptation notes (DESIGN.md §2.2):
  * message passing is implemented with ``jnp.take`` (gather) +
    ``jax.ops.segment_sum`` over an edge list — JAX has no CSR SpMM; the
    gather/scatter formulation *is* the system here and shards cleanly
    (edges and nodes row-sharded over the mesh).
  * the processor's 16 interaction-network layers are stacked and scanned
    (O(1) compile depth) with remat.
  * the assigned benchmark shapes are generic graphs (cora / reddit-minibatch /
    ogb-products / molecule batches), so the grid2mesh/mesh2grid bipartite
    stages operate on the benchmark graph itself: encoder/decoder are the
    GraphCast node/edge MLP encoders, the processor is the multi-mesh GNN.
    ``mesh_refinement=6`` is kept as metadata of the weather configuration.

Layer update (interaction network, sum aggregator, LayerNorm — as GraphCast):
    e' = e + LN(MLP_e([e, v_src, v_dst]))
    v' = v + LN(MLP_v([v, segment_sum(e', dst)]))
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import nn


@dataclasses.dataclass
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227          # output variables per node
    d_feat: int = 227          # input features per node (per-shape)
    mesh_refinement: int = 6   # metadata of the weather mesh configuration
    aggregator: str = "sum"
    norm_eps: float = 1e-6
    layer_unroll: int = 1      # <=0 -> full unroll (cost-extraction variant)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True


def _mlp_init(rng, d_in, d_hidden, d_out, dt):
    r1, r2 = nn.split_rngs(rng, 2)
    return {
        "l1": nn.linear_init(r1, d_in, d_hidden, ("gnn_in", "gnn_hidden"),
                             bias=True, dtype=dt),
        "l2": nn.linear_init(r2, d_hidden, d_out, ("gnn_hidden", "gnn_out"),
                             bias=True, dtype=dt),
        "norm": nn.layernorm_init(d_out, axes=("gnn_out",), dtype=dt),
    }


def _mlp(p, x, cfg):
    cd = cfg.compute_dtype
    h = jax.nn.silu(nn.linear(p["l1"], x, cd))
    h = nn.linear(p["l2"], h, cd)
    return nn.layernorm(p["norm"], h, cfg.norm_eps)


def _layer_init(rng, cfg: GraphCastConfig):
    r1, r2 = nn.split_rngs(rng, 2)
    D = cfg.d_hidden
    return {"edge_mlp": _mlp_init(r1, 3 * D, D, D, cfg.param_dtype),
            "node_mlp": _mlp_init(r2, 2 * D, D, D, cfg.param_dtype)}


def init(rng, cfg: GraphCastConfig):
    r_enc_n, r_enc_e, r_proc, r_dec = nn.split_rngs(rng, 4)
    D = cfg.d_hidden
    params = {
        "node_encoder": _mlp_init(r_enc_n, cfg.d_feat, D, D, cfg.param_dtype),
        # edge inputs: [src_feat_enc, dst_feat_enc] -> D  (no geometric features
        # on benchmark graphs; GraphCast's displacement features would slot here)
        "edge_encoder": _mlp_init(r_enc_e, 2 * D, D, D, cfg.param_dtype),
        "decoder": _mlp_init(r_dec, D, D, cfg.n_vars, cfg.param_dtype),
    }
    rngs = jnp.stack([jnp.asarray(x) for x in nn.split_rngs(r_proc, cfg.n_layers)])
    params["processor"] = jax.vmap(lambda rr: _layer_init(rr, cfg))(rngs)
    return params


def _aggregate(messages, dst, n_nodes, aggregator):
    if aggregator == "sum":
        return jax.ops.segment_sum(messages, dst, num_segments=n_nodes)
    if aggregator == "max":
        return jax.ops.segment_max(messages, dst, num_segments=n_nodes)
    if aggregator == "mean":
        s = jax.ops.segment_sum(messages, dst, num_segments=n_nodes)
        c = jax.ops.segment_sum(jnp.ones_like(dst, s.dtype), dst,
                                num_segments=n_nodes)
        return s / jnp.clip(c[:, None], 1)
    raise ValueError(aggregator)


def _processor_layer(p, v, e, src, dst, cfg: GraphCastConfig):
    """One interaction-network step. v: (N, D); e: (E, D); src/dst: (E,)."""
    n_nodes = v.shape[0]
    v = nn.constrain(v, ("act_rows", None))
    e = nn.constrain(e, ("act_rows", None))
    m_in = jnp.concatenate([e, v[src], v[dst]], axis=-1)
    e = e + _mlp(p["edge_mlp"], m_in, cfg)
    agg = _aggregate(e, dst, n_nodes, cfg.aggregator)
    v = v + _mlp(p["node_mlp"], jnp.concatenate([v, agg], axis=-1), cfg)
    return v, e


def forward(params, cfg: GraphCastConfig, node_feat, src, dst):
    """node_feat: (N, d_feat) -> per-node predictions (N, n_vars)."""
    cd = cfg.compute_dtype
    v = _mlp(params["node_encoder"], node_feat.astype(cd), cfg)
    e = _mlp(params["edge_encoder"],
             jnp.concatenate([v[src], v[dst]], axis=-1), cfg)

    def body(carry, lp):
        vv, ee = carry
        vv, ee = _processor_layer(lp, vv, ee, src, dst, cfg)
        return (vv, ee), None

    fn = jax.checkpoint(body) if cfg.remat else body
    (v, e), _ = jax.lax.scan(fn, (v, e), params["processor"],
                             unroll=(cfg.n_layers if cfg.layer_unroll <= 0
                                     else min(cfg.layer_unroll, cfg.n_layers)))
    return _mlp(params["decoder"], v, cfg).astype(jnp.float32)


def loss_fn(params, cfg: GraphCastConfig, batch):
    """MSE next-state loss (rollout surrogate).

    batch: {"node_feat": (N, d_feat), "src": (E,), "dst": (E,),
            "target": (N, n_vars), optional "node_mask": (N,)}
    Batched small graphs (molecule shape) are passed pre-flattened with
    disjoint edge indices (block-diagonal batching).
    """
    pred = forward(params, cfg, batch["node_feat"], batch["src"], batch["dst"])
    err = jnp.square(pred - batch["target"])
    mask = batch.get("node_mask")
    if mask is not None:
        err = err * mask[:, None]
        return err.sum() / jnp.clip(mask.sum() * cfg.n_vars, 1), {}
    return err.mean(), {}


def encode_nodes(params, cfg: GraphCastConfig, node_feat, src, dst):
    """Node embeddings (pre-decoder) — used for asyncval-style validation of
    GNN checkpoints when a retrieval-style metric over nodes is wanted."""
    cd = cfg.compute_dtype
    v = _mlp(params["node_encoder"], node_feat.astype(cd), cfg)
    e = _mlp(params["edge_encoder"],
             jnp.concatenate([v[src], v[dst]], axis=-1), cfg)

    def body(carry, lp):
        vv, ee = carry
        vv, ee = _processor_layer(lp, vv, ee, src, dst, cfg)
        return (vv, ee), None

    fn = jax.checkpoint(body) if cfg.remat else body
    (v, _), _ = jax.lax.scan(fn, (v, e), params["processor"],
                             unroll=(cfg.n_layers if cfg.layer_unroll <= 0
                                     else min(cfg.layer_unroll, cfg.n_layers)))
    v32 = v.astype(jnp.float32)
    return v32 / jnp.clip(jnp.linalg.norm(v32, axis=-1, keepdims=True), 1e-6)
