"""Minimal functional neural-net toolkit.

No flax on this box, so models are pure functions over parameter pytrees.
Conventions:

* Parameters are nested dicts of ``jnp`` arrays.
* During ``init`` every leaf is wrapped in :class:`Param`, which carries the
  *logical axis names* of each dimension (e.g. ``("embed", "mlp")``).  The
  logical axes are pytree aux-data, so ``jax.eval_shape`` over an init
  function yields a ``ShapeDtypeStruct`` tree *with* axis metadata — this is
  how the dry-run obtains parameter shardings without allocating anything.
* ``materialize(tree)`` strips :class:`Param` wrappers -> plain array pytree.
* ``logical_axes(tree)`` extracts the parallel axes pytree.
* ``repro.distributed.sharding`` maps logical axes -> mesh ``PartitionSpec``.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Any, Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Param wrapper
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """An array annotated with logical axis names (one per dim)."""

    value: Any
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    @property
    def shape(self):
        return self.value.shape

    @property
    def dtype(self):
        return self.value.dtype


def is_param(x) -> bool:
    return isinstance(x, Param)


def materialize(tree):
    """Strip Param wrappers -> plain pytree of arrays (or ShapeDtypeStructs)."""
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)


def logical_axes(tree):
    """Extract the logical-axes pytree parallel to ``materialize(tree)``."""
    return jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)


def abstract_init(init_fn: Callable, *args, **kwargs):
    """eval_shape an init function; returns (ShapeDtypeStruct tree, axes tree).

    All arguments are closed over (treated as static/constant), so non-array
    args such as config dataclasses are fine.
    """
    out = jax.eval_shape(lambda: init_fn(*args, **kwargs))
    return materialize(out), logical_axes(out)


# ---------------------------------------------------------------------------
# Logical activation-sharding constraints
# ---------------------------------------------------------------------------
#
# GSPMD propagates *parameter* shardings into activations, but for large
# batches it can legally choose layouts that replicate the batch dimension
# (observed: 32 GiB/device logit chunks on a 0.5B model).  Models therefore
# pin activations at layer boundaries with *logical* names ("act_batch",
# "act_seq", ...) resolved against a context installed by the launcher —
# models never see mesh axes, and with no context installed (unit tests,
# single device) ``constrain`` is the identity.

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, rules: Mapping[str, Any]):
    """rules: logical activation axis -> mesh axis (str/tuple) or None."""
    token = _ACT_CTX.set({"mesh": mesh, "rules": dict(rules)})
    try:
        yield
    finally:
        _ACT_CTX.reset(token)


def act_rule(name: str):
    """Mesh-axis assignment for one logical activation axis (or None when no
    context / no rule).  Used e.g. as ``vmap(..., spmd_axis_name=...)`` so
    GSPMD knows a mapped dim is sharded (MoE per-group dispatch)."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return None
    return ctx["rules"].get(name)


def constrain(x, logical_axes: Sequence[Optional[str]]):
    """with_sharding_constraint by logical activation axes (no-op without
    an installed context; unknown names and non-divisible dims replicate)."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx["mesh"], ctx["rules"]
    entries = []
    used = set()
    for dim, name in zip(x.shape, logical_axes):
        assignment = rules.get(name) if name is not None else None
        if assignment is None:
            entries.append(None)
            continue
        flat = (assignment,) if isinstance(assignment, str) else tuple(assignment)
        size = 1
        for a in flat:
            size *= mesh.shape[a]
        if any(a in flat for a in used) or dim % size != 0:
            entries.append(None)
            continue
        used.update(flat)
        entries.append(assignment)
    spec = jax.sharding.PartitionSpec(*entries)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(rng, shape, axes, stddev=0.02, dtype=jnp.float32) -> Param:
    return Param(jax.random.normal(rng, shape, dtype) * jnp.asarray(stddev, dtype), axes)


def fanin_init(rng, shape, axes, fan_in=None, dtype=jnp.float32) -> Param:
    """LeCun-normal style: stddev = 1/sqrt(fan_in)."""
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return Param(jax.random.normal(rng, shape, dtype) * jnp.asarray(std, dtype), axes)


def zeros_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Layers (init/apply pairs)
# ---------------------------------------------------------------------------


def linear_init(rng, in_dim, out_dim, axes, *, bias=True, dtype=jnp.float32,
                bias_axes=None):
    p = {"w": fanin_init(rng, (in_dim, out_dim), axes, dtype=dtype)}
    if bias:
        p["b"] = zeros_init((out_dim,), bias_axes if bias_axes is not None else (axes[-1],),
                            dtype=dtype)
    return p


def linear(params, x, compute_dtype=None):
    w = params["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def embedding_init(rng, vocab, dim, axes=("vocab", "embed"), dtype=jnp.float32):
    return {"table": normal_init(rng, (vocab, dim), axes, stddev=0.02, dtype=dtype)}


def embedding(params, ids, compute_dtype=None):
    t = params["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def layernorm_init(dim, axes=("embed",), dtype=jnp.float32):
    return {"scale": ones_init((dim,), axes, dtype=dtype),
            "bias": zeros_init((dim,), axes, dtype=dtype)}


def layernorm(params, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def rmsnorm_init(dim, axes=("embed",), dtype=jnp.float32):
    return {"scale": ones_init((dim,), axes, dtype=dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(ms + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """Rotary embedding.

    x: (..., seq, n_heads, head_dim); positions: broadcastable to (..., seq).
    Pairs dimension d with d + head_dim//2 (the "rotate_half" convention).
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc helpers
# ---------------------------------------------------------------------------


def split_rngs(rng, n):
    return list(jax.random.split(rng, n))


def count_params(params) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(math.prod(l.shape) for l in leaves))


def cast_floating(tree, dtype):
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, tree)
