"""Dense-retriever bi-encoder — the paper's model (§3 Encoder protocol, in JAX).

Asyncval's torch protocol is:

    class Encoder(torch.nn.Module):
        def __init__(self, ckpt_path, async_args): ...
        def encode_passage(self, psg) -> Tensor
        def encode_query(self, qry) -> Tensor

The JAX-native equivalent is :class:`EncoderSpec` — a pair of pure functions
over a parameter pytree, plus a loader that restores the pytree from a
checkpoint path (see ``repro.ckpt``).  Any architecture in the registry can be
wrapped into an EncoderSpec (LM backbones mean-pool; recsys models use their
item/user towers), which is how the paper's technique stays arch-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models import transformer as tfm


@dataclasses.dataclass
class EncoderSpec:
    """JAX-native Asyncval Encoder protocol.

    encode_query / encode_passage: (params, tokens (B,L) int32, mask (B,L) bool)
      -> (B, dim) float32 embeddings.
    """
    name: str
    dim: int
    encode_query: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    encode_passage: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    init: Callable[[Any], Any]                      # rng -> Param tree
    q_max_len: int = 32
    p_max_len: int = 128


def biencoder_spec(cfg: tfm.TransformerConfig, *, pooling: str = "cls",
                   q_max_len: int = 32, p_max_len: int = 128) -> EncoderSpec:
    """Shared-weight bi-encoder over a transformer trunk (Tevatron default)."""

    def enc(params, tokens, mask):
        return tfm.encode(params, cfg, tokens, mask, pooling)

    return EncoderSpec(name=cfg.name, dim=cfg.d_model,
                       encode_query=enc, encode_passage=enc,
                       init=lambda rng: tfm.init(rng, cfg),
                       q_max_len=q_max_len, p_max_len=p_max_len)


def contrastive_loss(params, spec: EncoderSpec, batch, *, temperature: float = 1.0):
    """In-batch-negative softmax CE (Tevatron / DPR training objective).

    batch: {"q_tokens": (B, Lq), "q_mask": (B, Lq),
            "p_tokens": (B, n_psg, Lp), "p_mask": (B, n_psg, Lp)}
    p[i, 0] is the positive for query i; all other passages in the batch act
    as negatives (n_psg - 1 explicit hard negatives per query supported).
    """
    q = spec.encode_query(params, batch["q_tokens"], batch["q_mask"])      # (B, D)
    B, n_psg, Lp = batch["p_tokens"].shape
    p_tok = batch["p_tokens"].reshape(B * n_psg, Lp)
    p_msk = batch["p_mask"].reshape(B * n_psg, Lp)
    p = spec.encode_passage(params, p_tok, p_msk)                          # (B*n, D)
    scores = (q @ p.T) / temperature                                       # (B, B*n)
    labels = jnp.arange(B) * n_psg                                         # positives
    lse = jax.nn.logsumexp(scores, axis=-1)
    pos = jnp.take_along_axis(scores, labels[:, None], axis=1)[:, 0]
    loss = jnp.mean(lse - pos)
    acc = jnp.mean((jnp.argmax(scores, axis=-1) == labels).astype(jnp.float32))
    return loss, {"contrastive_acc": acc}


def loss_fn(params, cfg: tfm.TransformerConfig, batch):
    """Registry-compatible loss entry (family='biencoder')."""
    spec = biencoder_spec(cfg)
    return contrastive_loss(params, spec, batch)
