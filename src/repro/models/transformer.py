"""LM-family transformer supporting the assigned architecture pool.

One flexible implementation covers:
  * dense llama-style (deepseek-67b)            — GQA, RoPE, SwiGLU, RMSNorm
  * qwen2 (0.5b / 72b)                          — GQA + QKV bias
  * arctic-480b                                 — dense FFN + *residual* 128-expert top-2 MoE
  * deepseek-v2-lite-16b                        — MLA (kv_lora=512) + 64-expert top-6 MoE,
                                                  2 shared experts, first layer dense
  * BERT-style encoder (paper's bi-encoder)     — post-LN, GELU, learned positions, bidir

Design notes
  * layers are stacked (leading L dim) and iterated with ``lax.scan`` so compile
    time is O(1) in depth; ``jax.checkpoint`` around the block gives remat.
  * attention is computed in query chunks (``lax.scan`` over q blocks) so the
    full (S, T) score matrix is never materialized — the XLA-level analogue of
    the Pallas flash kernel in ``repro.kernels.flash_attention`` (the TPU-target
    path; selected with ``attn_impl="pallas"``).
  * MoE uses sort-based gather/scatter dispatch (no GShard one-hot einsum): the
    dispatched activation tensor is the only O(tokens x topk x d_model) buffer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import nn

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TransformerConfig:
    name: str = "transformer"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    vocab_size: int = 1000
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    causal: bool = True
    act: str = "swiglu"                 # swiglu | gelu
    use_rope: bool = True
    max_position_embeddings: int = 0    # learned positions when >0 (BERT style)
    norm_style: str = "pre"             # pre (rms) | post (layernorm, BERT)
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_num_shared: int = 0
    moe_mode: str = "replace"           # replace | residual (arctic)
    moe_capacity_factor: float = 1.25
    first_k_dense: int = 0
    router_aux_coef: float = 0.01
    # --- MLA ---
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- execution ---
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 512                  # attention query-chunk size
    vocab_chunk: int = 0                # 0 = full logits; >0 = chunked xent
    attn_impl: str = "xla"              # xla | pallas (TPU target)
    # --- cost-extraction unrolls (roofline methodology, DESIGN.md §2.7) ---
    # XLA's cost_analysis counts a while(scan) body ONCE, not x trip-count;
    # the dry-run's cost-extraction variant fully unrolls every inner scan
    # (layers / attention q-chunks / vocab chunks) at reduced depth so
    # per-layer costs are counted exactly, then extrapolates to full depth.
    layer_unroll: int = 1
    attn_unroll: int = 1
    xent_unroll: int = 1
    # Expand KV heads to full H for the score/PV einsums (training only —
    # no cache involved).  With KV < TP degree, the grouped (B,S,KV,G,hd)
    # layout cannot shard heads over "model" (KV=8 < 16) and the O(S*T)
    # score tensor replicates across the TP axis; expansion restores a flat
    # (B,S,H,hd) layout that shards.  kv bytes grow G-fold but the score
    # tensor shrinks TP-fold — the Megatron GQA-under-TP training layout.
    attn_expand_kv: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.moe_num_experts > 0


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _attn_init(rng, cfg: TransformerConfig):
    """Attention parameters for one layer (un-stacked)."""
    rngs = nn.split_rngs(rng, 8)
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    p = {}
    if cfg.mla:
        qdim = H * (cfg.qk_nope_dim + cfg.qk_rope_dim)
        p["wq"] = nn.fanin_init(rngs[0], (D, qdim), ("embed", "heads"), dtype=dt)
        # joint down-projection -> [c_kv (kv_lora) | k_rope (rope_dim)]
        p["wdkv"] = nn.fanin_init(rngs[1], (D, cfg.kv_lora_rank + cfg.qk_rope_dim),
                                  ("embed", "kv_lora"), dtype=dt)
        p["kv_norm"] = nn.rmsnorm_init(cfg.kv_lora_rank, axes=("kv_lora",), dtype=dt)
        p["wuk"] = nn.fanin_init(rngs[2], (cfg.kv_lora_rank, H * cfg.qk_nope_dim),
                                 ("kv_lora", "heads"), dtype=dt)
        p["wuv"] = nn.fanin_init(rngs[3], (cfg.kv_lora_rank, H * cfg.v_head_dim),
                                 ("kv_lora", "heads"), dtype=dt)
        p["wo"] = nn.fanin_init(rngs[4], (H * cfg.v_head_dim, D), ("heads", "embed"),
                                fan_in=H * cfg.v_head_dim, dtype=dt)
    else:
        p["wq"] = nn.fanin_init(rngs[0], (D, H * hd), ("embed", "heads"), dtype=dt)
        p["wk"] = nn.fanin_init(rngs[1], (D, KV * hd), ("embed", "kv_heads"), dtype=dt)
        p["wv"] = nn.fanin_init(rngs[2], (D, KV * hd), ("embed", "kv_heads"), dtype=dt)
        p["wo"] = nn.fanin_init(rngs[3], (H * hd, D), ("heads", "embed"),
                                fan_in=H * hd, dtype=dt)
        if cfg.qkv_bias:
            p["bq"] = nn.zeros_init((H * hd,), ("heads",), dtype=dt)
            p["bk"] = nn.zeros_init((KV * hd,), ("kv_heads",), dtype=dt)
            p["bv"] = nn.zeros_init((KV * hd,), ("kv_heads",), dtype=dt)
    return p


def _dense_mlp_init(rng, cfg: TransformerConfig, d_ff: int):
    rngs = nn.split_rngs(rng, 3)
    D, dt = cfg.d_model, cfg.param_dtype
    if cfg.act == "swiglu":
        return {"w1": nn.fanin_init(rngs[0], (D, d_ff), ("embed", "mlp"), dtype=dt),
                "w3": nn.fanin_init(rngs[1], (D, d_ff), ("embed", "mlp"), dtype=dt),
                "w2": nn.fanin_init(rngs[2], (d_ff, D), ("mlp", "embed"),
                                    fan_in=d_ff, dtype=dt)}
    return {"w1": nn.linear_init(rngs[0], D, d_ff, ("embed", "mlp"), bias=True, dtype=dt),
            "w2": nn.linear_init(rngs[1], d_ff, D, ("mlp", "embed"), bias=True, dtype=dt)}


def _moe_init(rng, cfg: TransformerConfig):
    rngs = nn.split_rngs(rng, 5)
    D, E, F, dt = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff, cfg.param_dtype
    p = {"router": nn.normal_init(rngs[0], (D, E), ("embed", "expert"),
                                  stddev=0.02, dtype=jnp.float32)}
    p["w1"] = nn.fanin_init(rngs[1], (E, D, F), ("expert", "embed", "mlp"),
                            fan_in=D, dtype=dt)
    p["w3"] = nn.fanin_init(rngs[2], (E, D, F), ("expert", "embed", "mlp"),
                            fan_in=D, dtype=dt)
    p["w2"] = nn.fanin_init(rngs[3], (E, F, D), ("expert", "mlp", "embed"),
                            fan_in=F, dtype=dt)
    if cfg.moe_num_shared:
        p["shared"] = _dense_mlp_init(rngs[4], cfg, cfg.moe_num_shared * F)
    return p


def _norm_init(cfg: TransformerConfig):
    if cfg.norm_style == "post":
        return nn.layernorm_init(cfg.d_model, dtype=cfg.param_dtype)
    return nn.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype)


def _layer_init(rng, cfg: TransformerConfig, *, moe: bool):
    r1, r2, r3 = nn.split_rngs(rng, 3)
    p = {"attn_norm": _norm_init(cfg), "mlp_norm": _norm_init(cfg),
         "attn": _attn_init(r1, cfg)}
    if moe:
        p["moe"] = _moe_init(r2, cfg)
        if cfg.moe_mode == "residual":
            p["mlp"] = _dense_mlp_init(r3, cfg, cfg.d_ff)
    else:
        p["mlp"] = _dense_mlp_init(r3, cfg, cfg.d_ff)
    return p


def init(rng, cfg: TransformerConfig):
    """Returns a Param tree. Layer params are stacked along a leading L axis."""
    r_emb, r_layers, r_head, r_pos = nn.split_rngs(rng, 4)

    params = {"embed": nn.embedding_init(r_emb, cfg.vocab_size, cfg.d_model,
                                         axes=("vocab", "embed"), dtype=cfg.param_dtype)}
    if cfg.max_position_embeddings:
        params["pos_embed"] = nn.embedding_init(
            r_pos, cfg.max_position_embeddings, cfg.d_model,
            axes=("pos", "embed"), dtype=cfg.param_dtype)

    n_dense = cfg.first_k_dense if cfg.is_moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.is_moe else 0

    def stack_init(r, n, moe):
        if n == 0:
            return None
        rngs = jnp.stack([jnp.asarray(x) for x in nn.split_rngs(r, n)])
        def one(rr):
            return _layer_init(rr, cfg, moe=moe)
        return jax.vmap(lambda rr: one(rr))(rngs)

    r_dense, r_moe = nn.split_rngs(r_layers, 2)
    dense_stack = stack_init(r_dense, n_dense, moe=False)
    if dense_stack is not None:
        # vmap strips Param wrappers' aux? No: vmap maps over arrays inside Param
        params["dense_layers"] = dense_stack
    moe_stack = stack_init(r_moe, n_moe, moe=True)
    if moe_stack is not None:
        params["moe_layers"] = moe_stack

    params["final_norm"] = _norm_init(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": nn.fanin_init(r_head, (cfg.d_model, cfg.vocab_size),
                                                ("embed", "vocab"), dtype=cfg.param_dtype)}
    return params


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _norm(p, x, cfg):
    if cfg.norm_style == "post":
        return nn.layernorm(p, x, cfg.norm_eps)
    return nn.rmsnorm(p, x, cfg.norm_eps)


def _chunked_attention(q, k, v, *, causal: bool, q_offset, q_chunk: int,
                       kv_mask=None, unroll: int = 1):
    """Grouped-query attention computed in query chunks.

    q: (B, S, KV, G, hd) ; k, v: (B, T, KV, hd).
    q_offset: scalar — absolute position of q[0] (for causal masking in decode).
    kv_mask: optional (B, T) validity mask.
    Returns (B, S, KV, G, hd).
    """
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    dv = v.shape[-1]  # may differ from hd (MLA)
    scale = jnp.asarray(1.0 / (hd ** 0.5), jnp.float32)
    nq = max(1, min(q_chunk, S))
    n_chunks = -(-S // nq)
    pad = n_chunks * nq - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qc = q.reshape(B, n_chunks, nq, KV, G, hd)
    kpos = jnp.arange(T)

    def one_chunk(carry, inp):
        qi, ci = inp
        # bf16 operands + f32 accumulation (preferred_element_type) — the
        # MXU-native form.  Explicit .astype(f32) on k made XLA materialize
        # an f32 copy of the whole KV cache (loop-hoisted out of the layer
        # scan: +2x cache memory measured on qwen2-72b decode).
        s = jnp.einsum("bqkgd,btkd->bkgqt", qi * scale.astype(qi.dtype), k,
                       preferred_element_type=jnp.float32)
        if causal:
            qpos = q_offset + ci * nq + jnp.arange(nq)
            m = kpos[None, :] <= qpos[:, None]          # (nq, T)
            s = jnp.where(m[None, None, None], s, -1e30)
        if kv_mask is not None:
            s = jnp.where(kv_mask[:, None, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return carry, o.astype(v.dtype)

    # checkpoint each chunk: without it, differentiating the scan stacks the
    # (B, KV, G, nq, T) softmax residuals across ALL chunks — O(S*T) memory,
    # exactly what chunking exists to avoid.  With it, backward recomputes
    # each chunk's scores on the fly (the flash-attention backward).
    _, outs = jax.lax.scan(jax.checkpoint(one_chunk), None,
                           (jnp.moveaxis(qc, 1, 0), jnp.arange(n_chunks)),
                           unroll=(n_chunks if unroll <= 0
                                   else min(unroll, n_chunks)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_chunks * nq, KV, G, dv)
    return out[:, :S]


def _attention(p, x, cfg: TransformerConfig, *, positions, cache=None,
               cache_index=None, kv_mask=None):
    """Standard (non-MLA) GQA attention. Returns (out, new_cache)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.compute_dtype
    q = x @ p["wq"].astype(cd)
    k = x @ p["wk"].astype(cd)
    v = x @ p["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = nn.constrain(q.reshape(B, S, H, hd),
                     ("act_batch", "act_seq", "act_heads", None))
    k = nn.constrain(k.reshape(B, S, KV, hd),
                     ("act_batch", "act_seq", "act_kv_heads", None))
    v = nn.constrain(v.reshape(B, S, KV, hd),
                     ("act_batch", "act_seq", "act_kv_heads", None))
    if cfg.use_rope:
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:
        # write this step's k/v at cache_index (decode: S == 1)
        ck, cv = cache["k"], cache["v"]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        k_all, v_all = ck.astype(cd), cv.astype(cd)
        new_cache = {"k": ck, "v": cv}
        T = ck.shape[1]
        # positions < cache_index + S are populated (prefill writes S at once)
        valid = jnp.arange(T)[None, :] < (cache_index + S)
        kv_mask = valid if kv_mask is None else (kv_mask & valid)
        q_offset = cache_index
    else:
        k_all, v_all = k, v
        new_cache = None
        q_offset = jnp.asarray(0, jnp.int32)

    if cfg.attn_expand_kv and cache is None:
        g = H // KV
        k_all = nn.constrain(jnp.repeat(k_all, g, axis=2),
                             ("act_batch", "act_seq", "act_heads", None))
        v_all = nn.constrain(jnp.repeat(v_all, g, axis=2),
                             ("act_batch", "act_seq", "act_heads", None))
        qg = q.reshape(B, S, H, 1, hd)
        out = _chunked_attention(qg, k_all, v_all, causal=cfg.causal,
                                 q_offset=q_offset, q_chunk=cfg.q_chunk,
                                 kv_mask=kv_mask, unroll=cfg.attn_unroll)
        out = out.reshape(B, S, H * hd)
        out = out @ p["wo"].astype(cd)
        return out, new_cache
    if cfg.attn_impl == "pallas" and cache is None and kv_mask is None:
        # TPU-target fused kernel (interpret-mode on CPU).  The cached /
        # masked paths keep the XLA implementation: decode uses the
        # decode_attention kernel via serving code, and ragged kv masks
        # need the t_valid scalar plumbing of ops.flash_attention.
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k_all, 1, 2),
                            jnp.moveaxis(v_all, 1, 2), causal=cfg.causal)
        out = jnp.moveaxis(o, 1, 2).reshape(B, S, H * hd)
    else:
        qg = q.reshape(B, S, KV, H // KV, hd)
        out = _chunked_attention(qg, k_all, v_all, causal=cfg.causal,
                                 q_offset=q_offset, q_chunk=cfg.q_chunk,
                                 kv_mask=kv_mask, unroll=cfg.attn_unroll)
        out = out.reshape(B, S, H * hd)
    out = out @ p["wo"].astype(cd)
    return out, new_cache


def _mla_attention(p, x, cfg: TransformerConfig, *, positions, cache=None,
                   cache_index=None, kv_mask=None):
    """Multi-head latent attention (DeepSeek-V2). Cache stores (c_kv, k_rope).

    Prefill/train: expand c_kv -> per-head K_nope/V and run standard attention.
    Decode: *absorbed* form — queries are projected into the kv_lora space so
    attention runs directly against the compressed cache (the memory win MLA
    was designed for).
    """
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv, R = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    cd = cfg.compute_dtype

    q = (x @ p["wq"].astype(cd)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = nn.apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["wdkv"].astype(cd)                    # (B, S, R + dr)
    c_kv = nn.rmsnorm(p["kv_norm"], dkv[..., :R], cfg.norm_eps)
    k_rope = dkv[..., R:].reshape(B, S, 1, dr)
    k_rope = nn.apply_rope(k_rope, positions, cfg.rope_theta)

    scale = 1.0 / jnp.sqrt(jnp.asarray(dn + dr, jnp.float32))

    if cache is not None and S == 1:
        # ---- absorbed decode (attention directly in the compressed space) ----
        cc, cr = cache["ckv"], cache["krope"]
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, cache_index, 0))
        cr = jax.lax.dynamic_update_slice(cr, k_rope[:, :, 0].astype(cr.dtype),
                                          (0, cache_index, 0))
        new_cache = {"ckv": cc, "krope": cr}
        T = cc.shape[1]
        valid = (jnp.arange(T)[None, :] <= cache_index)
        if kv_mask is not None:
            valid = valid & kv_mask
        wuk = p["wuk"].astype(cd).reshape(R, H, dn)
        # q' = q_nope @ wuk^T per head: (B,S,H,R)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wuk)
        # bf16 operands, f32 accumulation (no f32 cache copies — see
        # _chunked_attention)
        s = jnp.einsum("bshr,btr->bhst", q_lat, cc,
                       preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bshd,btd->bhst", q_rope, cr,
                           preferred_element_type=jnp.float32)
        s = s * scale
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        prob = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", prob.astype(cd), cc.astype(cd))  # (B,S,H,R)
        wuv = p["wuv"].astype(cd).reshape(R, H, dv)
        out = jnp.einsum("bshr,rhd->bshd", ctx, wuv)
    else:
        # train / prefill: expand the compressed kv and run chunked attention.
        q_offset = jnp.asarray(0, jnp.int32)
        if cache is not None:
            cc, cr = cache["ckv"], cache["krope"]
            cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype),
                                              (0, cache_index, 0))
            cr = jax.lax.dynamic_update_slice(cr, k_rope[:, :, 0].astype(cr.dtype),
                                              (0, cache_index, 0))
            new_cache = {"ckv": cc, "krope": cr}
            c_all, r_all = cc.astype(cd), cr.astype(cd)[:, :, None]
            T = cc.shape[1]
            valid = jnp.arange(T)[None, :] < (cache_index + S)
            kv_mask = valid if kv_mask is None else (kv_mask & valid)
            q_offset = cache_index
        else:
            new_cache = None
            c_all, r_all = c_kv, k_rope
        T = c_all.shape[1]
        k_nope = (c_all @ p["wuk"].astype(cd)).reshape(B, T, H, dn)
        vv = (c_all @ p["wuv"].astype(cd)).reshape(B, T, H, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(r_all, (B, T, H, dr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        # MLA has H kv heads (no grouping): KV=H, G=1
        qg = qq.reshape(B, S, H, 1, dn + dr)
        out = _chunked_attention(qg, k, vv, causal=cfg.causal,
                                 q_offset=q_offset,
                                 q_chunk=cfg.q_chunk, kv_mask=kv_mask,
                                 unroll=cfg.attn_unroll)
        out = out.reshape(B, S, H, dv)

    out = out.reshape(B, S, H * dv) @ p["wo"].astype(cd)
    return out, new_cache


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------


def _dense_mlp(p, x, cfg: TransformerConfig):
    cd = cfg.compute_dtype
    if cfg.act == "swiglu":
        h = nn.silu(x @ p["w1"].astype(cd)) * (x @ p["w3"].astype(cd))
        return h @ p["w2"].astype(cd)
    h = nn.gelu(nn.linear(p["w1"], x, cd))
    return nn.linear(p["w2"], h, cd)


def _moe_dispatch(x_flat, expert_idx, gates, E: int, capacity: int):
    """Sort-based dispatch for one group.

    x_flat: (S, D); expert_idx/gates: (S, K).
    Returns (xe (E, C, D), slot_tok (E*C,), slot_gate (E*C,), slot_valid (E*C,)).
    """
    S, K = expert_idx.shape
    N = S * K
    flat_e = expert_idx.reshape(N)
    flat_g = gates.reshape(N)
    flat_tok = jnp.repeat(jnp.arange(S), K)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, stok = flat_e[order], flat_g[order], flat_tok[order]
    counts = jax.ops.segment_sum(jnp.ones(N, jnp.int32), se, num_segments=E)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N, dtype=jnp.int32) - starts[se]
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, E * capacity)  # overflow -> dropped
    slot_tok = jnp.zeros(E * capacity + 1, jnp.int32).at[slot].set(stok)[:-1]
    slot_gate = jnp.zeros(E * capacity + 1, flat_g.dtype).at[slot].set(sg)[:-1]
    slot_valid = jnp.zeros(E * capacity + 1, jnp.bool_).at[slot].set(keep)[:-1]
    xe = x_flat[slot_tok].reshape(E, capacity, -1)
    return xe, slot_tok, slot_gate, slot_valid


def _moe_block(p, x, cfg: TransformerConfig):
    """Token-choice top-k MoE with sort-based dispatch.

    x: (B, S, D) — each batch row is a routing group.
    Returns (out, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    cd = cfg.compute_dtype
    capacity = max(1, int(S * K / E * cfg.moe_capacity_factor))

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, K)                          # (B,S,K)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (GShard): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                                    # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    fe = jnp.mean(one_hot_top1, axis=(0, 1))
    aux = E * jnp.sum(me * fe)

    w1, w3, w2 = (p["w1"].astype(cd), p["w3"].astype(cd), p["w2"].astype(cd))

    # spmd_axis_name tells GSPMD the vmapped batch dim stays sharded on the
    # DP axes — without it the per-group dispatch buffers (B, E, C, D) are
    # free to replicate on the batch dim (observed: TB-scale buffers).
    spmd_axis = nn.act_rule("act_batch")
    xe, slot_tok, slot_gate, slot_valid = jax.vmap(
        lambda xg, eg, gg: _moe_dispatch(xg, eg, gg.astype(cd), E, capacity),
        spmd_axis_name=spmd_axis)(x.astype(cd), expert_idx, gates)
    # expert dim sharded (EP): the dispatch gather runs EP-local — without
    # this constraint GSPMD all-gathered the (B, E, C, D) dispatch buffer
    # across the mesh (23.7 GB/layer/device on arctic-480b, §Perf iter a5).
    xe = nn.constrain(xe, ("act_batch", "act_expert", None, None))

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w1))
    h = h * jnp.einsum("becd,edf->becf", xe, w3)
    oe = jnp.einsum("becf,efd->becd", h, w2)                     # (B,E,C,D)
    wgt = (slot_gate * slot_valid.astype(cd)).reshape(B, E, capacity)
    oe = oe * wgt[..., None]
    seg = jnp.where(slot_valid, slot_tok, S)                     # dropped -> S

    # combine: per-group scatter-add back to (S, D).  (A flat global
    # scatter over B*E*C was tried and REFUTED — GSPMD emitted more
    # gathers, §Perf iter a6; the per-group form + EP-sharded dispatch
    # above is the best measured layout.)
    def combine(oe_g, seg_g):
        return jax.ops.segment_sum(oe_g.reshape(E * capacity, D),
                                   seg_g.reshape(-1),
                                   num_segments=S + 1)[:S]

    out = jax.vmap(combine, spmd_axis_name=spmd_axis)(
        oe, seg.reshape(B, E, capacity))
    out = nn.constrain(out, ("act_batch", "act_seq", "act_embed"))
    if cfg.moe_num_shared:
        out = out + _dense_mlp(p["shared"], x, cfg)
    return out, aux


# ---------------------------------------------------------------------------
# Layer block + full forward
# ---------------------------------------------------------------------------


def _layer(p, x, cfg: TransformerConfig, *, positions, moe: bool, cache=None,
           cache_index=None, kv_mask=None):
    x = nn.constrain(x, ("act_batch", "act_seq", "act_embed"))
    attn_fn = _mla_attention if cfg.mla else _attention
    if cfg.norm_style == "post":
        a, new_cache = attn_fn(p["attn"], x, cfg, positions=positions, cache=cache,
                               cache_index=cache_index, kv_mask=kv_mask)
        x = _norm(p["attn_norm"], x + a, cfg)
        m = _dense_mlp(p["mlp"], x, cfg)
        x = _norm(p["mlp_norm"], x + m, cfg)
        return x, new_cache, jnp.zeros((), jnp.float32)
    # pre-norm
    a, new_cache = attn_fn(p["attn"], _norm(p["attn_norm"], x, cfg), cfg,
                           positions=positions, cache=cache,
                           cache_index=cache_index, kv_mask=kv_mask)
    x = x + a
    h = _norm(p["mlp_norm"], x, cfg)
    aux = jnp.zeros((), jnp.float32)
    if moe:
        mo, aux = _moe_block(p["moe"], h, cfg)
        if cfg.moe_mode == "residual":
            mo = mo + _dense_mlp(p["mlp"], h, cfg)
    else:
        mo = _dense_mlp(p["mlp"], h, cfg)
    return x + mo, new_cache, aux


def _scan_stack(stack_params, x, cfg, *, moe, positions, caches=None,
                cache_index=None, kv_mask=None):
    """Scan a stacked layer group. caches: pytree stacked on leading L axis."""
    def body(carry, inp):
        h = carry
        lp, lc = inp
        h, new_cache, aux = _layer(lp, h, cfg, positions=positions, moe=moe,
                                   cache=lc, cache_index=cache_index,
                                   kv_mask=kv_mask)
        return h, (new_cache, aux)

    fn = jax.checkpoint(body) if cfg.remat else body
    n = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
    x, (new_caches, auxs) = jax.lax.scan(
        fn, x, (stack_params, caches),
        unroll=(n if cfg.layer_unroll <= 0 else min(cfg.layer_unroll, n)))
    return x, new_caches, jnp.sum(auxs)


def forward(params, cfg: TransformerConfig, tokens, *, caches=None,
            cache_index=None, kv_mask=None, positions=None):
    """Run the trunk. Returns (hidden (B,S,D), new_caches, aux_loss)."""
    B, S = tokens.shape
    cd = cfg.compute_dtype
    x = nn.embedding(params["embed"], tokens, cd)
    x = nn.constrain(x, ("act_batch", "act_seq", "act_embed"))
    if positions is None:
        if cache_index is not None:
            positions = cache_index + jnp.arange(S)[None, :]
        else:
            positions = jnp.arange(S)[None, :] * jnp.ones((B, 1), jnp.int32)
    if cfg.max_position_embeddings:
        x = x + nn.embedding(params["pos_embed"], positions, cd)

    aux_total = jnp.zeros((), jnp.float32)
    dense_caches = caches.get("dense") if caches is not None else None
    moe_caches = caches.get("moe") if caches is not None else None
    new_caches = {}
    if "dense_layers" in params:
        x, nc, aux = _scan_stack(params["dense_layers"], x, cfg, moe=False,
                                 positions=positions, caches=dense_caches,
                                 cache_index=cache_index, kv_mask=kv_mask)
        new_caches["dense"] = nc
        aux_total += aux
    if "moe_layers" in params:
        x, nc, aux = _scan_stack(params["moe_layers"], x, cfg, moe=True,
                                 positions=positions, caches=moe_caches,
                                 cache_index=cache_index, kv_mask=kv_mask)
        new_caches["moe"] = nc
        aux_total += aux
    if caches is not None:
        # preserve key parity with the input cache tree (a dense model's
        # init_cache carries "moe": None; dropping the key changes the
        # pytree structure and breaks scan/jit out_shardings matching)
        for key in caches:
            new_caches.setdefault(key, caches[key])
    x = nn.constrain(x, ("act_batch", "act_seq", "act_embed"))
    x = _norm(params["final_norm"], x, cfg)
    return x, (new_caches if caches is not None else None), aux_total


def _lm_head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def logits(params, cfg: TransformerConfig, hidden):
    w = _lm_head_weight(params, cfg).astype(cfg.compute_dtype)
    return hidden @ w


def chunked_softmax_xent(hidden, w_lm, labels, mask, chunk: int,
                         unroll: int = 1):
    """Cross-entropy without materializing full (..., V) logits.

    hidden: (..., D) f/bf16; w_lm: (D, V); labels: (...,) int32; mask bool.
    Scans over vocab chunks keeping a running logsumexp + the label logit.
    Leading dims are PRESERVED (no token flattening) so the batch sharding
    survives GSPMD propagation — flattening (B, S, D) -> (B*S, D) merges the
    sharded batch dim into an unshardable reshape and replicates the logits.
    """
    lead = hidden.shape[:-1]
    D = hidden.shape[-1]
    V = w_lm.shape[1]
    n_chunks = -(-V // chunk)
    Vp = n_chunks * chunk
    wp = jnp.pad(w_lm, ((0, 0), (0, Vp - V)))
    wc = wp.reshape(D, n_chunks, chunk)

    def body(carry, inp):
        run_lse, lab_logit = carry
        w_i, ci = inp
        lg = (hidden @ w_i).astype(jnp.float32)                  # (..., chunk)
        lg = nn.constrain(lg, ("act_batch", "act_seq", "act_vocab"))
        base = ci * chunk
        valid = (base + jnp.arange(chunk)) < V
        lg = jnp.where(valid, lg, -jnp.inf)
        chunk_lse = jax.nn.logsumexp(lg, axis=-1)
        run_lse = jnp.logaddexp(run_lse, chunk_lse)
        local = labels - base
        inside = (local >= 0) & (local < chunk)
        got = jnp.take_along_axis(lg, jnp.clip(local, 0, chunk - 1)[..., None],
                                  axis=-1)[..., 0]
        lab_logit = jnp.where(inside, got, lab_logit)
        return (run_lse, lab_logit), None

    init = (jnp.full(lead, -jnp.inf, jnp.float32),
            jnp.full(lead, -jnp.inf, jnp.float32))
    # checkpoint: backward recomputes each chunk's logits instead of stacking
    # (..., chunk) f32 residuals across all vocab chunks (same reasoning as
    # the attention q-chunk scan).
    (lse, lab), _ = jax.lax.scan(jax.checkpoint(body), init,
                                 (jnp.moveaxis(wc, 1, 0), jnp.arange(n_chunks)),
                                 unroll=(n_chunks if unroll <= 0
                                         else min(unroll, n_chunks)))
    nll = (lse - lab) * mask
    return nll.sum() / jnp.clip(mask.sum(), 1)


def lm_loss(params, cfg: TransformerConfig, batch):
    """Causal LM loss. batch: {"tokens": (B,S) int32} (optionally "mask")."""
    tokens = batch["tokens"]
    mask = batch.get("mask", jnp.ones_like(tokens, jnp.bool_))
    hidden, _, aux = forward(params, cfg, tokens)
    tgt = tokens[:, 1:]
    h = hidden[:, :-1]
    m = mask[:, 1:] & mask[:, :-1]
    w = _lm_head_weight(params, cfg).astype(cfg.compute_dtype)
    if cfg.vocab_chunk:
        xent = chunked_softmax_xent(h, w, tgt, m,
                                    cfg.vocab_chunk, unroll=cfg.xent_unroll)
    else:
        lg = (h @ w).astype(jnp.float32)                     # (B, S1, V)
        lse = jax.nn.logsumexp(lg, axis=-1)
        lab = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        xent = ((lse - lab) * m).sum() / jnp.clip(m.sum(), 1)
    loss = xent + cfg.router_aux_coef * aux
    return loss, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# Serving entry points
# ---------------------------------------------------------------------------


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Abstract-friendly cache pytree, stacked per layer group."""
    n_dense = cfg.first_k_dense if cfg.is_moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.is_moe else 0

    def one(n):
        if n == 0:
            return None
        if cfg.mla:
            return {"ckv": jnp.zeros((n, batch, max_len, cfg.kv_lora_rank), dtype),
                    "krope": jnp.zeros((n, batch, max_len, cfg.qk_rope_dim), dtype)}
        return {"k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype)}

    return {"dense": one(n_dense), "moe": one(n_moe)}


def prefill(params, cfg: TransformerConfig, tokens, max_len: int = 0):
    """Encode a prompt, returning (last-token logits, caches).

    max_len: cache capacity (0 -> prompt length; set larger to decode after)."""
    B, S = tokens.shape
    caches = init_cache(cfg, B, max(max_len, S), dtype=cfg.compute_dtype)
    # cache_index = 0: positions 0..S-1 are written via dynamic_update_slice
    hidden, caches, _ = forward(params, cfg, tokens, caches=caches,
                                cache_index=jnp.asarray(0, jnp.int32))
    return logits(params, cfg, hidden[:, -1:]), caches


def decode_step(params, cfg: TransformerConfig, caches, token, index):
    """One decode step. token: (B,1) int32; index: scalar position to write."""
    hidden, caches, _ = forward(params, cfg, token, caches=caches,
                                cache_index=index)
    return logits(params, cfg, hidden), caches


# ---------------------------------------------------------------------------
# Embedding/encoding entry point (dense-retriever usage)
# ---------------------------------------------------------------------------


def encode(params, cfg: TransformerConfig, tokens, mask, pooling: str = "mean"):
    """Embed token sequences -> (B, D) L2-normalized vectors."""
    hidden, _, _ = forward(params, cfg, tokens, kv_mask=mask)
    if pooling == "cls":
        emb = hidden[:, 0]
    else:
        m = mask.astype(hidden.dtype)[..., None]
        emb = (hidden * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
    emb = emb.astype(jnp.float32)
    return emb / jnp.clip(jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-6)
