"""RecSys architecture pool: bert4rec, sasrec, mind, deepfm.

All four follow the production recommender layout: huge row-sharded embedding
tables -> feature-interaction op -> small MLP / scoring head.  Sequential
models (bert4rec, sasrec) reuse the transformer trunk; mind adds capsule
dynamic routing; deepfm is FM + deep MLP over 39 sparse fields.

Training over a 10^6-item vocabulary uses **sampled softmax** (shared negative
pool per batch, the industry standard) — full 1M-way softmax per position is
never materialized.  Retrieval-style validation (the paper's technique) scores
a user vector against the full item table via ``repro.core.retrieval``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.models import embedding_ops, nn
from repro.models import transformer as tfm

# Criteo-Kaggle categorical cardinalities (DLRM convention) + 13 numeric
# fields bucketized to 64 bins each -> 39 sparse fields, ~33.8M rows total.
CRITEO_CAT_VOCABS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572)
CRITEO_NUM_BUCKETS = (64,) * 13


@dataclasses.dataclass
class RecsysConfig:
    name: str = "recsys"
    model_type: str = "sasrec"        # bert4rec | sasrec | mind | deepfm
    embed_dim: int = 64
    item_vocab: int = 1_000_000
    seq_len: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    d_ff: int = 0                     # 0 -> embed_dim (sasrec) / 4x (bert4rec)
    n_interests: int = 4
    capsule_iters: int = 3
    field_vocab_sizes: Tuple[int, ...] = ()
    max_hot: int = 1                  # multi-hot width per sparse field
    mlp_dims: Tuple[int, ...] = (400, 400, 400)
    n_negatives: int = 2048
    n_serve_candidates: int = 1000
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = False

    @property
    def n_fields(self) -> int:
        return len(self.field_vocab_sizes)

    @property
    def total_rows(self) -> int:
        return int(sum(self.field_vocab_sizes))


# ---------------------------------------------------------------------------
# Sequential trunks (bert4rec / sasrec reuse the transformer)
# ---------------------------------------------------------------------------


def _trunk_cfg(cfg: RecsysConfig) -> tfm.TransformerConfig:
    if cfg.model_type == "bert4rec":
        return tfm.TransformerConfig(
            name=cfg.name + "-trunk", n_layers=cfg.n_blocks, d_model=cfg.embed_dim,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
            head_dim=cfg.embed_dim // cfg.n_heads,
            d_ff=cfg.d_ff or 4 * cfg.embed_dim,
            vocab_size=cfg.item_vocab + 2,       # +pad +[MASK]
            qkv_bias=True, use_rope=False,
            max_position_embeddings=cfg.seq_len, norm_style="post", act="gelu",
            causal=False, tie_embeddings=True, q_chunk=min(128, cfg.seq_len),
            param_dtype=cfg.param_dtype, compute_dtype=cfg.compute_dtype,
            remat=cfg.remat)
    # sasrec: unidirectional self-attention, learned positions
    return tfm.TransformerConfig(
        name=cfg.name + "-trunk", n_layers=cfg.n_blocks, d_model=cfg.embed_dim,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
        head_dim=cfg.embed_dim // cfg.n_heads,
        d_ff=cfg.d_ff or cfg.embed_dim,
        vocab_size=cfg.item_vocab + 1,           # +pad
        qkv_bias=False, use_rope=False,
        max_position_embeddings=cfg.seq_len, norm_style="pre", act="gelu",
        causal=True, tie_embeddings=True, q_chunk=min(128, cfg.seq_len),
        param_dtype=cfg.param_dtype, compute_dtype=cfg.compute_dtype,
        remat=cfg.remat)


def _item_table(params, cfg: RecsysConfig):
    if cfg.model_type in ("bert4rec", "sasrec"):
        return params["trunk"]["embed"]["table"]
    return params["item_embed"]


def _sampled_softmax(user_vec, pos_emb, neg_emb, mask=None):
    """CE over [positive | shared negatives].

    user_vec: (..., D); pos_emb: (..., D); neg_emb: (n_neg, D);
    mask: (...,) bool over prediction positions.
    """
    pos = (user_vec * pos_emb).sum(-1)                        # (...)
    neg = user_vec @ neg_emb.T                                # (..., n_neg)
    logits = jnp.concatenate([pos[..., None], neg], axis=-1).astype(jnp.float32)
    nll = jax.nn.logsumexp(logits, axis=-1) - logits[..., 0]
    if mask is None:
        return nll.mean(), nll
    m = mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.clip(m.sum(), 1), nll


# ---------------------------------------------------------------------------
# MIND capsule routing
# ---------------------------------------------------------------------------


def _squash(z, axis=-1, eps=1e-9):
    n2 = jnp.sum(jnp.square(z), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * z / jnp.sqrt(n2 + eps)


def capsule_routing(h, mask, routing_init, w_trans, iters: int):
    """B2I dynamic routing [arXiv:1904.08030].

    h: (B, S, D) behavior embeddings; mask: (B, S) bool;
    routing_init: (K, S) fixed/learned routing-logit init; w_trans: (D, D).
    Returns interest capsules (B, K, D).
    """
    hp = h @ w_trans                                          # (B,S,D)
    B = h.shape[0]
    b = jnp.broadcast_to(routing_init[None], (B,) + routing_init.shape)
    neg = jnp.asarray(-1e30, b.dtype)
    b = jnp.where(mask[:, None, :], b, neg)

    def one_iter(b, _):
        w = jax.nn.softmax(b, axis=1)                         # over capsules
        z = jnp.einsum("bks,bsd->bkd", w * mask[:, None, :].astype(w.dtype), hp)
        u = _squash(z)
        db = jnp.einsum("bkd,bsd->bks", u, hp)
        return jnp.where(mask[:, None, :], b + db, neg), u

    b, us = jax.lax.scan(one_iter, b, None, length=iters)
    return us[-1]                                             # (B,K,D)


# ---------------------------------------------------------------------------
# init / user encoding / losses per model type
# ---------------------------------------------------------------------------


def init(rng, cfg: RecsysConfig):
    r1, r2, r3, r4 = nn.split_rngs(rng, 4)
    if cfg.model_type in ("bert4rec", "sasrec"):
        return {"trunk": tfm.init(r1, _trunk_cfg(cfg))}
    if cfg.model_type == "mind":
        D = cfg.embed_dim
        return {
            "item_embed": embedding_ops.embedding_table_init(
                r1, cfg.item_vocab + 1, D, dtype=cfg.param_dtype),
            "w_trans": nn.fanin_init(r2, (D, D), ("embed", "embed2"),
                                     dtype=cfg.param_dtype),
            "routing_init": nn.normal_init(r3, (cfg.n_interests, cfg.seq_len),
                                           ("interests", "seq"), stddev=1.0,
                                           dtype=jnp.float32),
        }
    if cfg.model_type == "deepfm":
        rows, D = cfg.total_rows, cfg.embed_dim
        mlp = {}
        dims = (cfg.n_fields * D,) + tuple(cfg.mlp_dims) + (1,)
        rr = nn.split_rngs(r3, len(dims) - 1)
        for i in range(len(dims) - 1):
            mlp[f"l{i}"] = nn.linear_init(rr[i], dims[i], dims[i + 1],
                                          ("gnn_in", "gnn_hidden"), bias=True,
                                          dtype=cfg.param_dtype)
        return {
            "embed": embedding_ops.embedding_table_init(r1, rows, D,
                                                        dtype=cfg.param_dtype),
            "lin": embedding_ops.embedding_table_init(r2, rows, 1,
                                                      dtype=cfg.param_dtype),
            "bias": nn.zeros_init((), (), dtype=jnp.float32),
            "mlp": mlp,
        }
    raise ValueError(cfg.model_type)


def user_embed(params, cfg: RecsysConfig, hist, hist_mask=None):
    """Encode user history -> user vector(s).

    Returns (B, D) for sasrec/bert4rec, (B, K, D) interests for mind.
    """
    if hist_mask is None:
        hist_mask = hist > 0
    if cfg.model_type in ("bert4rec", "sasrec"):
        tc = _trunk_cfg(cfg)
        hidden, _, _ = tfm.forward(params["trunk"], tc, hist, kv_mask=hist_mask)
        # last valid position's hidden state is the user vector
        last = jnp.maximum(hist_mask.sum(-1) - 1, 0)                 # (B,)
        return jnp.take_along_axis(hidden, last[:, None, None], axis=1)[:, 0]
    if cfg.model_type == "mind":
        cd = cfg.compute_dtype
        h = embedding_ops.embedding_lookup(params["item_embed"], hist, cd)
        return capsule_routing(h.astype(jnp.float32), hist_mask,
                               params["routing_init"],
                               params["w_trans"].astype(jnp.float32),
                               cfg.capsule_iters)
    raise ValueError(cfg.model_type)


def _label_aware_user(interests, target_emb, power: float = 2.0):
    """MIND label-aware attention over interest capsules."""
    s = jnp.einsum("bkd,bd->bk", interests, target_emb) * power
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, interests)


def deepfm_scores(params, cfg: RecsysConfig, ids, valid):
    """DeepFM logit. ids/valid: (B, F, max_hot) (global row ids)."""
    cd = cfg.compute_dtype
    emb = embedding_ops.multi_hot_bag(params["embed"], ids, valid,
                                      mode="sum", compute_dtype=cd)  # (B,F,D)
    emb = nn.constrain(emb, ("act_batch", None, None))
    lin = embedding_ops.multi_hot_bag(params["lin"], ids, valid,
                                      mode="sum", compute_dtype=jnp.float32)
    first = lin.sum(axis=(1, 2))                                     # (B,)
    e32 = emb.astype(jnp.float32)
    s = e32.sum(axis=1)                                              # (B,D)
    fm2 = 0.5 * (jnp.square(s) - jnp.square(e32).sum(axis=1)).sum(-1)
    B = ids.shape[0]
    h = emb.reshape(B, -1)
    n_layers = len(cfg.mlp_dims) + 1
    for i in range(n_layers):
        h = nn.linear(params["mlp"][f"l{i}"], h, cd)
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    deep = h[:, 0].astype(jnp.float32)
    return params["bias"].astype(jnp.float32) + first + fm2 + deep


def loss_fn(params, cfg: RecsysConfig, batch):
    if cfg.model_type == "sasrec":
        hist, pos = batch["hist"], batch["pos"]                # (B,S)
        tc = _trunk_cfg(cfg)
        mask = hist > 0
        hidden, _, _ = tfm.forward(params["trunk"], tc, hist, kv_mask=mask)
        table = _item_table(params, cfg).astype(hidden.dtype)
        pos_emb = jnp.take(table, pos, axis=0)
        neg_emb = jnp.take(table, batch["neg_ids"], axis=0)
        loss, _ = _sampled_softmax(hidden, pos_emb, neg_emb, mask & (pos > 0))
        return loss, {}
    if cfg.model_type == "bert4rec":
        tokens = batch["tokens"]
        tc = _trunk_cfg(cfg)
        hidden, _, _ = tfm.forward(params["trunk"], tc, tokens,
                                   kv_mask=tokens > 0)
        hsel = jnp.take_along_axis(hidden, batch["mlm_positions"][..., None],
                                   axis=1)                      # (B,M,D)
        table = _item_table(params, cfg).astype(hidden.dtype)
        pos_emb = jnp.take(table, batch["mlm_labels"], axis=0)
        neg_emb = jnp.take(table, batch["neg_ids"], axis=0)
        loss, _ = _sampled_softmax(hsel, pos_emb, neg_emb, batch["mlm_mask"])
        return loss, {}
    if cfg.model_type == "mind":
        interests = user_embed(params, cfg, batch["hist"])     # (B,K,D)
        table = _item_table(params, cfg).astype(jnp.float32)
        tgt = jnp.take(table, batch["target"], axis=0)
        u = _label_aware_user(interests, tgt)
        neg_emb = jnp.take(table, batch["neg_ids"], axis=0)
        loss, _ = _sampled_softmax(u, tgt, neg_emb)
        return loss, {}
    if cfg.model_type == "deepfm":
        logit = deepfm_scores(params, cfg, batch["ids"], batch["valid"])
        y = batch["label"].astype(jnp.float32)
        loss = jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))
        auc_proxy = jnp.mean((jax.nn.sigmoid(logit) > 0.5) == (y > 0.5))
        return loss, {"acc": auc_proxy}
    raise ValueError(cfg.model_type)


def serve_fn(params, cfg: RecsysConfig, batch):
    """Online inference: score a candidate slate for each request."""
    if cfg.model_type == "deepfm":
        return deepfm_scores(params, cfg, batch["ids"], batch["valid"])
    u = user_embed(params, cfg, batch["hist"])
    table = _item_table(params, cfg).astype(jnp.float32)
    cand = jnp.take(table, batch["cand_ids"], axis=0)          # (C,D)
    if cfg.model_type == "mind":
        s = jnp.einsum("bkd,cd->bkc", u, cand)
        return s.max(axis=1)                                   # hard interest max
    return u.astype(jnp.float32) @ cand.T                      # (B,C)


def item_embeddings(params, cfg: RecsysConfig, ids):
    """Candidate-corpus embeddings for retrieval validation (asyncval path)."""
    table = _item_table(params, cfg).astype(jnp.float32)
    e = jnp.take(table, ids, axis=0)
    return e / jnp.clip(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-6)
