"""Sparse embedding ops for recsys: EmbeddingBag, hashing, row-sharded tables.

JAX has no native ``nn.EmbeddingBag`` and only BCOO sparse — these ops ARE part
of the system (per the assignment): EmbeddingBag = ``jnp.take`` gather +
``jax.ops.segment_sum`` reduce.  Tables carry logical axes
``("table_rows", "embed")`` so the sharding rules place rows across
``("data", "model")`` — the standard row-sharded (hash-bucketed) layout used by
production recommenders.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn


def embedding_table_init(rng, n_rows: int, dim: int, dtype=jnp.float32,
                         stddev: float = 0.02) -> nn.Param:
    return nn.normal_init(rng, (n_rows, dim), ("table_rows", "embed"),
                          stddev=stddev, dtype=dtype)


def hash_bucket(ids: jnp.ndarray, n_rows: int, salt: int = 0) -> jnp.ndarray:
    """Deterministic multiplicative hash into [0, n_rows) — the
    quotient-remainder-free variant of hashed embeddings."""
    h = (ids.astype(jnp.uint32) + jnp.uint32(salt)) * jnp.uint32(2654435761)
    h = h ^ (h >> 16)
    return (h % jnp.uint32(n_rows)).astype(jnp.int32)


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                     compute_dtype=None) -> jnp.ndarray:
    t = table if compute_dtype is None else table.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray, segment_ids: jnp.ndarray,
                  num_segments: int, *, mode: str = "sum", weights=None,
                  valid=None, compute_dtype=None) -> jnp.ndarray:
    """torch.nn.EmbeddingBag equivalent.

    ids: (nnz,) row indices; segment_ids: (nnz,) bag assignment (sorted not
    required); valid: (nnz,) bool for padding entries; weights: per-id scale
    (for weighted-sum bags).  Returns (num_segments, dim).
    """
    vecs = embedding_lookup(table, ids, compute_dtype)
    if weights is not None:
        vecs = vecs * weights[:, None].astype(vecs.dtype)
    if valid is not None:
        if mode == "max":
            vecs = jnp.where(valid[:, None], vecs, -jnp.inf)
        else:
            vecs = vecs * valid[:, None].astype(vecs.dtype)
    if mode == "sum":
        return jax.ops.segment_sum(vecs, segment_ids, num_segments=num_segments)
    if mode == "mean":
        s = jax.ops.segment_sum(vecs, segment_ids, num_segments=num_segments)
        ones = (valid.astype(vecs.dtype) if valid is not None
                else jnp.ones(ids.shape[0], vecs.dtype))
        c = jax.ops.segment_sum(ones, segment_ids, num_segments=num_segments)
        return s / jnp.clip(c[:, None], 1e-9)
    if mode == "max":
        out = jax.ops.segment_max(vecs, segment_ids, num_segments=num_segments)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(mode)


def multi_hot_bag(table: jnp.ndarray, ids: jnp.ndarray, valid: jnp.ndarray,
                  *, mode: str = "sum", compute_dtype=None) -> jnp.ndarray:
    """Dense-layout EmbeddingBag: ids (B, F, max_hot), valid same shape.

    Returns (B, F, dim) — one bag per (example, field).  This is the layout
    recsys batches use (fixed fields, ragged values padded to max_hot).
    """
    B, F, M = ids.shape
    flat = embedding_lookup(table, ids.reshape(-1), compute_dtype)
    flat = flat.reshape(B, F, M, -1)
    v = valid[..., None].astype(flat.dtype)
    if mode == "sum":
        return (flat * v).sum(axis=2)
    if mode == "mean":
        return (flat * v).sum(axis=2) / jnp.clip(v.sum(axis=2), 1e-9)
    if mode == "max":
        neg = jnp.where(valid[..., None], flat, -jnp.inf)
        out = neg.max(axis=2)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(mode)
