"""Checkpoint system with the two-phase-commit protocol Asyncval relies on.

Layout (one directory per step under a checkpoint root):

    <root>/step_00001000/
        manifest.json     # treedef, per-leaf shape/dtype, user metadata
        arrays/000.npy …  # one .npy per pytree leaf (leaf order = treedef order)
        COMMIT            # written LAST -> readers (the validator) only see
                          # fully-flushed checkpoints. This closes the torn-read
                          # race the paper's "listen to --ckpts_dir" glosses over.

Features needed at 1000-node scale:
  * atomic commit (tmp dir + fsync + rename + COMMIT marker);
  * async save (training never blocks on I/O);
  * restore to ANY mesh: ``restore(..., shardings=tree)`` lays leaves out with
    ``jax.device_put`` -> elastic validator/trainer meshes (DESIGN.md §2.8);
  * keep-last-k GC that never deletes checkpoints the validator hasn't
    processed (``protect`` set fed from the validation ledger).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Iterable, Optional

import jax
import numpy as np

COMMIT_MARKER = "COMMIT"
STEP_PREFIX = "step_"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"{STEP_PREFIX}{step:010d}")


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(root: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Two-phase-commit checkpoint write. Returns the committed directory."""
    os.makedirs(root, exist_ok=True)
    final = _step_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    arrays_dir = os.path.join(tmp, "arrays")
    os.makedirs(arrays_dir)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        path = os.path.join(arrays_dir, f"{i:05d}.npy")
        with open(path, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(final):          # idempotent re-save (restart replay)
        shutil.rmtree(final)
    os.rename(tmp, final)
    # phase 2: commit marker — readers must ignore directories without it
    cpath = os.path.join(final, COMMIT_MARKER)
    with open(cpath, "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    try:
        _fsync_dir(final)
    except FileNotFoundError:
        # benign race: the marker made the checkpoint visible, and an
        # aggressive consumer (quality-aware GC on the validator thread)
        # may validate AND evict it before this trailing durability fsync —
        # the directory is gone on purpose; there is nothing left to sync.
        pass
    return final


class AsyncSaver:
    """Background checkpoint writer — training never blocks on I/O *or* on
    the device→host transfer.

    One in-flight save at a time (the trainer waits only if it outruns disk,
    matching orbax semantics).  The calling thread only *issues* the
    device→host copies (``copy_to_host_async`` per leaf — a DMA enqueue,
    not a wait); the background thread materializes the numpy arrays once
    the copies land.  Safe because jax arrays are immutable: the trainer
    rebinds its state to new arrays each step, so the captured leaves can
    never change underneath the transfer (donated buffers excepted — the
    trainer's step does not donate).

    Hand-off hooks (all optional, all invoked on the background thread):
    ``on_host_copy(step, host_tree)`` fires the moment the host copy is
    materialized — BEFORE durable serialization, which is the lazy
    snapshot hand-off's publish point; ``on_durable(step)`` after the
    two-phase commit lands; ``on_failure(step, exc)`` if the durable save
    raises (the error is still surfaced on the next :meth:`wait`)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, root: str, step: int, tree: Any,
             extra: Optional[dict] = None, *,
             on_host_copy: Optional[Any] = None,
             on_durable: Optional[Any] = None,
             on_failure: Optional[Any] = None) -> None:
        self.wait()

        # issue every leaf's device->host DMA now, without waiting for any
        # of them — np.asarray below then finds the host value already (or
        # soon) resident instead of serializing transfer behind transfer
        def _start_copy(x):
            start = getattr(x, "copy_to_host_async", None)
            if start is not None:
                start()
            return x

        pending = jax.tree_util.tree_map(_start_copy, tree)

        def _run():
            try:
                host_tree = jax.tree_util.tree_map(
                    lambda x: np.asarray(x), pending)
                if on_host_copy is not None:
                    try:
                        on_host_copy(step, host_tree)
                    except BaseException as e:
                        # the hand-off publish is an optimization; its
                        # failure must never cost the durable checkpoint
                        self._error = e
                save(root, step, host_tree, extra)
            except BaseException as e:   # surfaced on next wait()
                self._error = e
                if on_failure is not None:
                    try:
                        on_failure(step, e)
                    except BaseException:
                        pass             # the save error takes precedence
                return
            if on_durable is not None:
                try:
                    on_durable(step)
                except BaseException as e:
                    self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def is_committed(path: str) -> bool:
    return os.path.exists(os.path.join(path, COMMIT_MARKER))


def list_steps(root: str) -> list[int]:
    """Committed checkpoint steps, ascending."""
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith(STEP_PREFIX) and not name.endswith(".tmp"):
            full = os.path.join(root, name)
            if is_committed(full):
                try:
                    steps.append(int(name[len(STEP_PREFIX):]))
                except ValueError:
                    continue
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    steps = list_steps(root)
    return steps[-1] if steps else None


def read_extra(root: str, step: int) -> dict:
    """The manifest's user metadata, without loading any arrays — cheap
    enough to scan when picking a restore candidate (e.g. the trainer
    skipping ``virtual`` ensemble checkpoints that carry no optimizer
    state)."""
    path = _step_dir(root, step)
    if not is_committed(path):
        raise FileNotFoundError(f"checkpoint {path} not committed")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("extra", {})


def restore(root: str, step: Optional[int] = None, *, shardings: Any = None):
    """Restore (tree, extra). ``shardings``: optional pytree of Shardings
    (same structure) -> leaves are placed for an arbitrary target mesh,
    which is what makes the validator mesh-elastic."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {root}")
    path = _step_dir(root, step)
    if not is_committed(path):
        raise FileNotFoundError(f"checkpoint {path} not committed")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    treedef = jax.tree_util.PyTreeDef.deserialize_using_proto(
        jax.tree_util.default_registry, bytes.fromhex(manifest["treedef"]))
    leaves = []
    for i, meta in enumerate(manifest["leaves"]):
        arr = np.load(os.path.join(path, "arrays", f"{i:05d}.npy"))
        if str(arr.dtype) != meta["dtype"]:
            # ml_dtypes types (bfloat16, float8_*) round-trip through .npy
            # as raw void records; re-view with the manifest dtype.
            import ml_dtypes  # noqa: F401  (registers the named dtypes)
            arr = arr.view(np.dtype(meta["dtype"]))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["extra"]


def gc_checkpoints(root: str, keep_last: int = 0,
                   protect: Iterable[int] = (), *,
                   keep: Optional[Iterable[int]] = None,
                   horizon: Optional[int] = None) -> list[int]:
    """Delete old committed checkpoints, never touching ``protect`` steps
    (checkpoints the validator has not finished). Returns deleted steps.

    Two retention shapes:
      * recency window (default): keep the last ``keep_last`` steps
        (``keep_last == 0`` keeps everything);
      * explicit set: ``keep`` names exactly the steps to retain — the
        quality-aware mode, fed top-k-by-metric from the control plane's
        ``CheckpointSelector`` (``protect`` still applies on top).

    ``horizon`` (keep-mode only) is the TOCTOU guard: the newest step the
    caller KNEW about when computing keep/protect.  A checkpoint committed
    after that snapshot (step > horizon) has no quality verdict yet and
    survives this round — the next decision, which ranks or protects it,
    owns its fate.  Defaults to ``max(keep | protect)``; an empty decision
    deletes nothing.
    """
    steps = list_steps(root)
    protected = set(protect)
    if keep is not None:
        keep_set = set(keep) | protected
        if horizon is None:
            horizon = max(keep_set) if keep_set else None
        candidates = [] if horizon is None else \
            [s for s in steps if s not in keep_set and s <= horizon]
    elif keep_last > 0:
        candidates = [s for s in steps[:-keep_last] if s not in protected]
    else:
        candidates = []
    for s in candidates:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
    return candidates
