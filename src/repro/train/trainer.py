"""Training loop with checkpoint/restart, async saving, and metric logging.

The trainer is the *producer* side of Asyncval: it trains, periodically
commits checkpoints to ``ckpt_dir`` (two-phase commit), and never waits for
validation.  The validator (``repro.core.validator``) is the consumer.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.train.optim import Optimizer


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 10
    ckpt_dir: Optional[str] = None
    keep_last: int = 0              # 0 = keep all (validator may lag)
    log_every: int = 10
    async_save: bool = True
    grad_accum: int = 1
    # convergence control plane: the async validator's EarlyStopController
    # publishes its verdict as an atomic marker file; the trainer polls for
    # it between steps (a single os.path.exists — training halts
    # asynchronously, it NEVER waits on validation).
    stop_file: Optional[str] = None
    stop_poll_every: int = 1        # steps between marker polls
    # lazy snapshot hand-off (repro.handoff.SnapshotChannel): publish a
    # host-resident param snapshot the moment the device->host copy lands,
    # while the durable ckpt.save races in the background — the validator
    # scores it without waiting for serialization or watcher polling.
    # None keeps the classic durable-only hand-off.
    snapshots: Any = None


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    grad_accum: int = 1):
    """Build a jit-able (params, opt_state, batch) -> (params, opt_state, metrics).

    ``loss_fn(params, batch) -> (loss, metrics)``.  With grad_accum > 1 the
    batch's leading axis is split into microbatches and gradients averaged
    (lax.scan — compile size independent of accumulation factor).
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if grad_accum <= 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                (l, a), g = grad_fn(params, mb)
                acc_l, acc_g = carry
                return (acc_l + l,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), a
            microbatches = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), auxs = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero_g), microbatches)
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            aux = jax.tree_util.tree_map(lambda a: a[-1], auxs)
        new_params, new_opt_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **aux}
        return new_params, new_opt_state, metrics

    return step


class Trainer:
    """CPU-runnable end-to-end trainer (examples / integration tests).

    Resumable: on construction it restores the latest committed checkpoint
    (params, optimizer state, data cursor, RNG) if one exists — node failure
    recovery is "restart the binary".
    """

    def __init__(self, cfg: TrainerConfig, loss_fn: Callable,
                 optimizer: Optimizer, init_params: Any,
                 batch_iter: Callable[[int], Any],
                 logger: Optional[Any] = None,
                 telemetry=None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.batch_iter = batch_iter          # step -> batch (deterministic)
        self.logger = logger
        # observation only: a `produced` lifecycle event per saved
        # checkpoint (the first edge of the checkpoint-to-verdict latency)
        self.telemetry = telemetry
        self.saver = ckpt.AsyncSaver()
        self._step_fn = jax.jit(make_train_step(loss_fn, optimizer,
                                                cfg.grad_accum))

        self.step = 0
        self.params = init_params
        self.opt_state = optimizer.init(init_params)
        self.stopped_early = False
        self.stop_verdict: Optional[dict] = None
        self._last_saved_step: Optional[int] = None
        if cfg.ckpt_dir:
            for latest in reversed(ckpt.list_steps(cfg.ckpt_dir)):
                # virtual checkpoints (control-plane ensembles) carry no
                # optimizer state — resume from the newest TRAINED one.
                if ckpt.read_extra(cfg.ckpt_dir, latest).get("virtual"):
                    continue
                state, extra = ckpt.restore(cfg.ckpt_dir, latest)
                self.params = state["params"]
                self.opt_state = state["opt_state"]
                self.step = int(extra.get("step", latest))
                break

    def _publish_snapshot(self, step, host_tree):
        """Async-saver host-copy hook: hand the validator a snapshot before
        the durable save starts (runs on the saver's background thread)."""
        from repro.handoff import ParamSnapshot
        self.cfg.snapshots.publish(ParamSnapshot.from_tree(step, host_tree))

    def _save(self):
        if not self.cfg.ckpt_dir:
            return
        state = {"params": self.params, "opt_state": self.opt_state}
        extra = {"step": self.step, "wall_time": time.time()}
        ch = self.cfg.snapshots
        tel = self.telemetry
        if tel is not None:
            # first edge of the checkpoint-to-verdict latency, whichever
            # hand-off route wins the race
            tel.mark("produced", self.step)
        if self.cfg.async_save:
            self.saver.save(
                self.cfg.ckpt_dir, self.step, state, extra,
                on_host_copy=self._publish_snapshot if ch is not None
                else None,
                on_durable=ch.mark_durable if ch is not None else None,
                on_failure=ch.mark_failed if ch is not None else None)
        else:
            ckpt.save(self.cfg.ckpt_dir, self.step, state, extra)
            if ch is not None:
                # degenerate (already durable) hand-off: publish after the
                # blocking save so sync mode keeps one code path downstream
                self._publish_snapshot(self.step, state)
                ch.mark_durable(self.step)
        self._last_saved_step = self.step
        if tel is not None:
            # async saves commit later; the event marks hand-off to the
            # save path, the COMMIT-marker mtime remains the durable edge
            tel.event("produced", step=self.step,
                      async_save=self.cfg.async_save)

    def _stop_requested(self) -> bool:
        """Poll the control plane's STOP marker (async early stopping)."""
        if not self.cfg.stop_file:
            return False
        if self.step % max(self.cfg.stop_poll_every, 1) != 0:
            return False
        from repro.control.earlystop import stop_requested
        verdict = stop_requested(self.cfg.stop_file)
        if verdict is None:
            return False
        self.stop_verdict = verdict
        return True

    def run(self, on_metrics: Optional[Callable[[int, dict], None]] = None):
        history = []
        while self.step < self.cfg.total_steps:
            if self._stop_requested():
                self.stopped_early = True
                break
            batch = self.batch_iter(self.step)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            # log/notify BEFORE committing the checkpoint: consumers of the
            # metrics feed (the control plane's train-loss lookup) are then
            # guaranteed to know about step t before any validator can see
            # checkpoint t — keeps online decisions == offline replay.
            if self.step % self.cfg.log_every == 0 or \
                    self.step == self.cfg.total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                history.append((self.step, m))
                if self.logger is not None:
                    self.logger.log(self.step, m)
                if on_metrics is not None:
                    on_metrics(self.step, m)
            if self.step % self.cfg.ckpt_every == 0 \
                    or self.step == self.cfg.total_steps:
                self._save()
        if self.stopped_early and self.cfg.ckpt_dir \
                and self._last_saved_step != self.step:
            self._save()    # commit the final state for selection/ensembling
        self.saver.wait()
        return history
