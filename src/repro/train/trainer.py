"""Training loop with checkpoint/restart, async saving, and metric logging.

The trainer is the *producer* side of Asyncval: it trains, periodically
commits checkpoints to ``ckpt_dir`` (two-phase commit), and never waits for
validation.  The validator (``repro.core.validator``) is the consumer.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.train.optim import Optimizer


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 10
    ckpt_dir: Optional[str] = None
    keep_last: int = 0              # 0 = keep all (validator may lag)
    log_every: int = 10
    async_save: bool = True
    grad_accum: int = 1


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    grad_accum: int = 1):
    """Build a jit-able (params, opt_state, batch) -> (params, opt_state, metrics).

    ``loss_fn(params, batch) -> (loss, metrics)``.  With grad_accum > 1 the
    batch's leading axis is split into microbatches and gradients averaged
    (lax.scan — compile size independent of accumulation factor).
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if grad_accum <= 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                (l, a), g = grad_fn(params, mb)
                acc_l, acc_g = carry
                return (acc_l + l,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), a
            microbatches = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), auxs = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero_g), microbatches)
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            aux = jax.tree_util.tree_map(lambda a: a[-1], auxs)
        new_params, new_opt_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **aux}
        return new_params, new_opt_state, metrics

    return step


class Trainer:
    """CPU-runnable end-to-end trainer (examples / integration tests).

    Resumable: on construction it restores the latest committed checkpoint
    (params, optimizer state, data cursor, RNG) if one exists — node failure
    recovery is "restart the binary".
    """

    def __init__(self, cfg: TrainerConfig, loss_fn: Callable,
                 optimizer: Optimizer, init_params: Any,
                 batch_iter: Callable[[int], Any],
                 logger: Optional[Any] = None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.batch_iter = batch_iter          # step -> batch (deterministic)
        self.logger = logger
        self.saver = ckpt.AsyncSaver()
        self._step_fn = jax.jit(make_train_step(loss_fn, optimizer,
                                                cfg.grad_accum))

        self.step = 0
        self.params = init_params
        self.opt_state = optimizer.init(init_params)
        if cfg.ckpt_dir:
            latest = ckpt.latest_step(cfg.ckpt_dir)
            if latest is not None:
                state, extra = ckpt.restore(cfg.ckpt_dir, latest)
                self.params = state["params"]
                self.opt_state = state["opt_state"]
                self.step = int(extra.get("step", latest))

    def _save(self):
        if not self.cfg.ckpt_dir:
            return
        state = {"params": self.params, "opt_state": self.opt_state}
        extra = {"step": self.step, "wall_time": time.time()}
        if self.cfg.async_save:
            self.saver.save(self.cfg.ckpt_dir, self.step, state, extra)
        else:
            ckpt.save(self.cfg.ckpt_dir, self.step, state, extra)

    def run(self, on_metrics: Optional[Callable[[int, dict], None]] = None):
        history = []
        while self.step < self.cfg.total_steps:
            batch = self.batch_iter(self.step)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.step % self.cfg.ckpt_every == 0 \
                    or self.step == self.cfg.total_steps:
                self._save()
            if self.step % self.cfg.log_every == 0 or \
                    self.step == self.cfg.total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                history.append((self.step, m))
                if self.logger is not None:
                    self.logger.log(self.step, m)
                if on_metrics is not None:
                    on_metrics(self.step, m)
        self.saver.wait()
        return history
