"""Optimizers and schedules (pure JAX; no optax on this box).

Optax-style interface:  ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (new_params, new_state)``.

Includes the distributed-optimization features used at scale:
  * AdamW (fp32 moments) — default.
  * Adafactor (factored second moment) — for the 480B-parameter MoE where
    full Adam state does not fit 256 chips (DESIGN.md §4).
  * global-norm clipping, weight decay masks.
  * error-feedback int8 gradient compression (``compressed``): quantize
    grads to int8 with a per-tensor scale before the (simulated) all-reduce,
    carrying the quantization error into the next step — 4x less gradient
    collective traffic at <1% convergence penalty (validated in tests).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]   # (grads, state, params) -> (params, state)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


def _is_matrix(x) -> bool:
    return x.ndim >= 2


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(lr: Callable | float, *, b1=0.9, b2=0.999, eps=1e-8,
          weight_decay=0.01, max_grad_norm: Optional[float] = 1.0,
          decay_mask: Optional[Callable[[Any], Any]] = None) -> Optimizer:
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr_t = sched(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)
        mask = (decay_mask(params) if decay_mask is not None
                else jax.tree_util.tree_map(_is_matrix, params))

        def upd(p, g, m, v, use_wd):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat, vhat = m / b1c, v / b2c
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + jnp.where(use_wd, weight_decay, 0.0) \
                    * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_mask = tdef.flatten_up_to(mask)
        out = [upd(p, g, m, v, w) for p, g, m, v, w in
               zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; no first moment by default)
# ---------------------------------------------------------------------------


def adafactor(lr: Callable | float, *, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0, max_grad_norm: Optional[float] = 1.0) -> Optimizer:
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        def slot(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "slots": jax.tree_util.tree_map(slot, params)}

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr_t = sched(step)
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, slot):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = beta * slot["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * slot["vc"] + (1 - beta) * g2.mean(-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.clip(vr.mean(-1, keepdims=True), eps))[..., :, None]
                cfac = jax.lax.rsqrt(vc)[..., None, :]
                u = g32 * rfac * cfac
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta * slot["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v)
                new_slot = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            delta = lr_t * u
            if weight_decay:
                delta = delta + lr_t * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - delta).astype(p.dtype), new_slot

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["slots"])
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        return (tdef.unflatten([o[0] for o in out]),
                {"step": step, "slots": tdef.unflatten([o[1] for o in out])})

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Error-feedback int8 gradient compression
# ---------------------------------------------------------------------------


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed(inner: Optimizer) -> Optimizer:
    """Error-feedback int8 gradient compression wrapper.

    In production the int8 tensors are what crosses the wire in the gradient
    all-reduce (4x traffic cut vs bf16 + scale exchange); here the quantize ->
    dequantize round-trip models the numerics exactly, and the residual error
    is fed back next step (EF-SGD), which is what preserves convergence.
    """

    def init(params):
        err = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"inner": inner.init(params), "err": err}

    def update(grads, state, params):
        def comp(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = quantize_int8(corrected)
            deq = dequantize_int8(q, scale)
            return deq, corrected - deq

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = tdef.flatten_up_to(state["err"])
        pairs = [comp(g, e) for g, e in zip(flat_g, flat_e)]
        deq = tdef.unflatten([p[0] for p in pairs])
        err = tdef.unflatten([p[1] for p in pairs])
        new_params, inner_state = inner.update(deq, state["inner"], params)
        return new_params, {"inner": inner_state, "err": err}

    return Optimizer(init, update)
