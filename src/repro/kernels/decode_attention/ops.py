"""jit'd dispatch wrapper for the decode_attention Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_kernel


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def decode_attention(q, k, v, length, *, bk: int = 512,
                     interpret: bool | None = None):
    """q: (B, KV, G, d); k, v: (B, KV, T, d); length: int or (1,) i32.

    Returns (B, KV, G, d) in q.dtype."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, KV, G, d = q.shape
    T = k.shape[2]
    bk = min(bk, _pad_to(T, 128))
    Gp, dp, Tp = _pad_to(G, 8), _pad_to(d, 128), _pad_to(T, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Gp - G), (0, dp - d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, dp - d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, dp - d)))
    if dp != d:
        qp = qp * (dp ** 0.5) / (d ** 0.5)
    length = jnp.asarray(length, jnp.int32).reshape((1,))
    out = decode_attention_kernel(length, qp, kp, vp, bk=bk,
                                  interpret=interpret)
    return out[:, :, :G, :d]
