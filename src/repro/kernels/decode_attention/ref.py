"""Pure-jnp oracle for decode_attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(length, q, k, v):
    """length: scalar/(1,) i32; q: (B, KV, G, d); k, v: (B, KV, T, d)
    -> (B, KV, G, d)."""
    d = q.shape[-1]
    T = k.shape[2]
    s = jnp.einsum("bhgd,bhtd->bhgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    valid = jnp.arange(T) < jnp.asarray(length).reshape(())
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
