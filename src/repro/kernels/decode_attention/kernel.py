"""Pallas TPU kernel: single-token GQA decode attention over a long KV cache.

The ``decode_32k`` / ``long_500k`` serving cells are dominated by streaming
the KV cache (arithmetic intensity ~= G, the GQA group size) — a pure
HBM-bandwidth workload.  The kernel:

  * grid = (batch, kv_heads, cache_blocks), cache innermost;
  * the G query rows of one kv head (a (G, d) tile, G = H // KV) stay
    resident; cache tiles (bk, d) stream through VMEM exactly once;
  * online softmax (running m / l / acc scratch) — no (H, T) score tensor;
  * the *dynamic* cache length arrives via scalar-memory (SMEM) so blocks
    past the valid prefix are skipped entirely (``pl.when``) — with a
    524k-token cache capacity and a 32k prefix, 94% of the sweep is DMA
    that never happens.

q rows per tile are padded to the 8-row sublane minimum in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

_LANES = 128
_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, bk: int, scale: float):
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)
    length = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * bk

    @pl.when(k_start < length)                   # skip blocks past the prefix
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, _NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            corr * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True),
            l_scr.shape)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ki == n_k - 1)
    def _flush():
        l = l_scr[:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_kernel(length, q, k, v, *, bk: int = 512,
                            interpret: bool = False):
    """length: (1,) i32 valid cache length; q: (B, KV, G, d);
    k, v: (B, KV, T, d); T % bk == 0, d % 128 == 0, G % 8 == 0.
    Returns (B, KV, G, d) in q.dtype."""
    B, KV, G, d = q.shape
    T = k.shape[2]
    assert T % bk == 0 and d % _LANES == 0 and G % 8 == 0
    grid = (B, KV, T // bk)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_decode_kernel, bk=bk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, d), lambda b, h, ki, _: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b, h, ki, _: (b, h, ki, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b, h, ki, _: (b, h, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, d),
                                   lambda b, h, ki, _: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, _LANES), jnp.float32),
                pltpu.VMEM((G, _LANES), jnp.float32),
                pltpu.VMEM((G, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length, q, k, v)
