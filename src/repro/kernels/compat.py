"""JAX version shims shared by the Pallas kernels.

``pltpu.CompilerParams`` was named ``TPUCompilerParams`` in older JAX
releases; resolving the alias here keeps the kernels on one spelling
without monkey-patching the third-party module.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
