"""Pure-jnp oracle for the topk_mips kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_mips_ref(q: jnp.ndarray, c: jnp.ndarray, *, k: int):
    """q: (Q, D), c: (N, D) -> (scores (Q, k) f32, indices (Q, k) i32)."""
    scores = (q.astype(jnp.float32) @ c.astype(jnp.float32).T)
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_s, top_i.astype(jnp.int32)
