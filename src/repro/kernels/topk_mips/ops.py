"""jit'd dispatch wrapper for the topk_mips Pallas kernel.

Handles shape padding (queries to bq, corpus rows to bn, feature dim to the
128-lane MXU width) and backend selection: on TPU the Mosaic kernel runs
natively; everywhere else (this CPU box) ``interpret=True`` executes the
kernel body in Python for correctness validation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk_mips.kernel import topk_mips_kernel


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def topk_mips(q: jnp.ndarray, c: jnp.ndarray, *, k: int, bq: int = 128,
              bn: int = 1024, interpret: bool | None = None,
              n_valid: int | None = None):
    """Exact top-k MIPS: q (Q, D) x c (N, D) -> (scores, indices) (Q, k).

    ``n_valid`` (static) marks how many leading corpus rows are real; trailing
    rows (fixed-shape chunk padding from the streaming engine) are masked out
    of the top-k.  Defaults to all rows.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _topk_mips_jit(q, c, k=k, bq=bq, bn=bn, interpret=interpret,
                          n_valid=n_valid)


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret",
                                             "n_valid"))
def _topk_mips_jit(q, c, *, k, bq, bn, interpret, n_valid):
    # jitted end to end so the padding/slicing around the kernel compiles
    # into one program — the streaming engine calls this once per corpus
    # chunk, where eager per-call pads would dominate the hot loop.
    Q, D = q.shape
    N = c.shape[0]
    if n_valid is None:
        n_valid = N
    n_valid = min(n_valid, N)
    k_eff = min(k, n_valid)
    bq = min(bq, _pad_to(Q, 8))
    bn = min(bn, _pad_to(max(N, k_eff), 128))
    kp = k_eff                                     # k <= bn guaranteed below
    if kp > bn:
        bn = _pad_to(kp, 128)
    Dp = _pad_to(D, 128)
    Qp = _pad_to(Q, bq)
    Np = _pad_to(N, bn)
    qp = jnp.pad(q, ((0, Qp - Q), (0, Dp - D)))
    cp = jnp.pad(c, ((0, Np - N), (0, Dp - D)))
    scores, idx = topk_mips_kernel(qp, cp, k=kp, n_valid=n_valid, bq=bq,
                                   bn=bn, interpret=interpret)
    return scores[:Q], idx[:Q]


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_carry(run_s, run_i, chunk_s, chunk_i, base, *, k: int):
    """Fold a chunk-local top-k (indices relative to the chunk) into the
    running (Q, k) carry.  ``base`` is dynamic — one compile per chunk shape,
    not per chunk position."""
    s = jnp.concatenate([run_s, chunk_s], axis=1)
    i = jnp.concatenate([run_i, chunk_i + base], axis=1)
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(i, pos, axis=1)


def topk_mips_chunk(q: jnp.ndarray, c_chunk: jnp.ndarray, run_s: jnp.ndarray,
                    run_i: jnp.ndarray, *, base, n_valid: int | None = None,
                    bq: int = 128, bn: int = 1024,
                    interpret: bool | None = None):
    """Chunk-carry entry point for the streaming ValidationEngine.

    Computes the local top-k of one fixed-shape corpus chunk with the Pallas
    kernel and merges it into the running ``(Q, k)`` carry — the chunk's
    embeddings never leave the device and the full corpus scores are never
    materialized.  ``base`` (dynamic) is the chunk's global row offset;
    ``n_valid`` (static, at most two distinct values per corpus: full chunks
    and the ragged tail) masks chunk padding rows.
    """
    k = run_s.shape[1]
    n = c_chunk.shape[0] if n_valid is None else min(n_valid, c_chunk.shape[0])
    if n <= 0:
        return run_s, run_i
    s, i = topk_mips(q, c_chunk, k=min(k, n), bq=bq, bn=bn,
                     interpret=interpret, n_valid=n_valid)
    return _merge_carry(run_s, run_i, s, i.astype(jnp.int32),
                        jnp.asarray(base, jnp.int32), k=k)
