"""jit'd dispatch wrapper for the topk_mips Pallas kernel.

Handles shape padding (queries to bq, corpus rows to bn, feature dim to the
128-lane MXU width) and backend selection: on TPU the Mosaic kernel runs
natively; everywhere else (this CPU box) ``interpret=True`` executes the
kernel body in Python for correctness validation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_mips.kernel import topk_mips_kernel


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def topk_mips(q: jnp.ndarray, c: jnp.ndarray, *, k: int, bq: int = 128,
              bn: int = 1024, interpret: bool | None = None):
    """Exact top-k MIPS: q (Q, D) x c (N, D) -> (scores, indices) (Q, k)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Q, D = q.shape
    N = c.shape[0]
    k_eff = min(k, N)
    bq = min(bq, _pad_to(Q, 8))
    bn = min(bn, _pad_to(max(N, k_eff), 128))
    kp = k_eff                                     # k <= bn guaranteed below
    if kp > bn:
        bn = _pad_to(kp, 128)
    Dp = _pad_to(D, 128)
    Qp = _pad_to(Q, bq)
    Np = _pad_to(N, bn)
    qp = jnp.pad(q, ((0, Qp - Q), (0, Dp - D)))
    cp = jnp.pad(c, ((0, Np - N), (0, Dp - D)))
    scores, idx = topk_mips_kernel(qp, cp, k=kp, n_valid=N, bq=bq, bn=bn,
                                   interpret=interpret)
    return scores[:Q], idx[:Q]
