"""jit'd dispatch wrapper for the topk_mips Pallas kernel.

Handles shape padding (queries to bq, corpus rows to bn, feature dim to the
128-lane MXU width) and backend selection: on TPU the Mosaic kernel runs
natively; everywhere else (this CPU box) ``interpret=True`` executes the
kernel body in Python for correctness validation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk_mips.kernel import (topk_mips_kernel,
                                            topk_mips_kernel_int8)

SCORE_DTYPES = ("f32", "bf16", "int8")


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-row int8 quantization: ``x`` (..., D) f32 ->
    (values (..., D) int8, scales (..., 1) f32) with ``values * scales ~ x``.

    Per-ROW granularity on purpose: a row's quantized image is independent
    of how the corpus is chunked or sharded, so the streaming, sharded, and
    materialized engines all score the exact same int8 corpus — quantized
    cross-engine parity stays tie-level, not tolerance-level.  All-zero rows
    get scale 1 (not 0), keeping the dequantized scores finite.
    """
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    vals = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return vals, scale


def topk_mips(q: jnp.ndarray, c: jnp.ndarray, *, k: int, bq: int = 128,
              bn: int = 1024, interpret: bool | None = None,
              n_valid: int | None = None, score_dtype: str = "f32"):
    """Exact top-k MIPS: q (Q, D) x c (N, D) -> (scores, indices) (Q, k).

    ``n_valid`` (static) marks how many leading corpus rows are real; trailing
    rows (fixed-shape chunk padding from the streaming engine) are masked out
    of the top-k.  Defaults to all rows.

    ``score_dtype`` picks the scoring precision: ``"f32"`` (default — the
    path below, bit-for-bit unchanged), ``"bf16"`` (inputs cast to bf16, f32
    MXU accumulation — half the tile bytes), or ``"int8"`` (symmetric
    per-row quantization, exact int32 accumulation, per-tile scales folded
    in before the f32 carry merge — a quarter of the tile bytes).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if score_dtype == "f32":
        return _topk_mips_jit(q, c, k=k, bq=bq, bn=bn, interpret=interpret,
                              n_valid=n_valid)
    if score_dtype == "bf16":
        return _topk_mips_jit(jnp.asarray(q, jnp.bfloat16),
                              jnp.asarray(c, jnp.bfloat16), k=k, bq=bq,
                              bn=bn, interpret=interpret, n_valid=n_valid)
    if score_dtype == "int8":
        return _topk_mips_int8_jit(q, c, k=k, bq=bq, bn=bn,
                                   interpret=interpret, n_valid=n_valid)
    raise ValueError(f"unknown score_dtype {score_dtype!r} "
                     f"(expected one of {SCORE_DTYPES})")


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret",
                                             "n_valid"))
def _topk_mips_jit(q, c, *, k, bq, bn, interpret, n_valid):
    # jitted end to end so the padding/slicing around the kernel compiles
    # into one program — the streaming engine calls this once per corpus
    # chunk, where eager per-call pads would dominate the hot loop.
    Q, D = q.shape
    N = c.shape[0]
    if n_valid is None:
        n_valid = N
    n_valid = min(n_valid, N)
    k_eff = min(k, n_valid)
    bq = min(bq, _pad_to(Q, 8))
    bn = min(bn, _pad_to(max(N, k_eff), 128))
    kp = k_eff                                     # k <= bn guaranteed below
    if kp > bn:
        bn = _pad_to(kp, 128)
    Dp = _pad_to(D, 128)
    Qp = _pad_to(Q, bq)
    Np = _pad_to(N, bn)
    qp = jnp.pad(q, ((0, Qp - Q), (0, Dp - D)))
    cp = jnp.pad(c, ((0, Np - N), (0, Dp - D)))
    scores, idx = topk_mips_kernel(qp, cp, k=kp, n_valid=n_valid, bq=bq,
                                   bn=bn, interpret=interpret)
    return scores[:Q], idx[:Q]


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret",
                                             "n_valid"))
def _topk_mips_int8_jit(q, c, *, k, bq, bn, interpret, n_valid):
    # same padding contract as _topk_mips_jit; quantization happens BEFORE
    # feature-dim padding (zero columns would not change per-row |max| but
    # quantizing first keeps the scales equal to the engine-side ones, which
    # see unpadded (chunk, D) embeddings).  Value padding is 0 and scale
    # padding is 1, so padded rows score exactly 0 before the n_valid mask
    # turns them into -inf.
    Q, D = q.shape
    N = c.shape[0]
    if n_valid is None:
        n_valid = N
    n_valid = min(n_valid, N)
    k_eff = min(k, n_valid)
    bq = min(bq, _pad_to(Q, 8))
    bn = min(bn, _pad_to(max(N, k_eff), 128))
    kp = k_eff
    if kp > bn:
        bn = _pad_to(kp, 128)
    Dp = _pad_to(D, 128)
    Qp = _pad_to(Q, bq)
    Np = _pad_to(N, bn)
    qv, qs = quantize_int8(q)
    cv, cs = quantize_int8(c)
    qp = jnp.pad(qv, ((0, Qp - Q), (0, Dp - D)))
    cp = jnp.pad(cv, ((0, Np - N), (0, Dp - D)))
    qsp = jnp.pad(qs, ((0, Qp - Q), (0, 0)), constant_values=1.0)
    csp = jnp.pad(cs, ((0, Np - N), (0, 0)), constant_values=1.0)
    scores, idx = topk_mips_kernel_int8(qp, cp, qsp, csp.reshape(1, Np),
                                        k=kp, n_valid=n_valid, bq=bq, bn=bn,
                                        interpret=interpret)
    return scores[:Q], idx[:Q]


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_carry(run_s, run_i, chunk_s, chunk_i, base, *, k: int):
    """Fold a chunk-local top-k (indices relative to the chunk) into the
    running (Q, k) carry.  ``base`` is dynamic — one compile per chunk shape,
    not per chunk position."""
    s = jnp.concatenate([run_s, chunk_s], axis=1)
    i = jnp.concatenate([run_i, chunk_i + base], axis=1)
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(i, pos, axis=1)


def topk_mips_chunk(q: jnp.ndarray, c_chunk: jnp.ndarray, run_s: jnp.ndarray,
                    run_i: jnp.ndarray, *, base, n_valid: int | None = None,
                    bq: int = 128, bn: int = 1024,
                    interpret: bool | None = None,
                    score_dtype: str = "f32"):
    """Chunk-carry entry point for the streaming ValidationEngine.

    Computes the local top-k of one fixed-shape corpus chunk with the Pallas
    kernel and merges it into the running ``(Q, k)`` carry — the chunk's
    embeddings never leave the device and the full corpus scores are never
    materialized.  ``base`` (dynamic) is the chunk's global row offset;
    ``n_valid`` (static, at most two distinct values per corpus: full chunks
    and the ragged tail) masks chunk padding rows.
    """
    k = run_s.shape[1]
    n = c_chunk.shape[0] if n_valid is None else min(n_valid, c_chunk.shape[0])
    if n <= 0:
        return run_s, run_i
    s, i = topk_mips(q, c_chunk, k=min(k, n), bq=bq, bn=bn,
                     interpret=interpret, n_valid=n_valid,
                     score_dtype=score_dtype)
    return _merge_carry(run_s, run_i, s, i.astype(jnp.int32),
                        jnp.asarray(base, jnp.int32), k=k)
