"""Pallas TPU kernel: blocked exact MIPS with a running top-k in VMEM.

The paper's retrieval stage — score every corpus vector against every
validation query and keep the top-k — is a flat inner-product scan.  The
GPU/host baseline (FAISS ``IndexFlatIP``) streams the corpus through CPU
SIMD registers; the TPU-native rethink is:

  * corpus tiles (``bn x D``) stream HBM -> VMEM once; each tile hits the
    MXU against a resident query tile (``bq x D``) — a (bq, D) x (D, bn)
    matmul with f32 accumulation;
  * the per-query *running top-k* (scores + global indices) lives in VMEM
    scratch across the whole corpus sweep — candidates never round-trip to
    HBM per tile (the FAISS heap equivalent, kept on-chip);
  * the merge is ``top_k([running ‖ tile_scores])`` — a tournament merge on
    the VPU, amortized against the MXU matmul;
  * grid = (q_tiles, corpus_tiles), corpus innermost ("arbitrary"
    semantics — the running top-k is carried across corpus steps; q tiles
    are embarrassingly parallel).

Dims: D and bn are multiples of 128 (MXU lane width); bq a multiple of 8
(sublane).  ``ops.topk_mips`` pads inputs and slices the result.

Precision (``ops.topk_mips(score_dtype=...)``):

  * ``f32``  — the kernel below, untouched;
  * ``bf16`` — the SAME kernel body with bf16 query/corpus tiles: the MXU
    eats bf16 natively and ``preferred_element_type=jnp.float32`` keeps the
    accumulator (and therefore the running top-k carry) in f32, so the
    ``-inf`` padding mask and the tournament merge are unchanged — only the
    HBM->VMEM tile traffic halves;
  * ``int8`` — :func:`topk_mips_kernel_int8`: int8 tiles hit the MXU with an
    exact int32 accumulator; per-row scale factors ride alongside the tiles
    into VMEM (a ``(bq, 1)`` query-scale column and a ``(1, bn)``
    corpus-tile scale row) and are folded into the scores BEFORE the
    ``-inf`` mask and the running-carry tournament merge, so the carry
    itself stays plain f32 — narrow dtypes never touch the merge.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _mips_kernel(q_ref, c_ref, out_s_ref, out_i_ref, run_s, run_i, *,
                 k: int, bn: int, n_total: int):
    """One (q_tile, c_tile) grid step.

    q_ref: (bq, D) VMEM; c_ref: (bn, D) VMEM;
    out_s_ref / out_i_ref: (bq, k) output tiles;
    run_s / run_i: (bq, k) VMEM scratch carried across corpus steps.
    """
    ci = pl.program_id(1)
    n_ctiles = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, -jnp.inf)
        run_i[...] = jnp.zeros_like(run_i)

    # MXU: (bq, D) x (D, bn) -> (bq, bn), f32 accumulation
    scores = jax.lax.dot_general(
        q_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    base = ci * bn
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + base
    valid = col < n_total                       # mask corpus padding rows
    scores = jnp.where(valid, scores, -jnp.inf)

    # tournament merge: top-k of [running candidates ‖ this tile]
    merged_s = jnp.concatenate([run_s[...], scores], axis=1)
    merged_i = jnp.concatenate([run_i[...], col], axis=1)
    top_s, pos = jax.lax.top_k(merged_s, k)
    run_s[...] = top_s
    run_i[...] = jnp.take_along_axis(merged_i, pos, axis=1)

    @pl.when(ci == n_ctiles - 1)
    def _flush():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def _mips_kernel_int8(q_ref, c_ref, qs_ref, cs_ref, out_s_ref, out_i_ref,
                      run_s, run_i, *, k: int, bn: int, n_total: int):
    """Quantized sibling of :func:`_mips_kernel`.

    q_ref: (bq, D) int8; c_ref: (bn, D) int8;
    qs_ref: (bq, 1) f32 per-query-row scales (resident across the sweep);
    cs_ref: (1, bn) f32 per-corpus-row scales, sliced per corpus tile;
    run_s / run_i: (bq, k) f32/i32 VMEM scratch — the carry stays f32, the
    scales are folded into the tile scores before the mask and the merge.
    """
    ci = pl.program_id(1)
    n_ctiles = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, -jnp.inf)
        run_i[...] = jnp.zeros_like(run_i)

    # MXU: int8 x int8 -> exact int32 accumulation, then dequantize with the
    # per-row scales (outer product of the two scale vectors) into f32 —
    # BEFORE masking, so -inf padding survives the narrow input dtype.
    raw = jax.lax.dot_general(
        q_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    scores = raw.astype(jnp.float32) * qs_ref[...] * cs_ref[...]

    base = ci * bn
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + base
    valid = col < n_total                       # mask corpus padding rows
    scores = jnp.where(valid, scores, -jnp.inf)

    merged_s = jnp.concatenate([run_s[...], scores], axis=1)
    merged_i = jnp.concatenate([run_i[...], col], axis=1)
    top_s, pos = jax.lax.top_k(merged_s, k)
    run_s[...] = top_s
    run_i[...] = jnp.take_along_axis(merged_i, pos, axis=1)

    @pl.when(ci == n_ctiles - 1)
    def _flush():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "n_valid", "bq", "bn", "interpret"))
def topk_mips_kernel(q: jnp.ndarray, c: jnp.ndarray, *, k: int,
                     n_valid: int, bq: int = 128, bn: int = 1024,
                     interpret: bool = False):
    """q: (Q, D), c: (N, D) — Q % bq == 0, N % bn == 0, D % 128 == 0.

    ``n_valid`` <= N marks real (non-padding) corpus rows.  Returns
    (scores (Q, k) f32, indices (Q, k) i32).  ``k`` <= bn.
    """
    Q, D = q.shape
    N = c.shape[0]
    assert Q % bq == 0 and N % bn == 0 and k <= bn and D % 128 == 0
    grid = (Q // bq, N // bn)

    kernel = functools.partial(_mips_kernel, k=k, bn=bn, n_total=n_valid)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, D), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((bn, D), lambda qi, ci: (ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((bq, k), lambda qi, ci: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, c)


@functools.partial(jax.jit,
                   static_argnames=("k", "n_valid", "bq", "bn", "interpret"))
def topk_mips_kernel_int8(q: jnp.ndarray, c: jnp.ndarray,
                          q_scale: jnp.ndarray, c_scale: jnp.ndarray, *,
                          k: int, n_valid: int, bq: int = 128,
                          bn: int = 1024, interpret: bool = False):
    """Quantized top-k MIPS: q (Q, D) int8, c (N, D) int8, q_scale (Q, 1)
    f32 per-query-row scales, c_scale (1, N) f32 per-corpus-row scales.

    Same grid/blocking contract as :func:`topk_mips_kernel` (Q % bq == 0,
    N % bn == 0, k <= bn, D % 128 == 0); the scale vectors are blocked
    alongside the tiles — ``c_scale`` arrives one ``(1, bn)`` slice per
    corpus tile — and folded into the scores before the f32 carry merge.
    Returns (scores (Q, k) f32, indices (Q, k) i32).
    """
    Q, D = q.shape
    N = c.shape[0]
    assert Q % bq == 0 and N % bn == 0 and k <= bn and D % 128 == 0
    assert q_scale.shape == (Q, 1) and c_scale.shape == (1, N)
    grid = (Q // bq, N // bn)

    kernel = functools.partial(_mips_kernel_int8, k=k, bn=bn, n_total=n_valid)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, D), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((bn, D), lambda qi, ci: (ci, 0)),
            pl.BlockSpec((bq, 1), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((1, bn), lambda qi, ci: (0, ci)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((bq, k), lambda qi, ci: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, c, q_scale, c_scale)
