"""Pallas TPU kernel: fused (flash) attention with online softmax.

Used by the corpus encoder (bidirectional, the paper's hot encode path) and
the LM backbones (causal, GQA).  VMEM tiling:

  * q tile (bq, d) resident; k/v tiles (bk, d) stream;
  * online softmax: running row-max ``m``, normalizer ``l`` and the
    f32 accumulator ``acc`` live in VMEM scratch across kv tiles — the
    (S, T) score matrix never exists in HBM;
  * causal blocks strictly above the diagonal are skipped via ``pl.when``
    (compute skipped, DMA still scheduled — Mosaic hoists the cheap case);
  * GQA: the kv-head block index is ``h // group`` — no KV duplication.

Grid: (batch, heads, q_blocks, kv_blocks), kv innermost ("arbitrary").
``m``/``l`` are stored lane-replicated (bq, 128) — the standard Mosaic
layout trick for row statistics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

_LANES = 128
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, causal: bool, t_valid: int,
                  scale: float):
    qi, ki = pl.program_id(2), pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < t_valid
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[:, :1]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                               # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                       # (bq, 1)
        l_new = corr * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_k - 1)
    def _flush():
        l = l_scr[:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "t_valid", "bq", "bk", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool, t_valid: int,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = False):
    """q: (B, H, S, d); k, v: (B, KV, T, d); H % KV == 0.

    S % bq == 0, T % bk == 0, d % 128 == 0 (ops.py pads).  ``t_valid``
    masks key padding.  Returns (B, H, S, d) in q.dtype.
    """
    B, H, S, d = q.shape
    KV, T = k.shape[1], k.shape[2]
    assert H % KV == 0 and S % bq == 0 and T % bk == 0 and d % _LANES == 0
    group = H // KV
    grid = (B, H, S // bq, T // bk)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               t_valid=t_valid, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
