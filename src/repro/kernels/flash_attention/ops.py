"""jit'd dispatch wrapper for the flash_attention Pallas kernel.

Pads (S, T) to block multiples and d to the 128-lane width, then slices.
On non-TPU backends the kernel body runs in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def flash_attention(q, k, v, *, causal: bool = False,
                    t_valid: int | None = None, bq: int = 256, bk: int = 256,
                    interpret: bool | None = None):
    """q: (B, H, S, d); k, v: (B, KV, T, d) -> (B, H, S, d)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, S, d = q.shape
    T = k.shape[2]
    t_valid = T if t_valid is None else t_valid
    bq = min(bq, _pad_to(S, 8))
    bk = min(bk, _pad_to(T, 128))
    Sp, Tp, dp = _pad_to(S, bq), _pad_to(T, bk), _pad_to(d, 128)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, dp - d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, dp - d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, dp - d)))
    # the kernel scales by 1/sqrt(d_padded); rescale so it matches 1/sqrt(d)
    if dp != d:
        qp = qp * (dp ** 0.5) / (d ** 0.5)
    out = flash_attention_kernel(qp, kp, vp, causal=causal, t_valid=t_valid,
                                 bq=bq, bk=bk, interpret=interpret)
    return out[:, :, :S, :d]
