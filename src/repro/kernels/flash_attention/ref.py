"""Pure-jnp oracle for flash_attention: dense softmax attention w/ GQA."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool, t_valid: int | None = None):
    """q: (B, H, S, d); k, v: (B, KV, T, d) -> (B, H, S, d)."""
    B, H, S, d = q.shape
    KV, T = k.shape[1], k.shape[2]
    group = H // KV
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (d ** 0.5)
    tpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if t_valid is not None:
        mask = mask & (tpos[None, :] < t_valid)
    if causal:
        mask = mask & (tpos[None, :] <= jnp.arange(S)[:, None])
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
