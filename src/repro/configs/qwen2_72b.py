"""qwen2-72b — dense LM with GQA + QKV bias [arXiv:2407.10671].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""

from repro.configs.registry import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-72b", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=29568, vocab_size=152064, qkv_bias=True,
        rope_theta=1_000_000.0, act="swiglu", tie_embeddings=False, q_chunk=512)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-72b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=211, qkv_bias=True, act="swiglu",
        q_chunk=16)
