"""graphcast — encoder-processor-decoder mesh GNN [arXiv:2212.12794].

n_layers=16 d_hidden=512 mesh_refinement=6 aggregator=sum n_vars=227.
The assigned shapes run the same block over generic benchmark graphs
(see DESIGN.md §Arch-applicability).
"""

from repro.configs.registry import GNN_SHAPES
from repro.models.graphcast import GraphCastConfig

FAMILY = "gnn"
SHAPES = GNN_SHAPES


def full_config() -> GraphCastConfig:
    return GraphCastConfig(name="graphcast", n_layers=16, d_hidden=512,
                           n_vars=227, d_feat=227, mesh_refinement=6,
                           aggregator="sum")


def smoke_config() -> GraphCastConfig:
    return GraphCastConfig(name="graphcast-smoke", n_layers=2, d_hidden=32,
                           n_vars=7, d_feat=11, mesh_refinement=1,
                           aggregator="sum")
