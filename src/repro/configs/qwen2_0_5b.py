"""qwen2-0.5b — dense LM with GQA + QKV bias [arXiv:2407.10671].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, tied embeddings.
"""

from repro.configs.registry import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-0.5b", n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        head_dim=64, d_ff=4864, vocab_size=151936, qkv_bias=True,
        rope_theta=1_000_000.0, act="swiglu", tie_embeddings=True, q_chunk=512)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-0.5b-smoke", n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
        head_dim=8, d_ff=96, vocab_size=211, qkv_bias=True, act="swiglu",
        tie_embeddings=True, q_chunk=16)
