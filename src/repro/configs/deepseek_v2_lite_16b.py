"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

27L d_model=2048 16H, MLA kv_lora=512 (qk_nope=128, qk_rope=64, v=128),
MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408, first layer dense
(d_ff=10944), vocab=102400.

NOTE (DESIGN.md §4): the assignment line mentions both "64e top-6" and
"2 shared+160 routed"; 160 routed belongs to full V2 — lite is 64 routed.
"""

from repro.configs.registry import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=10944, vocab_size=102400,
        rope_theta=10000.0, act="swiglu",
        mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        moe_num_experts=64, moe_top_k=6, moe_d_ff=1408, moe_num_shared=2,
        first_k_dense=1, moe_mode="replace", q_chunk=512)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-lite-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=211, act="swiglu",
        mla=True, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        moe_num_experts=8, moe_top_k=2, moe_d_ff=48, moe_num_shared=2,
        first_k_dense=1, moe_mode="replace", q_chunk=16)
