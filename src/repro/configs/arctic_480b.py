"""arctic-480b — dense + residual-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000; 128 experts top-2
applied as a *residual* branch in parallel with the dense FFN.
"""

from repro.configs.registry import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        head_dim=128, d_ff=4864, vocab_size=32000, qkv_bias=False,
        rope_theta=10000.0, act="swiglu",
        moe_num_experts=128, moe_top_k=2, moe_d_ff=4864, moe_mode="residual",
        moe_capacity_factor=1.25, q_chunk=512)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-480b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=211, act="swiglu",
        moe_num_experts=8, moe_top_k=2, moe_d_ff=96, moe_mode="residual",
        q_chunk=16)
