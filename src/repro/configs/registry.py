"""Architecture registry: ``--arch <id>`` -> config + family metadata.

Each arch module exposes:
  FAMILY        : "lm" | "gnn" | "recsys" | "biencoder"
  full_config() : the exact published configuration (dry-run only)
  smoke_config(): reduced same-family config (CPU tests)
  SHAPES        : dict shape_name -> shape params (family-specific)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict

_ARCH_MODULES = {
    "deepseek-67b": "repro.configs.deepseek_67b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "arctic-480b": "repro.configs.arctic_480b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "graphcast": "repro.configs.graphcast",
    "bert4rec": "repro.configs.bert4rec",
    "sasrec": "repro.configs.sasrec",
    "mind": "repro.configs.mind",
    "deepfm": "repro.configs.deepfm",
    # the paper's own architecture (BERT-based dense-retriever bi-encoder)
    "dr-bert-base": "repro.configs.dr_bert_base",
}

ARCH_IDS = list(_ARCH_MODULES)
ASSIGNED_ARCH_IDS = [a for a in ARCH_IDS if a != "dr-bert-base"]


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    family: str
    full_config: Callable[[], Any]
    smoke_config: Callable[[], Any]
    shapes: Dict[str, dict]
    module: Any


def get(arch_id: str) -> ArchSpec:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return ArchSpec(arch_id=arch_id, family=mod.FAMILY, full_config=mod.full_config,
                    smoke_config=mod.smoke_config, shapes=dict(mod.SHAPES), module=mod)


# Shape tables shared within each family -----------------------------------

LM_SHAPES = {
    "train_4k": {"kind": "train", "seq_len": 4096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32768, "global_batch": 128},
    # decode against a 524,288-token cache: O(L) per emitted token — see
    # DESIGN.md §2.4 for why full-attention archs run this cell (decode-only).
    "long_500k": {"kind": "decode", "seq_len": 524288, "global_batch": 1},
}

GNN_SHAPES = {
    "full_graph_sm": {"kind": "full_graph", "n_nodes": 2708, "n_edges": 10556,
                      "d_feat": 1433},
    "minibatch_lg": {"kind": "minibatch", "n_nodes": 232965, "n_edges": 114615892,
                     "batch_nodes": 1024, "fanout": (15, 10)},
    "ogb_products": {"kind": "full_graph", "n_nodes": 2449029, "n_edges": 61859140,
                     "d_feat": 100},
    "molecule": {"kind": "batched_graphs", "n_nodes": 30, "n_edges": 64,
                 "batch": 128},
}

RECSYS_SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1, "n_candidates": 1_000_000},
}

# The paper's own validation workload shapes (encode corpus / retrieve):
BIENCODER_SHAPES = {
    "train_contrastive": {"kind": "train", "global_batch": 256, "q_len": 32,
                          "p_len": 128, "n_passages": 2},
    "encode_corpus": {"kind": "encode", "batch": 4096, "p_len": 128},
    "retrieve": {"kind": "retrieve", "n_queries": 6980, "corpus": 8_841_823,
                 "dim": 768, "k": 1000},
}
