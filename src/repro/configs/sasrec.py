"""sasrec — unidirectional self-attentive recommender [arXiv:1808.09781].

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50, next-item objective.
"""

from repro.configs.registry import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def full_config() -> RecsysConfig:
    return RecsysConfig(name="sasrec", model_type="sasrec", embed_dim=50,
                        n_blocks=2, n_heads=1, seq_len=50,
                        item_vocab=1_000_000, n_negatives=2048)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(name="sasrec-smoke", model_type="sasrec",
                        embed_dim=24, n_blocks=2, n_heads=1, seq_len=12,
                        item_vocab=499, n_negatives=32)
