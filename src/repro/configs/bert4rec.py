"""bert4rec — bidirectional sequential recommender [arXiv:1904.06690].

embed_dim=64 n_blocks=2 n_heads=2 seq_len=200, cloze (masked-item) objective.
"""

from repro.configs.registry import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def full_config() -> RecsysConfig:
    return RecsysConfig(name="bert4rec", model_type="bert4rec", embed_dim=64,
                        n_blocks=2, n_heads=2, seq_len=200,
                        item_vocab=1_000_000, n_negatives=2048)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(name="bert4rec-smoke", model_type="bert4rec",
                        embed_dim=32, n_blocks=2, n_heads=2, seq_len=16,
                        item_vocab=997, n_negatives=32)
