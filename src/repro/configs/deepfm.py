"""deepfm — FM + deep MLP CTR model [arXiv:1703.04247].

n_sparse=39 embed_dim=10 mlp=400-400-400; Criteo-scale field vocabularies
(26 categorical + 13 bucketized numeric = 39 sparse fields, ~33.8M rows).
"""

from repro.configs.registry import RECSYS_SHAPES
from repro.models.recsys import (CRITEO_CAT_VOCABS, CRITEO_NUM_BUCKETS,
                                 RecsysConfig)

FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def full_config() -> RecsysConfig:
    return RecsysConfig(name="deepfm", model_type="deepfm", embed_dim=10,
                        field_vocab_sizes=CRITEO_NUM_BUCKETS + CRITEO_CAT_VOCABS,
                        mlp_dims=(400, 400, 400), max_hot=1)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(name="deepfm-smoke", model_type="deepfm", embed_dim=8,
                        field_vocab_sizes=(13, 7, 31, 17, 5, 23),
                        mlp_dims=(32, 32), max_hot=2)
