"""dr-bert-base — the paper's own architecture: BERT-base bi-encoder DR.

12L d_model=768 12H d_ff=3072 vocab=30522, post-LN, GELU, learned positions,
CLS pooling; trained with in-batch-negative contrastive loss (Tevatron setup
the paper's demonstration uses).
"""

from repro.configs.registry import BIENCODER_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "biencoder"
SHAPES = BIENCODER_SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="dr-bert-base", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        head_dim=64, d_ff=3072, vocab_size=30522, qkv_bias=True,
        use_rope=False, max_position_embeddings=512, norm_style="post",
        act="gelu", causal=False, q_chunk=128)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="dr-bert-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=211, qkv_bias=True,
        use_rope=False, max_position_embeddings=64, norm_style="post",
        act="gelu", causal=False, q_chunk=16)
