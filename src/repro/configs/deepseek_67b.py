"""deepseek-67b — dense llama-arch LM [arXiv:2401.02954].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""

from repro.configs.registry import LM_SHAPES
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
SHAPES = LM_SHAPES


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-67b", n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=22016, vocab_size=102400, qkv_bias=False,
        rope_theta=10000.0, act="swiglu", tie_embeddings=False, q_chunk=512)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-67b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=257, qkv_bias=False, act="swiglu",
        q_chunk=16)
