"""mind — multi-interest network with dynamic (capsule) routing [arXiv:1904.08030].

embed_dim=64 n_interests=4 capsule_iters=3.
"""

from repro.configs.registry import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def full_config() -> RecsysConfig:
    return RecsysConfig(name="mind", model_type="mind", embed_dim=64,
                        n_interests=4, capsule_iters=3, seq_len=50,
                        item_vocab=1_000_000, n_negatives=2048)


def smoke_config() -> RecsysConfig:
    return RecsysConfig(name="mind-smoke", model_type="mind", embed_dim=16,
                        n_interests=3, capsule_iters=2, seq_len=10,
                        item_vocab=211, n_negatives=16)
