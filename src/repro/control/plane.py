"""ControlPlane — the asynchronous feedback half of Asyncval, in one object.

The seed repo's data path is one-way: trainer -> checkpoints -> validator ->
ledger.  The control plane closes the loop without ever putting validation
on the training hot path:

    ledger row --> CheckpointSelector --> quality-aware GC (top-k ∪ protect)
               --> EarlyStopController --> atomic STOP marker (trainer polls)
               --> (after stop) greedy/uniform checkpoint soup -->
                   virtual checkpoint, re-validated via the normal path

It plugs into ``AsyncValidator(controller=...)``: the validator invokes
``on_result`` after every ledger append (on the validator thread — the
trainer never sees it).  The trainer's only coupling is the STOP marker file
and the optional ``note_train`` feed of train losses (for the overfit
detector's train-vs-validation gap trend).

Every decision is an event in a :class:`ControlEventLog`;
:func:`replay_ledger` re-derives the full decision sequence offline from
validation-ledger rows alone — byte-identical, which makes control policies
testable without ever running a trainer.
"""

from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.ckpt import checkpoint as ckpt
from repro.control.earlystop import EarlyStopConfig, EarlyStopController
from repro.control.ensemble import greedy_soup, materialize_virtual, \
    uniform_soup
from repro.control.events import ControlEvent, ControlEventLog
from repro.control.metricspec import MetricSpec, flatten_rows
from repro.control.selection import CheckpointSelector, SelectionConfig


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    metric: str = "MRR@10"         # a composite spec: "m", "task:m", or a
                                   # weighted "w1*task:m + ..." aggregate
                                   # over a multi-task suite's flat metrics
    mode: str = "max"              # max | min (is bigger better?)
    keep_top_k: int = 0            # 0 = quality-aware GC disabled
    ema: float = 0.0               # selection smoothing (0 = off)
    early_stop: bool = False
    patience: int = 3
    min_delta: float = 0.0
    overfit_window: int = 0        # >= 3 enables the overfit detector
    overfit_min_slope: float = 0.0
    ensemble_top_k: int = 0        # 0 = ensembling disabled
    ensemble_greedy: bool = True   # greedy metric-guided vs uniform soup

    @property
    def ranking_depth(self) -> int:
        return max(self.keep_top_k, self.ensemble_top_k, 1)


class ControlPlane:
    def __init__(self, ckpt_root: Optional[str], cfg: ControlConfig, *,
                 stop_path: Optional[str] = None,
                 event_path: Optional[str] = None,
                 telemetry=None,
                 durability: Optional[Callable[[int], str]] = None):
        self.ckpt_root = ckpt_root
        self.cfg = cfg
        # lazy hand-off durability gate: ``durability(step)`` reports
        # "pending" | "durable" | "failed" (SnapshotChannel.durability is
        # the canonical source; default falls back to the COMMIT marker).
        # DECISIONS (selection, early stop) act on snapshot-scored rows
        # immediately — they are reversible observations; ACTUATIONS that
        # destroy state (quality GC here; soup/promotion already require a
        # committed checkpoint to read) are deferred while any observed
        # snapshot-scored step is still pending.  Actuations are excluded
        # from events.decisions(), so deferral never breaks replay parity.
        self.durability = durability
        self._gc_hold: set = set()
        self._gc_validator: Any = None
        # observation only (decision latency, `selected` lifecycle events);
        # the decision path itself stays clock-free so replay_ledger — which
        # constructs planes without telemetry — re-derives identical events.
        self.telemetry = telemetry
        self.events = ControlEventLog(event_path)
        self.selector = CheckpointSelector(
            SelectionConfig(metric=cfg.metric, mode=cfg.mode,
                            top_k=cfg.ranking_depth, ema=cfg.ema),
            event_log=self.events)
        self.earlystop: Optional[EarlyStopController] = None
        if cfg.early_stop:
            self.earlystop = EarlyStopController(
                EarlyStopConfig(metric=cfg.metric, mode=cfg.mode,
                                patience=cfg.patience,
                                min_delta=cfg.min_delta,
                                overfit_window=cfg.overfit_window,
                                overfit_min_slope=cfg.overfit_min_slope),
                stop_path=stop_path, event_log=self.events)
        self._train_lock = threading.Lock()
        self._train_steps: List[int] = []          # sorted
        self._train_loss: Dict[int, float] = {}
        self.ensemble_step: Optional[int] = None
        self.ensemble_members: List[int] = []

    # -- train-side feed (overfit detector input) ---------------------------
    def note_train(self, step: int, metrics: Dict[str, Any]) -> None:
        """Record a train-loop loss (called from the trainer's metrics hook;
        thread-safe, never blocks on validation state)."""
        if "loss" not in metrics:
            return
        with self._train_lock:
            if step not in self._train_loss:
                bisect.insort(self._train_steps, step)
            self._train_loss[step] = float(metrics["loss"])

    def train_loss_for(self, step: int) -> Optional[float]:
        """Latest train loss at or before ``step`` (pure given the feed)."""
        with self._train_lock:
            i = bisect.bisect_right(self._train_steps, step)
            if i == 0:
                return None
            return self._train_loss[self._train_steps[i - 1]]

    # -- decision path (pure; shared by online + offline replay) ------------
    def observe(self, step: int, metrics: Dict[str, float],
                context: Optional[dict] = None) -> None:
        tel = self.telemetry
        if tel is None:
            return self._observe(step, metrics, context)
        # time the decision from OUTSIDE the fold body: the fold itself
        # stays clock-free, so a replay plane (never given telemetry)
        # re-derives identical decisions and events
        before = self.selector.best_step
        t0 = time.perf_counter()
        self._observe(step, metrics, context)
        tel.metrics.histogram("control.decision_s").observe(
            time.perf_counter() - t0)
        after = self.selector.best_step
        if after != before:
            tel.event("selected", step=after, prev=before, observed=step)

    def _observe(self, step: int, metrics: Dict[str, float],
                 context: Optional[dict] = None) -> None:
        decision = self.selector.observe(step, metrics, context=context)
        if self.earlystop is not None:
            # early stopping judges the SAME (EMA-smoothed) series the
            # selector ranks by — with cfg.ema a raw noise spike must not
            # reset patience or fake an overfit trend.
            smoothed = {**metrics, self.cfg.metric: decision["value"]}
            self.earlystop.observe(step, smoothed,
                                   train_loss=self.train_loss_for(step))

    @property
    def stopped(self) -> bool:
        return self.earlystop is not None and self.earlystop.stopped

    def rehydrate(self, rows, expected_tasks=None,
                  group: str = "consecutive") -> int:
        """Warm the selector's ranking from a previous session's
        validation-ledger rows (``ValidationLedger.rows()``).
        ``expected_tasks`` (the suite's task names) drops partially-recorded
        steps — rows a crash left incomplete — which the online controller
        never observed and which will re-validate in full.

        Restart safety for quality-aware GC: the ledger makes validation
        idempotent (old steps are never re-validated), so without this a
        fresh selector would rank only the new session's steps and GC the
        previous session's best checkpoints.  Per-task (schema-v2) rows are
        grouped back into per-step observations (``group="completion"`` for
        fleet ledgers, where workers interleave steps — see
        :func:`~repro.control.metricspec.flatten_rows`).  Early stopping is
        NOT rehydrated — a stop verdict must come from evidence this session
        gathers (a continued run deliberately gets fresh patience)."""
        n = 0
        for step, flat, ctx in flatten_rows(rows, expected_tasks,
                                            with_context=True, group=group):
            try:
                self.selector.observe(step, flat, context=ctx)
            except KeyError:
                # without expected_tasks a partially-recorded step can
                # still surface here, missing the metric the spec needs;
                # online, the controller never saw it — the validator will
                # re-validate and re-observe it, so skip rather than
                # poison startup.
                continue
            n += 1
        return n

    # -- validator hook (decisions + actuations) ----------------------------
    def on_result(self, result: Any, validator: Any = None) -> None:
        """AsyncValidator post-record hook (runs on the validator thread)."""
        # provenance attached to the decision event exactly as the ledger
        # rows record it, so offline replay re-derives the same payload
        context = {"engine": str(getattr(result, "engine", "")),
                   "score_dtype": str(getattr(result, "score_dtype",
                                              "f32"))}
        wid = str(getattr(result, "worker_id", "") or "")
        if wid:
            # fleet attribution, keyed only when present — exactly like the
            # ledger rows, so replay re-derives the same event payloads
            context["worker_id"] = wid
        hand = str(getattr(result, "handoff", "") or "")
        if hand and hand != "durable":
            # hand-off provenance, keyed only for snapshot-scored rows —
            # mirroring the ledger's omitted-when-durable discipline
            context["handoff"] = hand
        self.observe(result.step, result.metrics, context=context)
        if self.cfg.keep_top_k > 0 and self.ckpt_root and validator is not None:
            self._gc_validator = validator
            self.hold_gc_until_durable(result.step, hand)
            self.maybe_gc(validator)

    def hold_gc_until_durable(self, step: int, handoff: str = "") -> bool:
        """Register a GC hold when ``step``'s evidence is snapshot-scored
        and its durable commit hasn't landed: deleting OTHER checkpoints on
        its say-so is irreversible, so GC waits for the step's COMMIT (or
        its failure).  Returns True when a hold was taken."""
        if "snapshot" in str(handoff).split(",") \
                and self._durable_state(step) == "pending":
            self._gc_hold.add(step)
            return True
        return False

    def _durable_state(self, step: int) -> str:
        """``"pending" | "durable" | "failed"`` for ``step`` — the wired
        ``durability`` callable when present, else the COMMIT marker."""
        if self.durability is not None:
            return str(self.durability(step))
        if self.ckpt_root is None:
            return "durable"
        return "durable" if ckpt.is_committed(
            ckpt._step_dir(self.ckpt_root, step)) else "pending"

    def maybe_gc(self, validator: Any = None) -> bool:
        """Run quality-aware GC unless a snapshot-scored step it would act
        on is still awaiting its durable commit.  Holds resolve on either
        outcome — DURABLE (the evidence persisted) or FAILED (the step's
        checkpoint will never exist; nothing to protect-by-deferral).
        Returns True when GC actually ran."""
        validator = validator if validator is not None \
            else self._gc_validator
        if self.cfg.keep_top_k <= 0 or not self.ckpt_root \
                or validator is None:
            return False
        self._gc_hold = {s for s in self._gc_hold
                         if self._durable_state(s) == "pending"}
        if self._gc_hold:
            return False
        self.selector.gc(self.ckpt_root,
                         protect=validator.protect_set(),
                         k=self.cfg.keep_top_k)
        return True

    # -- ensemble (after training stopped / drained) ------------------------
    def build_ensemble(self, score_fn: Callable[[Any], float], *,
                       step: Optional[int] = None) -> Optional[int]:
        """Soup the top-k ranked checkpoints into a committed virtual
        checkpoint; returns its step (None if ensembling is disabled or
        fewer than two members are rankable)."""
        if self.cfg.ensemble_top_k <= 0 or not self.ckpt_root:
            return None
        ranked = self.selector.top_steps(self.cfg.ensemble_top_k)
        # only checkpoints still on disk can be souped: when the ranking
        # runs deeper than the retention budget (ensemble_top_k >
        # keep_top_k), quality-aware GC has already deleted the tail.
        # Filtered here in the actuation layer — the selector's decision
        # state must not depend on filesystem effects, or offline replay
        # would diverge.
        available = set(ckpt.list_steps(self.ckpt_root))
        ranked = [s for s in ranked if s in available]
        if len(ranked) < 2:
            return None
        if self.cfg.ensemble_greedy:
            params, members, score = greedy_soup(
                self.ckpt_root, ranked, score_fn, mode=self.cfg.mode)
        else:
            params = uniform_soup(self.ckpt_root, ranked)
            members, score = list(ranked), float(score_fn(params))
        vstep = materialize_virtual(self.ckpt_root, params, members=members,
                                    step=step)
        self.ensemble_step, self.ensemble_members = vstep, members
        self.events.emit("ensemble", vstep, members=members, score=score,
                         greedy=self.cfg.ensemble_greedy)
        return vstep


def replay_ledger(rows, cfg: ControlConfig, *, train_history=None,
                  expected_tasks=None,
                  group: str = "consecutive") -> ControlPlane:
    """Offline replay: re-derive the decision sequence from validation-ledger
    rows (``ValidationLedger.rows()``, insertion order).

    Returns a plane whose ``events.decisions()`` is identical to the online
    run's — no filesystem access, no markers, no deletions.
    ``train_history``: optional ``[(step, loss), ...]`` feed for the overfit
    detector (the trainer's logged losses).  ``expected_tasks``: the suite's
    task names, to drop crash-torn partial steps the online controller
    never observed.  ``group="completion"`` replays a FLEET ledger, where
    workers interleave rows across steps and an observation happens when a
    step's last expected task row lands (the supervisor's feed order)."""
    plane = ControlPlane(None, cfg, stop_path=None, event_path=None)
    for step, loss in (train_history or []):
        plane.note_train(step, {"loss": loss})
    for step, flat, ctx in flatten_rows(rows, expected_tasks,
                                        with_context=True, group=group):
        try:
            plane.observe(step, flat, context=ctx)
        except KeyError:
            continue          # partial step (crash between task rows): the
            #                   online controller never observed it either
    return plane
