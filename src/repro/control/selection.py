"""Ledger-driven checkpoint selection: best-so-far, top-k, quality-aware GC.

The validator produces ledger rows; nothing in the seed repo consumed them.
``CheckpointSelector`` closes that loop: it ranks checkpoints by a chosen
validation metric (optionally EMA-smoothed to de-noise subset validation),
maintains best-so-far / top-k, and drives *quality-aware* retention through
``ckpt.gc_checkpoints(keep=...)`` — keep the top-k checkpoints by metric
plus everything the validator still protects, instead of the blind
``keep_last`` window the trainer shipped with.

Determinism: ranking state is a pure function of the ``observe`` call
sequence.  Ties break toward the LATER step (fresher weights preferred at
equal quality), so replaying a ledger reproduces identical rankings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.ckpt import checkpoint as ckpt
from repro.control.events import ControlEventLog
from repro.control.metricspec import MetricSpec


@dataclasses.dataclass(frozen=True)
class SelectionConfig:
    metric: str = "MRR@10"       # a composite spec: "m", "task:m", or a
                                 # weighted "w1*task:m + w2*task2:m" sum
    mode: str = "max"            # max | min (is bigger better?)
    top_k: int = 3               # ranking depth (also the GC keep budget)
    ema: float = 0.0             # 0 disables; else s_t = ema*s_{t-1} + (1-ema)*x_t

    def __post_init__(self):
        if self.mode not in ("max", "min"):
            raise ValueError(f"mode must be max|min, got {self.mode!r}")
        if not (0.0 <= self.ema < 1.0):
            raise ValueError(f"ema must be in [0, 1), got {self.ema}")
        MetricSpec.parse(self.metric)         # fail fast on a bad spec


class CheckpointSelector:
    def __init__(self, cfg: SelectionConfig,
                 event_log: Optional[ControlEventLog] = None):
        self.cfg = cfg
        self.spec = MetricSpec.parse(cfg.metric)
        self.events = event_log if event_log is not None else ControlEventLog()
        self._raw: Dict[int, float] = {}
        self._value: Dict[int, float] = {}    # smoothed (== raw when ema=0)
        self._ema_state: Optional[float] = None

    # -- ranking ------------------------------------------------------------
    def _key(self, item: Tuple[int, float]):
        step, value = item
        sign = -1.0 if self.cfg.mode == "max" else 1.0
        return (sign * value, -step)          # ties -> later step first

    def ranking(self) -> List[Tuple[int, float]]:
        """(step, smoothed value) best-first."""
        return sorted(self._value.items(), key=self._key)

    def top_steps(self, k: Optional[int] = None) -> List[int]:
        k = self.cfg.top_k if k is None else k
        return [s for s, _ in self.ranking()[:max(k, 0)]]

    @property
    def best_step(self) -> Optional[int]:
        top = self.top_steps(1)
        return top[0] if top else None

    @property
    def best_value(self) -> Optional[float]:
        s = self.best_step
        return None if s is None else self._value[s]

    def value(self, step: int) -> Optional[float]:
        return self._value.get(step)

    # -- ingestion ----------------------------------------------------------
    def observe(self, step: int, metrics: Dict[str, float],
                context: Optional[dict] = None) -> dict:
        """Fold one validation row in (observation order = smoothing order).

        ``context`` is optional provenance (``{"engine", "score_dtype"}``)
        merged into the decision record, so every ``select`` event names the
        data path and scoring precision that produced its value — mixed-
        precision ledgers stay auditable from the event log alone.

        Returns the decision record; also emitted as a ``select`` event."""
        x = self.spec.value(metrics)
        self._raw[step] = x
        if self.cfg.ema > 0.0:
            prev = self._ema_state if self._ema_state is not None else x
            value = self.cfg.ema * prev + (1.0 - self.cfg.ema) * x
            self._ema_state = value
        else:
            value = x
        prev_best = self.best_step
        self._value[step] = value
        decision = {"value": value, "raw": x,
                    "best_step": self.best_step,
                    "new_best": self.best_step == step
                                and prev_best != step,
                    "top_steps": self.top_steps()}
        if context:
            decision.update(context)
        self.events.emit("select", step, **decision)
        return decision

    def observe_rows(self, rows: Iterable[dict],
                     expected_tasks=None) -> None:
        """Replay validation-ledger rows (``ValidationLedger.rows()``) —
        per-task rows are grouped back into per-step observations.  A
        partially-recorded step (crash between a suite's task rows) is
        skipped — dropped outright when ``expected_tasks`` is given, else
        when it lacks the metrics the spec needs — exactly as the online
        controller never observed it (same discipline as
        ``ControlPlane.rehydrate`` / ``replay_ledger``)."""
        from repro.control.metricspec import flatten_rows
        for step, flat, ctx in flatten_rows(rows, expected_tasks,
                                            with_context=True):
            try:
                self.observe(step, flat, context=ctx)
            except KeyError:
                continue

    # -- quality-aware retention --------------------------------------------
    def keep_set(self, protect: Iterable[int] = (),
                 k: Optional[int] = None) -> Set[int]:
        """Top-k by metric ∪ externally protected (unvalidated) steps.

        ``k`` overrides the ranking depth (the plane ranks deeper than it
        retains when ``ensemble_top_k > keep_top_k``)."""
        return set(self.top_steps(k)) | set(protect)

    def gc(self, root: str, protect: Iterable[int] = (),
           k: Optional[int] = None) -> List[int]:
        """Delete committed checkpoints outside :meth:`keep_set`.

        ``protect`` is the validator's ``protect_set()`` — committed-but-
        unvalidated steps are never deletable, so a checkpoint can never be
        lost between commit and its quality verdict.  The knowledge horizon
        (newest step this selector has ranked or been told to protect) is
        passed down so a checkpoint committed mid-decision survives, while
        a ranked-out newest one is still collectable."""
        keep = self.keep_set(protect, k)
        known = set(self._value) | set(protect)
        deleted = ckpt.gc_checkpoints(root, protect=protect, keep=keep,
                                      horizon=max(known) if known else None)
        self.events.emit("gc", self.best_step if self.best_step is not None
                         else -1, deleted=deleted, kept=sorted(keep))
        return deleted
