"""Checkpoint ensembles: top-k weight averaging and greedy metric-guided soup.

Checkpoint Ensembles (Chen et al., 2017) / model soups: the best "checkpoint"
of a run is often a *combination* of several.  Because the selector already
ranks checkpoints by validation metric, ensembling is a pure consumer:

  * ``uniform_soup``  — average the weights of the given steps.
  * ``greedy_soup``   — best-first: start from the top-ranked checkpoint and
    greedily keep each next candidate only if adding it does not hurt the
    validation score; by construction the result scores >= the best single
    checkpoint under the same ``score_fn``.

``materialize_virtual`` commits the soup through the ordinary two-phase
``ckpt.save`` with the trainer's ``{"params", "opt_state"}`` state shape, so
downstream (watcher -> AsyncValidator -> StreamingEngine -> ledger -> GC) a
virtual checkpoint is indistinguishable from a trained one — it is
re-validated through exactly the same path and lands in the same ledger.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core.pipeline import params_from_checkpoint

try:                                    # params trees are jax pytrees
    import jax
    _tree_map = jax.tree_util.tree_map
except ImportError:                     # pragma: no cover - jax is baked in
    _tree_map = None

VIRTUAL_KEY = "ensemble_of"


def average_params(trees: Sequence[Any],
                   weights: Optional[Sequence[float]] = None) -> Any:
    """Leaf-wise weighted mean; accumulates in float64, restores leaf dtype."""
    if not trees:
        raise ValueError("average_params needs at least one tree")
    if weights is None:
        weights = [1.0 / len(trees)] * len(trees)
    if len(weights) != len(trees):
        raise ValueError("len(weights) != len(trees)")
    total = float(sum(weights))

    def avg(*leaves):
        acc = np.zeros(np.shape(leaves[0]), np.float64)
        for w, leaf in zip(weights, leaves):
            acc += (w / total) * np.asarray(leaf, np.float64)
        return acc.astype(np.asarray(leaves[0]).dtype)

    return _tree_map(avg, *trees)


def load_params(root: str, step: int,
                params_extractor: Callable = params_from_checkpoint) -> Any:
    state, _ = ckpt.restore(root, step)
    return params_extractor(state)


def uniform_soup(root: str, steps: Sequence[int],
                 params_extractor: Callable = params_from_checkpoint) -> Any:
    return average_params([load_params(root, s, params_extractor)
                           for s in steps])


def greedy_soup(root: str, ranked_steps: Sequence[int],
                score_fn: Callable[[Any], float], *, mode: str = "max",
                params_extractor: Callable = params_from_checkpoint,
                ) -> Tuple[Any, List[int], float]:
    """Metric-guided soup over ``ranked_steps`` (best single first).

    ``score_fn(params) -> float`` must be the SAME scoring the selector
    ranked by (e.g. ``pipeline.validate_params(p).metrics[m]``) for the
    >= best-single guarantee to be meaningful.  Returns
    ``(params, member_steps, score)``."""
    if not ranked_steps:
        raise ValueError("greedy_soup needs at least one ranked step")
    better = (lambda a, b: a >= b) if mode == "max" else (lambda a, b: a <= b)
    members = [ranked_steps[0]]
    trees = [load_params(root, ranked_steps[0], params_extractor)]
    params = trees[0]
    score = float(score_fn(params))
    for step in ranked_steps[1:]:
        cand_trees = trees + [load_params(root, step, params_extractor)]
        cand = average_params(cand_trees)
        cand_score = float(score_fn(cand))
        if better(cand_score, score):
            members.append(step)
            trees = cand_trees
            params, score = cand, cand_score
    return params, members, score


def materialize_virtual(root: str, params: Any, *, members: Sequence[int],
                        step: Optional[int] = None,
                        extra: Optional[dict] = None) -> int:
    """Two-phase-commit the soup as a regular checkpoint; returns its step.

    Default step id is ``max(committed) + 1`` so the virtual checkpoint
    appears as the newest — the watcher discovers it like any other and the
    ledger records its re-validation."""
    if step is None:
        steps = ckpt.list_steps(root)
        step = (max(steps) + 1) if steps else 0
    # "virtual" marks a checkpoint with no optimizer/training state: the
    # trainer must not resume from it (Trainer.__init__ skips these).
    meta = {"step": step, "virtual": True,
            VIRTUAL_KEY: [int(s) for s in members], **(extra or {})}
    ckpt.save(root, step, {"params": params, "opt_state": {}}, extra=meta)
    return step
