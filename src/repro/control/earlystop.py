"""Early stopping: patience + history-based overfit detection + STOP marker.

The paper's stated purpose for async validation is to "avoid over-fitting
and determine when the model has converged so as to stop training" — this
module is that verdict.  Two detectors, both pure functions of the observed
(step, validation value[, train loss]) sequence:

  * plateau  — classic patience/min-delta: stop after ``patience``
    consecutive evaluations without an improvement of at least ``min_delta``
    over the best seen.
  * overfit  — history-based (Li et al. 2024, "Keeping Deep Learning Models
    in Check"): over a sliding window of the last ``overfit_window``
    evaluations, the validation metric trends *worse* while the train loss
    still trends *down* — the train-vs-validation gap is widening, the
    classic overfit signature that naive patience can miss (a slow bleed
    never trips min_delta).  Trends are least-squares slopes, so a single
    noisy evaluation cannot trigger it.

The verdict is published as an atomic ``STOP`` marker file (tmp + fsync +
rename, same discipline as checkpoint commit): the trainer polls for the
marker between steps and halts — training stops *asynchronously*, it never
blocks on (or even knows about) validation.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.control.events import ControlEventLog
from repro.control.metricspec import MetricSpec

STOP_MARKER = "STOP"


@dataclasses.dataclass(frozen=True)
class EarlyStopConfig:
    metric: str = "MRR@10"         # a composite spec: "m", "task:m", or a
                                   # weighted "w1*task:m + ..." aggregate
    mode: str = "max"              # max | min (is bigger better?)
    patience: int = 3              # evaluations without improvement
    min_delta: float = 0.0         # improvement below this is noise
    overfit_window: int = 0        # >= 3 enables the overfit detector
    overfit_min_slope: float = 0.0  # val must worsen faster than this/eval

    def __post_init__(self):
        if self.mode not in ("max", "min"):
            raise ValueError(f"mode must be max|min, got {self.mode!r}")
        if self.patience < 1:
            raise ValueError("patience must be >= 1")
        if self.overfit_window == 1 or self.overfit_window == 2:
            raise ValueError("overfit_window needs >= 3 points for a trend")
        MetricSpec.parse(self.metric)          # fail fast on a bad spec


def _slope(ys: List[float]) -> float:
    """Least-squares slope of ys against 0..n-1 (n >= 2)."""
    n = len(ys)
    xm = (n - 1) / 2.0
    ym = sum(ys) / n
    num = sum((i - xm) * (y - ym) for i, y in enumerate(ys))
    den = sum((i - xm) ** 2 for i in range(n))
    return num / den


def write_stop_marker(path: str, verdict: dict) -> None:
    """Atomically publish the stop verdict (tmp + fsync + rename)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(verdict, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def stop_requested(path: Optional[str]) -> Optional[dict]:
    """The trainer-side poll: verdict dict if a STOP marker exists."""
    if not path or not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {"reason": "unreadable_marker"}


class EarlyStopController:
    def __init__(self, cfg: EarlyStopConfig, *,
                 stop_path: Optional[str] = None,
                 event_log: Optional[ControlEventLog] = None):
        self.cfg = cfg
        self.spec = MetricSpec.parse(cfg.metric)
        self.stop_path = stop_path
        self.events = event_log if event_log is not None else ControlEventLog()
        self.best: Optional[float] = None
        self.best_step: Optional[int] = None
        self.bad_evals = 0
        self.stopped = False
        self.reason: Optional[str] = None
        self.stop_step: Optional[int] = None
        # (step, val value, train loss or None), observation order
        self._history: List[Tuple[int, float, Optional[float]]] = []

    # -- detectors ----------------------------------------------------------
    def _improved(self, value: float) -> bool:
        if self.best is None:
            return True
        if self.cfg.mode == "max":
            return value > self.best + self.cfg.min_delta
        return value < self.best - self.cfg.min_delta

    def _overfit(self) -> bool:
        w = self.cfg.overfit_window
        if w < 3 or len(self._history) < w:
            return False
        window = self._history[-w:]
        trains = [t for _, _, t in window]
        if any(t is None for t in trains):
            return False                      # gap undefined without train loss
        vals = [v for _, v, _ in window]
        val_slope = _slope(vals)
        train_slope = _slope([float(t) for t in trains])
        worsening = (val_slope < -self.cfg.overfit_min_slope
                     if self.cfg.mode == "max"
                     else val_slope > self.cfg.overfit_min_slope)
        return worsening and train_slope <= 0.0

    # -- ingestion ----------------------------------------------------------
    def observe(self, step: int, metrics: Dict[str, float],
                train_loss: Optional[float] = None) -> bool:
        """Fold one validation row in; returns the (latched) stop verdict."""
        value = self.spec.value(metrics)
        self._history.append((step, value,
                              None if train_loss is None
                              else float(train_loss)))
        if self.stopped:                       # latched: drain-time rows
            return True                        # cannot un-stop training
        if self._improved(value):
            self.best, self.best_step = value, step
            self.bad_evals = 0
        else:
            self.bad_evals += 1
        reason = None
        if self._overfit():
            reason = "overfit"
        elif self.bad_evals >= self.cfg.patience:
            reason = "plateau"
        if reason is not None:
            self._trigger(step, reason)
        return self.stopped

    def _trigger(self, step: int, reason: str) -> None:
        self.stopped = True
        self.reason = reason
        self.stop_step = step
        verdict = {"reason": reason, "step": step,
                   "metric": self.cfg.metric, "best_step": self.best_step,
                   "best_value": self.best, "bad_evals": self.bad_evals}
        self.events.emit("stop", step,
                         **{k: v for k, v in verdict.items() if k != "step"})
        if self.stop_path:
            write_stop_marker(self.stop_path, verdict)
            self.events.emit("stop_marker", step, path=self.stop_path)
