"""Composite control-metric specs: ``"metric"``, ``"task:metric"``, or a
weighted aggregate — how the control plane consumes a multi-task suite.

The validator's flat metric dict (see
:class:`repro.core.suite.SuiteResult`) keys every value twice: bare
(``"MRR@10"``, the ``default`` task only — v1 ledger compatibility) and
task-qualified (``"dev:MRR@10"``).  A spec addresses either, or combines
several::

    "MRR@10"                              # single metric (v1 behaviour)
    "dev:MRR@10"                          # one task of a suite
    "0.5*dev:MRR@10 + 0.5*heldout:MRR@10" # weighted aggregate (Cho et al.
                                          # 2022: select checkpoints that
                                          # transfer across validation sets)

Grammar: ``spec := term ("+" term)*``, ``term := [weight "*"] key``.
Weights are floats (negative allowed, so a ``min`` series can contribute to
a ``max`` aggregate).  Parsing is eager and errors list what went wrong;
evaluation errors list the metric keys actually available, so a typo'd
task or metric fails loudly at the first observation, not as a silent
no-op."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    raw: str
    terms: Tuple[Tuple[float, str], ...]      # ((weight, key), ...)

    @classmethod
    def parse(cls, spec: str) -> "MetricSpec":
        if not isinstance(spec, str) or not spec.strip():
            raise ValueError(f"metric spec must be a non-empty string, "
                             f"got {spec!r}")
        terms: List[Tuple[float, str]] = []
        for part in spec.split("+"):
            part = part.strip()
            if not part:
                raise ValueError(f"empty term in metric spec {spec!r}")
            if "*" in part:
                w_s, key = part.split("*", 1)
                try:
                    w = float(w_s.strip())
                except ValueError:
                    raise ValueError(f"bad weight {w_s.strip()!r} in metric "
                                     f"spec {spec!r}") from None
            else:
                w, key = 1.0, part
            key = key.strip()
            if not key:
                raise ValueError(f"empty metric key in spec {spec!r}")
            terms.append((w, key))
        return cls(raw=spec, terms=tuple(terms))

    @property
    def composite(self) -> bool:
        return len(self.terms) > 1 or self.terms[0][0] != 1.0

    def keys(self) -> List[str]:
        return [k for _, k in self.terms]

    def _lookup(self, key: str, metrics: Dict[str, float]) -> float:
        try:
            return float(metrics[key])
        except KeyError:
            raise KeyError(
                f"metric {key!r} (from control spec {self.raw!r}) is not in "
                f"this run's metrics {sorted(metrics)}") from None

    def value(self, metrics: Dict[str, float]) -> float:
        """Evaluate against a flat metric dict.  An exact-key hit on the
        whole spec wins first — that is how the control plane overrides a
        composite series with its EMA-smoothed value."""
        if self.raw in metrics:
            return float(metrics[self.raw])
        return sum(w * self._lookup(k, metrics) for w, k in self.terms)


def flatten_rows(rows, expected_tasks=None, with_context=False,
                 group="consecutive"):
    """Group per-(step, task) ledger rows back into per-step flat metric
    dicts — the observation stream the control plane consumed online.

    ``group="consecutive"`` (single-validator ledgers): a suite records
    every task's row for a step consecutively, so CONSECUTIVE rows with the
    same step form one observation (two visits to the same step at
    different times stay two observations, preserving decision order).
    Schema-v1 rows (no ``"task"``) are the ``default`` task, whose metrics
    keep their bare names — a v1 ledger replays byte-identically to its
    pre-suite decisions.

    ``group="completion"`` (fleet ledgers): N workers interleave rows of
    DIFFERENT steps, so consecutive grouping would shred observations.
    Rows accumulate per step instead, and the observation is emitted at the
    position of the row that COMPLETES the expected task set — exactly when
    the online fleet supervisor fed it to the controller, so online and
    replayed decision sequences match byte-for-byte.  Requires
    ``expected_tasks``; rows left incomplete (in flight, or crash-torn) are
    dropped.

    ``expected_tasks`` (the suite's task names) drops observations missing
    any expected task's row: a partially-recorded step (crash between a
    suite's task rows) was never observed by the online controller, so
    replaying it — even when the surviving rows happen to satisfy the
    metric spec — would diverge EMA/patience/ranking state from the
    crash-free run.  The step re-validates and re-records in full.

    ``with_context=True`` returns ``(step, flat, context)`` triples, where
    ``context`` is the provenance payload the online controller attached to
    its events (``{"engine", "score_dtype"}`` — plus ``"worker_id"`` when
    the rows carry fleet attribution — joined across the group's rows
    exactly like :class:`~repro.core.suite.SuiteResult` joins them) — or
    ``None`` when no row in the group carries any of those keys, so
    replaying a pre-provenance ledger emits byte-identical events."""
    out: List[Tuple[int, Dict[str, float], set, list]] = []

    def absorb(bucket, row):
        _, flat, tasks, raws = bucket
        task = str(row.get("task", "default"))
        tasks.add(task)
        raws.append(row)
        for m, v in row.get("metrics", {}).items():
            if task == "default":
                flat[m] = v
            flat[f"{task}:{m}"] = v

    if group == "consecutive":
        for row in rows:
            if "kind" in row:       # fleet claim records (workqueue schema)
                continue            # are not observations
            step = int(row["step"])
            if not out or out[-1][0] != step:
                out.append((step, {}, set(), []))
            absorb(out[-1], row)
        if expected_tasks is not None:
            expected = set(expected_tasks)
            out = [g for g in out if expected <= g[2]]
    elif group == "completion":
        if expected_tasks is None:
            raise ValueError(
                "group='completion' needs expected_tasks: completion of a "
                "step is defined by the suite's task set")
        expected = set(expected_tasks)
        acc: Dict[int, tuple] = {}          # step -> in-flight bucket
        for row in rows:
            if "kind" in row:
                continue
            step = int(row["step"])
            bucket = acc.setdefault(step, (step, {}, set(), []))
            absorb(bucket, row)
            if expected <= bucket[2]:
                # this row completed the step: the observation lands HERE,
                # in completion order; a later re-validation of the step
                # starts a fresh bucket (a second observation, like the
                # consecutive path's re-record handling)
                out.append(acc.pop(step))
    else:
        raise ValueError(f"unknown grouping {group!r} "
                         "(consecutive | completion)")
    if not with_context:
        return [(step, flat) for step, flat, _, _ in out]

    def join(values: set) -> str:
        return values.pop() if len(values) == 1 else ",".join(sorted(values))

    result = []
    for step, flat, _, raws in out:
        ctx = None
        if any("engine" in r or "score_dtype" in r or "worker_id" in r
               for r in raws):
            ctx = {"engine": join({str(r.get("engine", "")) for r in raws}),
                   "score_dtype": join({str(r.get("score_dtype", "f32"))
                                        for r in raws})}
            if any("worker_id" in r for r in raws):
                # fleet attribution: absent from pre-fleet rows, so ledgers
                # without it keep emitting byte-identical events
                ctx["worker_id"] = join({str(r.get("worker_id", ""))
                                         for r in raws})
            if any("handoff" in r for r in raws):
                # hand-off provenance: ledgered only on snapshot-scored
                # rows, so pre-handoff ledgers replay byte-identically
                ctx["handoff"] = join({str(r.get("handoff", "durable"))
                                       for r in raws})
        result.append((step, flat, ctx))
    return result


def metric_mode(spec: str) -> str:
    """``"min"`` when every term is an AverageRank-style lower-is-better
    series, else ``"max"`` (weighted aggregates mixing directions flip signs
    via negative weights instead)."""
    parsed = spec if isinstance(spec, MetricSpec) else MetricSpec.parse(spec)
    def base(key: str) -> str:
        return key.rsplit(":", 1)[-1]
    return "min" if all(base(k).lower().startswith("averagerank")
                        for k in parsed.keys()) else "max"
