"""Control-plane event ledger — every controller decision as a JSONL row.

The validation ledger records *what happened* (metrics per checkpoint); this
log records *what the control plane decided about it* (rankings, stop
verdicts, retention sets, ensemble builds).  Two properties matter:

  * durability — each event is flushed + fsync'd on append, mirroring the
    two-phase-commit discipline of the checkpoint layer, so a crashed
    controller can be audited from disk;
  * determinism — events carry NO wall-clock state.  Decisions are a pure
    function of the validation rows observed (in observation order), so
    replaying a ledger offline reproduces the identical decision sequence
    (tests/test_control_integration.py locks this down).

Events split into two classes:

  * decisions  (``select``, ``stop``) — pure outputs of the controllers;
    byte-identical under offline replay.
  * actuations (``gc``, ``ensemble``, ``stop_marker``, and the serving
    tier's ``swap`` / ``swap_failed``) — side effects on the filesystem or
    the live serving index.  Recorded for audit but excluded from replay
    comparison: they depend on external state (what was committed/
    protected/buildable at that instant).

The serving tier (repro.serve) keeps its swap events in a SEPARATE
ControlEventLog file from the control plane's decisions — the promoter
tails the decision log read-only and appends actuations to its own, so
offline decision replay never has to skip interleaved serve traffic.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Iterator, List, Optional

DECISION_KINDS = frozenset({"select", "stop"})
ACTUATION_KINDS = frozenset({"gc", "ensemble", "stop_marker",
                             # serving tier (repro.serve.promoter): live
                             # index hot-swaps and aborted promotions
                             "swap", "swap_failed"})


@dataclasses.dataclass(frozen=True)
class ControlEvent:
    seq: int
    kind: str
    step: int
    payload: dict

    def to_json(self) -> str:
        return json.dumps({"seq": self.seq, "kind": self.kind,
                           "step": self.step, **self.payload},
                          sort_keys=True)


class ControlEventLog:
    """Append-only, fsync'd, restart-loading event log (thread-safe)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._events: List[ControlEvent] = []
        self._lock = threading.Lock()
        self._torn_offset = None
        if path and os.path.exists(path):
            # same crash discipline as ValidationLedger: a torn FINAL line
            # (emit died mid-write) is dropped on load and truncated by the
            # owning writer just before its next emit — loading never
            # mutates the file; interior corruption raises.
            from repro.core.jsonl import read_jsonl_tolerant
            recs, self._torn_offset = read_jsonl_tolerant(
                path, kind="control event")
            for rec in recs:
                seq, kind, step = (rec.pop("seq"), rec.pop("kind"),
                                   rec.pop("step"))
                self._events.append(ControlEvent(
                    seq=int(seq), kind=kind, step=int(step), payload=rec))

    def emit(self, kind: str, step: int, **payload) -> ControlEvent:
        with self._lock:
            ev = ControlEvent(seq=len(self._events), kind=kind,
                              step=int(step), payload=payload)
            self._events.append(ev)
            if self.path:
                if self._torn_offset is not None:   # writer-side repair
                    from repro.core.jsonl import truncate_torn_tail
                    truncate_torn_tail(self.path, self._torn_offset)
                    self._torn_offset = None
                with open(self.path, "a") as f:
                    f.write(ev.to_json() + "\n")
                    f.flush()
                    os.fsync(f.fileno())
            return ev

    def events(self) -> List[ControlEvent]:
        with self._lock:
            return list(self._events)

    def decisions(self) -> List[ControlEvent]:
        """Replay-comparable subset: pure decisions, renumbered densely so
        interleaved actuations (absent offline) don't shift the seq ids."""
        out = []
        for ev in self.events():
            if ev.kind in DECISION_KINDS:
                out.append(dataclasses.replace(ev, seq=len(out)))
        return out

    def __iter__(self) -> Iterator[ControlEvent]:
        return iter(self.events())

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)
