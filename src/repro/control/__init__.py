"""Convergence control plane: the feedback half of Asyncval.

Consumes the validation ledger that the async validator produces and closes
the loop — checkpoint selection + quality-aware retention (``selection``),
asynchronous early stopping via an atomic STOP marker (``earlystop``),
checkpoint-ensemble virtual checkpoints (``ensemble``) — with every decision
recorded as a replayable JSONL event (``events``).  ``plane.ControlPlane``
bundles them behind the ``AsyncValidator(controller=...)`` hook.
"""

from repro.control.earlystop import (EarlyStopConfig, EarlyStopController,
                                     stop_requested, write_stop_marker)
from repro.control.ensemble import (average_params, greedy_soup,
                                    materialize_virtual, uniform_soup)
from repro.control.events import (ACTUATION_KINDS, DECISION_KINDS,
                                  ControlEvent, ControlEventLog)
from repro.control.metricspec import MetricSpec, flatten_rows, metric_mode
from repro.control.plane import ControlConfig, ControlPlane, replay_ledger
from repro.control.selection import CheckpointSelector, SelectionConfig

__all__ = [
    "ACTUATION_KINDS", "DECISION_KINDS", "ControlEvent", "ControlEventLog",
    "CheckpointSelector", "SelectionConfig",
    "EarlyStopConfig", "EarlyStopController", "stop_requested",
    "write_stop_marker",
    "average_params", "greedy_soup", "materialize_virtual", "uniform_soup",
    "ControlConfig", "ControlPlane", "replay_ledger",
    "MetricSpec", "flatten_rows", "metric_mode",
]
