"""JAX version portability shims for the sharding API surface.

The codebase targets the current ``jax.shard_map`` / ``jax.make_mesh``
API; older JAX releases ship ``shard_map`` under ``jax.experimental``,
call the replication checker ``check_rep`` instead of ``check_vma``, and
have no ``axis_types`` argument.  Everything mesh-related routes through
here so call sites stay on the modern spelling.
"""

from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across JAX versions (``check`` = check_vma/check_rep)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    try:
        auto = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=auto)
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)
