"""Logical-axis -> PartitionSpec rules engine (GSPMD layout planning).

Every parameter in this codebase is born with *logical axis names* (see
``repro.models.nn.Param``); a :class:`Rules` table maps those names onto mesh
axes.  The engine is shape-aware:

  * **divisibility** — a mesh axis is only applied if it divides the dim;
    otherwise the dim falls back to the next candidate (or replication).
    This is what lets e.g. BERT's vocab=30522 coexist with a 16-way model
    axis without per-arch special cases.
  * **conflict dedup** — a mesh axis may appear at most once per array spec
    (PartitionSpec invariant); the first (leftmost) logical axis that claims
    it wins.  E.g. MoE ``("expert", "embed", "mlp")`` with expert->model,
    mlp->model resolves to EP on experts, mlp replicated.
  * **stacked layers** — arrays whose ndim exceeds their logical rank carry
    leading stack dims (scan-over-layers); those are never sharded.

The rule tables below implement DESIGN.md §2.5.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import nn

# a rule value: mesh axis name, tuple of mesh axes (joint sharding), a
# priority list of candidates tried in order, or None (replicate).
AxisRule = Union[None, str, Tuple[str, ...], Sequence[Union[str, Tuple[str, ...]]]]


@dataclasses.dataclass
class Rules:
    table: Dict[str, AxisRule]
    default: AxisRule = None

    def candidates(self, logical: str):
        """Normalized list of candidate mesh-axis assignments for one dim."""
        rule = self.table.get(logical, self.default)
        if rule is None:
            return []
        if isinstance(rule, str):
            return [rule]
        if isinstance(rule, tuple):
            return [rule]
        return list(rule)  # priority list


def _axis_size(mesh: Mesh, assignment: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(assignment, str):
        return mesh.shape[assignment]
    size = 1
    for a in assignment:
        size *= mesh.shape[a]
    return size


def spec_for(shape: Tuple[int, ...], axes: Tuple[str, ...], rules: Rules,
             mesh: Mesh) -> P:
    """PartitionSpec for one array given its logical axes.

    ``len(axes)`` may be smaller than ``len(shape)``: the extra *leading*
    dims are scan stacks and stay unsharded.
    """
    n_stack = len(shape) - len(axes)
    assert n_stack >= 0, f"rank {len(shape)} < logical rank {len(axes)}"
    entries: list = [None] * n_stack
    used: set = set()
    for dim, logical in zip(shape[n_stack:], axes):
        chosen = None
        for cand in rules.candidates(logical):
            flat = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a in used for a in flat):
                continue
            if dim % _axis_size(mesh, cand) != 0:
                continue
            chosen = cand
            used.update(flat)
            break
        entries.append(chosen)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(abstract_params: Any, axes_tree: Any, rules: Rules,
                   mesh: Mesh) -> Any:
    """NamedSharding pytree parallel to ``abstract_params``.

    ``abstract_params``: ShapeDtypeStructs (from ``nn.abstract_init``);
    ``axes_tree``: the matching logical-axes pytree.
    """
    def one(leaf, axes):
        return NamedSharding(mesh, spec_for(tuple(leaf.shape), tuple(axes),
                                            rules, mesh))
    return jax.tree_util.tree_map(one, abstract_params, axes_tree,
                                  is_leaf=lambda x: isinstance(x, tuple))


def opt_state_shardings(abstract_opt_state: Any, abstract_params: Any,
                        param_shardings: Any, mesh: Mesh) -> Any:
    """Shardings for optimizer state by *shape matching* against params.

    Works for any optimizer whose slots mirror the param tree:
      * same-shape slots (Adam m/v, error-feedback buffers) inherit the
        param's spec;
      * factored slots (Adafactor vr/vc: param shape minus one dim) inherit
        the spec with the dropped dim removed;
      * anything else (step counters, scalars) is replicated.
    """
    flat_p = jax.tree_util.tree_leaves(abstract_params)
    flat_s = jax.tree_util.tree_leaves(param_shardings)
    by_shape: Dict[Tuple[int, ...], NamedSharding] = {}
    for p, s in zip(flat_p, flat_s):
        by_shape.setdefault(tuple(p.shape), s)

    # factored lookup: map "param shape minus dim d" -> spec minus entry d
    factored: Dict[Tuple[int, ...], NamedSharding] = {}
    for p, s in zip(flat_p, flat_s):
        shape = tuple(p.shape)
        if len(shape) < 2:
            continue
        spec = list(s.spec) + [None] * (len(shape) - len(s.spec))
        for d in (len(shape) - 1, len(shape) - 2):   # adafactor drops -1 / -2
            red = shape[:d] + shape[d + 1:]
            rspec = spec[:d] + spec[d + 1:]
            while rspec and rspec[-1] is None:
                rspec.pop()
            factored.setdefault(red, NamedSharding(mesh, P(*rspec)))

    replicated = NamedSharding(mesh, P())

    def one(leaf):
        shape = tuple(leaf.shape)
        if shape in by_shape:
            return by_shape[shape]
        if shape in factored:
            return factored[shape]
        return replicated

    return jax.tree_util.tree_map(one, abstract_opt_state)


# ---------------------------------------------------------------------------
# Rule tables (DESIGN.md §2.5)
# ---------------------------------------------------------------------------


def lm_train_rules() -> Rules:
    """2-D sharding: TP on "model" (heads/mlp/vocab/experts), FSDP on "data"."""
    return Rules({
        "embed": ["data", "model"],     # FSDP; fall back to model if data ∤ dim
        "embed2": "data",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": ["model", "data"],
        "expert": "model",              # EP
        "kv_lora": "data",
        "table_rows": [("data", "model"), "data", "model"],
        "gnn_in": "data",
        "gnn_hidden": "model",
        "gnn_out": None,
        "pos": None, "seq": None, "interests": None,
    })


def lm_serve_rules() -> Rules:
    """Inference: 2-D weight sharding — TP on "model" (heads/mlp/vocab/
    experts) plus "data" on the embed dim.  Weights-resident TP-only serving
    (embed replicated) does not fit the 70B+/480B archs on 16 GiB chips
    (measured: arctic decode 176 GiB/device); the 2-D layout trades one
    all-gather per projection for a 16x weight-memory cut — the MaxText
    big-model serving layout."""
    return Rules({
        "embed": "data",
        "embed2": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": ["model", "data"],
        "expert": "model",
        "kv_lora": "data",
        "table_rows": [("data", "model"), "data", "model"],
        "gnn_in": None, "gnn_hidden": "model", "gnn_out": None,
        "pos": None, "seq": None, "interests": None,
    })


def fsdp_only_rules() -> Rules:
    """Pure ZeRO-3 over every mesh axis jointly (validator / encode meshes:
    encoding is data-parallel so weights just need to fit)."""
    return Rules({}, default=[("data", "model"), "data", "model"])


# -- input/activation specs --------------------------------------------------


def rows_sharding(mesh: Mesh,
                  axis_names: Optional[Sequence[str]] = None) -> NamedSharding:
    """NamedSharding splitting an array's leading (rows) dim over the given
    mesh axes (jointly when several).  This is the layout the streaming
    validation engine stages token chunks with (``jax.device_put`` ahead of
    compute) so the ``shard_map`` step's row-sharded ``in_specs`` find the
    buffers already resident — no re-layout or gather at dispatch."""
    axes = tuple(axis_names or mesh.axis_names)
    ax = axes[0] if len(axes) == 1 else axes
    return NamedSharding(mesh, P(ax))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated NamedSharding on ``mesh``.  Used by the sharded
    rerank stage to pin mesh-invariant operands — the ``(Q, Cmax)``
    candidate slot map and the query matrix — onto every device ONCE at
    stage build, so the per-chunk ``shard_map`` step's replicated
    ``in_specs`` find them resident instead of re-broadcasting each
    dispatch."""
    return NamedSharding(mesh, P())


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes used for data parallelism ("pod" joins "data" if present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def lm_batch_spec(mesh: Mesh, global_batch: int) -> P:
    dp = batch_axes(mesh)
    if global_batch % _axis_size(mesh, dp) == 0:
        return P(dp)
    if global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P()


def cache_spec(mesh: Mesh, cache_shape: Tuple[int, ...], batch: int,
               *, seq_dim: int = 2, batch_dim: int = 1) -> P:
    """KV-cache layout: batch on DP axes, sequence on "model" (GQA kv-head
    counts don't divide 16); batch=1 long-context shards sequence over
    every axis (DESIGN.md §2.4)."""
    dp = batch_axes(mesh)
    entries: list = [None] * len(cache_shape)
    T = cache_shape[seq_dim]
    if batch % _axis_size(mesh, dp) == 0:
        entries[batch_dim] = dp
        if T % mesh.shape["model"] == 0:
            entries[seq_dim] = "model"
    else:
        # batch unshardable -> give the sequence the whole mesh
        all_ax = tuple(a for a in ("pod", "data", "model")
                       if a in mesh.axis_names)
        if T % _axis_size(mesh, all_ax) == 0:
            entries[seq_dim] = all_ax
        elif T % mesh.shape["model"] == 0:
            entries[seq_dim] = "model"
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)
