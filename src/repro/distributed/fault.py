"""Fault-tolerance & straggler-mitigation primitives.

At 1000+ nodes the validator's corpus-encode is a bag-of-tasks over chunk
workers; stragglers (slow hosts, pre-emptions) dominate tail latency.  The
classic mitigation (MapReduce "backup tasks") is:

  * **over-decomposition** — split the corpus into ~``over_factor`` x more
    chunks than workers so no worker owns a big indivisible slice;
  * **dynamic work queue** — workers pull, never pre-assigned;
  * **speculative re-execution** — when the queue drains, idle workers
    duplicate the slowest in-flight chunks; first result wins
    (deterministic: both executions produce identical embeddings).

The queue is also the *elasticity* point: workers may join/leave between
chunk pulls (the validator mesh can grow/shrink across checkpoints).

On this box workers are threads; in production each worker is a pod slice
driving its own pjit'd encode step — the scheduling logic is identical.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence


@dataclasses.dataclass
class Chunk:
    chunk_id: int
    payload: Any


@dataclasses.dataclass
class ChunkResult:
    chunk_id: int
    value: Any
    worker: str
    duration_s: float
    speculative: bool = False


def make_chunks(items: Sequence[Any], n_workers: int,
                over_factor: int = 4) -> List[Chunk]:
    """Over-decompose ``items`` into ~n_workers*over_factor chunks."""
    n = len(items)
    n_chunks = max(1, min(n, n_workers * over_factor))
    size = -(-n // n_chunks)
    return [Chunk(i, items[s:s + size])
            for i, s in enumerate(range(0, n, size))]


class WorkQueue:
    """Thread-safe dynamic queue with speculative duplicate execution."""

    def __init__(self, chunks: Sequence[Chunk], *, speculate: bool = True,
                 max_attempts: int = 3):
        self._lock = threading.Lock()
        self._pending: List[Chunk] = list(chunks)
        self._inflight: Dict[int, Dict[str, float]] = {}   # id -> {worker: t0}
        self._done: Dict[int, ChunkResult] = {}
        self._failures: Dict[int, int] = {}
        self._chunk_by_id = {c.chunk_id: c for c in chunks}
        self._total = len(chunks)
        self.speculate = speculate
        self.max_attempts = max_attempts

    # -- worker API ----------------------------------------------------------
    def acquire(self, worker: str) -> Optional[Chunk]:
        """Next chunk for ``worker``; a speculative duplicate of the oldest
        in-flight chunk when the primary queue is drained; None when done."""
        with self._lock:
            if self._pending:
                c = self._pending.pop(0)
                self._inflight.setdefault(c.chunk_id, {})[worker] = time.time()
                return c
            if self.speculate:
                # duplicate the longest-running chunk this worker isn't on
                cands = [(min(ts.values()), cid)
                         for cid, ts in self._inflight.items()
                         if cid not in self._done and worker not in ts]
                if cands:
                    _, cid = min(cands)
                    self._inflight[cid][worker] = time.time()
                    return Chunk(cid, self._chunk_by_id[cid].payload)
            return None

    def complete(self, worker: str, chunk_id: int, value: Any) -> bool:
        """Record a result. Returns True iff this execution 'won' (first)."""
        with self._lock:
            t0 = self._inflight.get(chunk_id, {}).get(worker, time.time())
            if chunk_id in self._done:
                self._inflight.get(chunk_id, {}).pop(worker, None)
                return False
            spec = len(self._inflight.get(chunk_id, {})) > 1
            self._done[chunk_id] = ChunkResult(
                chunk_id, value, worker, time.time() - t0, speculative=spec)
            self._inflight.pop(chunk_id, None)
            return True

    def fail(self, worker: str, chunk_id: int, err: Any = None) -> None:
        """Worker died / raised: requeue unless the chunk already completed
        or exceeded max_attempts (then it surfaces via ``failed_chunks``)."""
        with self._lock:
            self._inflight.get(chunk_id, {}).pop(worker, None)
            if chunk_id in self._done:
                return
            self._failures[chunk_id] = self._failures.get(chunk_id, 0) + 1
            if self._failures[chunk_id] < self.max_attempts \
                    and not self._inflight.get(chunk_id):
                self._pending.append(self._chunk_by_id[chunk_id])

    # -- status ----------------------------------------------------------------
    @property
    def finished(self) -> bool:
        with self._lock:
            return len(self._done) == self._total

    @property
    def failed_chunks(self) -> List[int]:
        with self._lock:
            return [cid for cid, n in self._failures.items()
                    if n >= self.max_attempts and cid not in self._done]

    def results(self) -> List[ChunkResult]:
        with self._lock:
            return [self._done[cid] for cid in sorted(self._done)]


def run_chunked(items: Sequence[Any], fn: Callable[[Any], Any], *,
                n_workers: int = 4, over_factor: int = 4,
                speculate: bool = True,
                worker_delay: Optional[Callable[[str], float]] = None,
                fail_once: Sequence[int] = ()) -> List[Any]:
    """Execute ``fn(chunk.payload)`` over all chunks with the full straggler/
    fault machinery; returns per-chunk values in chunk order.

    ``worker_delay``/``fail_once`` are test hooks simulating slow and crashing
    workers (chunk ids in ``fail_once`` raise on their first execution).
    """
    chunks = make_chunks(items, n_workers, over_factor)
    queue = WorkQueue(chunks, speculate=speculate)
    failed_once = set()
    errors: List[BaseException] = []

    def worker(name: str):
        while True:
            c = queue.acquire(name)
            if c is None:
                if queue.finished or queue.failed_chunks or errors:
                    return
                time.sleep(0.001)
                continue
            try:
                if worker_delay is not None:
                    time.sleep(worker_delay(name))
                if c.chunk_id in fail_once and c.chunk_id not in failed_once:
                    failed_once.add(c.chunk_id)
                    raise RuntimeError(f"injected failure on {c.chunk_id}")
                queue.complete(name, c.chunk_id, fn(c.payload))
            except BaseException as e:
                if isinstance(e, RuntimeError) and "injected" in str(e):
                    queue.fail(name, c.chunk_id, e)
                else:
                    errors.append(e)
                    queue.fail(name, c.chunk_id, e)

    threads = [threading.Thread(target=worker, args=(f"w{i}",))
               for i in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    if queue.failed_chunks:
        raise RuntimeError(f"chunks failed permanently: {queue.failed_chunks}")
    return [r.value for r in queue.results()]
