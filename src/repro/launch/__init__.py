from repro.launch import mesh  # noqa: F401
