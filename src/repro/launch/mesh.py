"""Production mesh construction (functions only — importing this module
never touches jax device state).

Production target: TPU v5e pods, 256 chips each (16 x 16).  The multi-pod
mesh adds a leading "pod" axis (2 pods = 512 chips): DP spans
("pod", "data"), TP/EP stays intra-pod on "model" (ICI-only; the pod axis
crosses DCN, which only sees data-parallel gradient reduction — the
standard multi-pod layout).

Asyncval deployment note (DESIGN.md §2.1): training and validation are
*disaggregated* — ``make_disaggregated_meshes`` splits the device set so
pod 0 trains while pod 1 validates; the checkpoint directory is the only
coupling between them.
"""

from __future__ import annotations

import jax

from repro.distributed import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_validator_mesh(n_devices: int | None = None, *, model_axis: int = 1):
    """Elastic validator mesh: any device count (corpus encoding is purely
    data-parallel, so the validator defaults to model=1)."""
    devices = jax.devices()
    n = n_devices or len(devices)
    assert n % model_axis == 0
    return jax.sharding.Mesh(
        __import__("numpy").asarray(devices[:n]).reshape(n // model_axis,
                                                         model_axis),
        ("data", "model"))


def make_disaggregated_meshes():
    """(train_mesh, validator_mesh) over disjoint halves of the device set —
    the Asyncval deployment: pod 0 trains, pod 1 validates."""
    devices = jax.devices()
    n = len(devices)
    assert n >= 2, "disaggregation needs >= 2 devices"
    half = n // 2
    import numpy as np
    train = jax.sharding.Mesh(np.asarray(devices[:half]).reshape(half, 1),
                              ("data", "model"))
    val = jax.sharding.Mesh(np.asarray(devices[half:2 * half]).reshape(half, 1),
                            ("data", "model"))
    return train, val
