"""Validator fleet launcher — N workers draining one ledger work queue.

Asyncval moves validation onto "another GPU"; the fleet moves it onto N of
them.  Every shared decision flows through the ledger file (see
``repro.core.workqueue`` for the claim-record schema): workers claim
(step, task) units, the supervisor publishes discovered checkpoints and
feeds completed steps to the control plane, and everything is replayable
offline because no decision ever reads a wall clock.

Two pieces:

  * :class:`FleetSupervisor` — the in-process coordination loop: watches
    the checkpoint root, publishes each committed step's work units, pumps
    completion-grouped observations into a :class:`ControlPlane`, and runs
    claim-aware quality GC (a checkpoint under a live lease is NEVER
    deleted, whoever holds it).  It can also spawn and supervise local
    worker subprocesses.
  * ``python -m repro.launch.fleet --workers N -- <worker argv...>`` — a
    thin CLI that spawns N copies of a worker command (typically
    ``python -m repro.core.cli --worker ...``) with distinct worker ids
    and restarts crashed ones within a budget.  Heterogeneous fleets (one
    8-device full-corpus worker + one CPU smoke worker) just launch the
    differing commands directly, or through the API.

See ``examples/fleet_validation.py`` for the full walkthrough: 1 trainer +
2 heterogeneous workers + control plane.
"""

from __future__ import annotations

import argparse
import dataclasses
import subprocess
import sys
import time
from typing import Any, Callable, List, Optional, Sequence

from repro.ckpt import checkpoint as ckpt
from repro.control.metricspec import flatten_rows
from repro.core.watcher import CheckpointWatcher, Policy
from repro.core.workqueue import WorkQueue, WorkUnit


@dataclasses.dataclass
class WorkerProc:
    """One supervised local worker subprocess."""
    worker_id: str
    argv: List[str]
    proc: subprocess.Popen
    restarts: int = 0


class LocalWorkerPool:
    """Spawns and supervises local worker subprocesses."""

    def __init__(self):
        self.workers: List[WorkerProc] = []

    def spawn(self, base_argv: Sequence[str], n: int, *,
              id_prefix: str = "worker") -> List[WorkerProc]:
        """Spawn ``n`` workers running ``base_argv`` with distinct
        ``--worker_id``\\ s appended (``repro.core.cli --worker`` reads it;
        custom workers are free to ignore it)."""
        spawned = []
        for i in range(len(self.workers), len(self.workers) + n):
            wid = f"{id_prefix}-{i}"
            argv = list(base_argv) + ["--worker_id", wid]
            wp = WorkerProc(worker_id=wid, argv=argv,
                            proc=subprocess.Popen(argv))
            self.workers.append(wp)
            spawned.append(wp)
        return spawned

    def poll(self, *, max_restarts: int = 0) -> List[WorkerProc]:
        """Reap exited workers; restart crashed ones (rc != 0) within the
        per-worker ``max_restarts`` budget.  A crashed worker's in-flight
        lease simply expires — a surviving peer reclaims the unit, which is
        the fleet's whole crash-tolerance story."""
        restarted = []
        for wp in self.workers:
            rc = wp.proc.poll()
            if rc is None or rc == 0:
                continue
            if wp.restarts < max_restarts:
                wp.restarts += 1
                wp.proc = subprocess.Popen(wp.argv)
                restarted.append(wp)
        return restarted

    def alive(self) -> List[WorkerProc]:
        return [wp for wp in self.workers if wp.proc.poll() is None]

    def shutdown(self, *, timeout_s: float = 10.0) -> List[int]:
        """Terminate every worker; returns their exit codes."""
        for wp in self.workers:
            if wp.proc.poll() is None:
                wp.proc.terminate()
        deadline = time.monotonic() + timeout_s
        codes = []
        for wp in self.workers:
            left = max(0.0, deadline - time.monotonic())
            try:
                codes.append(wp.proc.wait(timeout=left))
            except subprocess.TimeoutExpired:
                wp.proc.kill()
                codes.append(wp.proc.wait())
        return codes


class FleetSupervisor:
    """Publishes work, consumes completions, protects in-flight claims.

    ``plan_units`` maps a committed step to its work units — pass the
    suite's bound :meth:`~repro.core.suite.ValidationSuite.plan_units` so
    unit requirements (``mesh_size`` etc.) match what workers execute; the
    default publishes one requirement-free unit per expected task.

    The supervisor never claims units itself: its queue handle is a
    read-mostly participant whose only appends are unit publications."""

    def __init__(self, ckpt_root: str, ledger_path: str,
                 expected_tasks: Sequence[str], *,
                 control: Any = None,
                 policy: Optional[Policy] = None,
                 plan_units: Optional[Callable[[int],
                                               List[WorkUnit]]] = None,
                 lease_ttl: int = 16, max_abandons: int = 2,
                 extra_protect: Optional[Callable[[], set]] = None,
                 telemetry=None,
                 snapshots: Any = None):
        self.ckpt_root = ckpt_root
        # lazy hand-off spool (repro.handoff.SnapshotSpool): announced
        # snapshot steps publish their units BEFORE the durable COMMIT
        # surfaces via the watcher; workers whose ``snapshots`` source maps
        # the same spool then score from the mmap'd spill.  Publication is
        # keyed (step, task), so the watcher's later discovery of the same
        # step collapses in the fold — first route wins, exactly once.
        self.snapshots = snapshots
        # GC protections beyond fleet state — e.g. the serving tier's
        # Promoter.protect_set (live + mid-promotion checkpoint steps)
        self.extra_protect = extra_protect
        self.expected_tasks = tuple(expected_tasks) or ("default",)
        self.control = control
        # observation only: discovery lag + published/discovered lifecycle
        # events and the fold's fleet.* counter mirrors (see repro.obs)
        self.telemetry = telemetry
        self.queue = WorkQueue(ledger_path, "supervisor",
                               lease_ttl=lease_ttl,
                               max_abandons=max_abandons,
                               telemetry=telemetry)
        self.watcher = CheckpointWatcher(ckpt_root, policy=policy,
                                         telemetry=telemetry)
        self.plan_units = plan_units or (lambda step: [
            WorkUnit.make(step, t) for t in self.expected_tasks])
        self.pool = LocalWorkerPool()
        self._observed = 0          # completion-ordered observations fed

    # -- work publication ---------------------------------------------------
    def publish_pending(self) -> int:
        """Publish every newly announced snapshot's and newly committed
        (policy-selected) step's units.  Idempotent: re-publication after a
        restart — or of a step both routes surface — collapses in the
        fold."""
        n = 0
        if self.snapshots is not None:
            for step in self.snapshots.poll():
                n += len(self.queue.publish(self.plan_units(step),
                                            source="snapshot"))
                # the durable checkpoint may land later; consume its watcher
                # discovery so the policy's skip accounting stays truthful
                self.watcher.mark_seen(step)
        for step in self.watcher.poll():
            n += len(self.queue.publish(self.plan_units(step)))
        return n

    # -- control pump -------------------------------------------------------
    def pump_control(self) -> int:
        """Feed newly COMPLETED steps to the control plane, in completion
        order — the same ``group="completion"`` fold
        ``ControlPlane.replay_ledger`` applies offline, so online and
        replayed decision sequences are byte-identical."""
        if self.control is None:
            return 0
        state = self.queue.refresh()
        obs = flatten_rows(state.result_rows, self.expected_tasks,
                           with_context=True, group="completion")
        fed = 0
        for step, flat, context in obs[self._observed:]:
            self._observed += 1
            try:
                self.control.observe(step, flat, context=context)
            except KeyError:
                continue    # spec metric missing: replay skips identically
            fed += 1
            cfg = self.control.cfg
            if cfg.keep_top_k > 0 and self.control.ckpt_root:
                # durability gate: snapshot-scored evidence defers the
                # irreversible GC until the step's durable commit lands
                self.control.hold_gc_until_durable(
                    step, (context or {}).get("handoff", ""))
                self.control.maybe_gc(self)
        return fed

    def protect_set(self) -> set:
        """Steps GC must keep: committed but not fully validated (minus
        policy skips) — plus anything under a LIVE lease, whichever worker
        holds it: GC'ing a checkpoint mid-restore would turn a peer's
        crash-safe claim into a spurious failure.  ``extra_protect``
        (constructor hook) unions in protections outside the fleet's own
        state — e.g. the checkpoint backing a live serving index."""
        committed = set(ckpt.list_steps(self.ckpt_root))
        state = self.queue.refresh()
        done = {s for s in {u.step for u in
                            (st.unit for st in state.units.values())}
                if state.step_complete(s, self.expected_tasks)}
        protected = committed - done - self.watcher.skipped
        protected |= committed & state.claimed_steps()
        if self.extra_protect is not None:
            protected |= set(self.extra_protect())
        return protected

    def step_complete(self, step: int) -> bool:
        return self.queue.refresh().step_complete(step, self.expected_tasks)

    def run_once(self) -> int:
        """One supervision round: publish, pump, reap workers."""
        self.publish_pending()
        fed = self.pump_control()
        self.poll_workers()
        return fed

    # -- local worker subprocesses (delegated to the pool) -------------------
    @property
    def workers(self) -> List[WorkerProc]:
        return self.pool.workers

    def spawn_workers(self, base_argv: Sequence[str], n: int, *,
                      id_prefix: str = "worker") -> List[WorkerProc]:
        return self.pool.spawn(base_argv, n, id_prefix=id_prefix)

    def poll_workers(self, *, max_restarts: int = 0) -> List[WorkerProc]:
        return self.pool.poll(max_restarts=max_restarts)

    def alive_workers(self) -> List[WorkerProc]:
        return self.pool.alive()

    def shutdown(self, *, timeout_s: float = 10.0) -> List[int]:
        return self.pool.shutdown(timeout_s=timeout_s)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.fleet",
        description="spawn and supervise N local validator workers: "
                    "everything after '--' is the worker command "
                    "(typically 'python -m repro.core.cli --worker ...'); "
                    "each copy gets a distinct --worker_id")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max_restarts", type=int, default=1,
                    help="per-worker restart budget for crashed (rc != 0) "
                         "workers")
    ap.add_argument("--poll_interval", type=float, default=1.0)
    ap.add_argument("worker_argv", nargs=argparse.REMAINDER,
                    help="worker command after '--'")
    args = ap.parse_args(argv)
    base = [a for a in args.worker_argv if a != "--"]
    if not base:
        ap.error("pass the worker command after '--'")
    # supervision only: CLI workers discover + publish units themselves
    # (publication is idempotent), so no ledger path is needed here
    pool = LocalWorkerPool()
    pool.spawn(base, args.workers)
    print(f"[fleet] {args.workers} workers spawned", file=sys.stderr)
    try:
        while pool.alive():
            pool.poll(max_restarts=args.max_restarts)
            time.sleep(args.poll_interval)
    except KeyboardInterrupt:
        pool.shutdown()
    codes = [wp.proc.poll() for wp in pool.workers]
    print(f"[fleet] exit codes: {codes}", file=sys.stderr)
    return 0 if all(c == 0 for c in codes) else 1


if __name__ == "__main__":
    sys.exit(main())
