"""Batched LM generation demo (prefill + decode with KV caches).

Runs a reduced LM config on CPU: batches incoming prompts, prefills the
cache, then decodes greedily.  The same ``prefill``/``decode_step`` entry
points are what the big dry-run cells lower on the production mesh.

This is a transformer-stack demo, NOT the retrieval serving tier — that
is ``python -m repro.launch.serve`` (repro.serve), which serves dense-
retrieval queries against control-plane-promoted checkpoints.

    python -m repro.launch.lm_demo --arch qwen2-0.5b --batch 4 \\
        --prompt-len 16 --gen 24
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import nn
from repro.models import transformer as tfm


def serve_batch(params, cfg, prompts: jnp.ndarray, gen: int):
    """prompts: (B, P) int32 -> generated (B, gen) int32 (greedy)."""
    B, P = prompts.shape
    max_len = P + gen
    logits, caches = jax.jit(
        lambda p, t: tfm.prefill(p, cfg, t, max_len=max_len))(params, prompts)
    step = jax.jit(lambda p, c, t, i: tfm.decode_step(p, cfg, c, t, i))
    tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1).astype(jnp.int32)
    out = [tok]
    for i in range(gen - 1):
        logits, caches = step(params, caches, tok, jnp.asarray(P + i, jnp.int32))
        tok = jnp.argmax(logits[:, 0], axis=-1).reshape(B, 1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get(args.arch).smoke_config()
    params = nn.materialize(tfm.init(jax.random.PRNGKey(args.seed), cfg))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    gen = serve_batch(params, cfg, prompts, args.gen)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"[lm_demo] arch={args.arch} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}: "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(gen[0])[:16])


if __name__ == "__main__":
    main()
