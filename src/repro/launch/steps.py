"""Per-(arch x shape) step construction: abstract inputs + shardings.

``build_step(arch_id, shape_name, mesh)`` returns a :class:`StepSpec` whose
``fn``/``abstract_args``/``in_shardings``/``out_shardings`` feed straight
into ``jax.jit(...).lower(...)`` — the multi-pod dry-run, the roofline
extraction, and the real launchers all consume the same builders.

Variants (DESIGN.md §2.7):
  * ``variant="full"`` — the real configuration (scan-over-layers, remat):
    compile proof + memory analysis.
  * ``variant="cost"``  — layer stacks cut to ``cost_layers`` per stack and
    every inner scan fully unrolled, so ``cost_analysis()`` counts each body
    exactly once per trip; the dry-run extrapolates per-layer costs back to
    full depth.

Nothing in this module allocates device memory: parameters come from
``nn.abstract_init`` (ShapeDtypeStructs), inputs are ShapeDtypeStructs.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding as shd
from repro.models import graphcast as gcast
from repro.models import nn
from repro.models import recsys as rcs
from repro.models import transformer as tfm
from repro.models.biencoder import biencoder_spec, contrastive_loss
from repro.train import optim
from repro.train.trainer import make_train_step

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class StepSpec:
    cell: str
    kind: str                       # train | prefill | decode | serve | retrieval | encode
    fn: Callable
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    meta: Dict[str, Any]


def _repl(mesh):
    return NamedSharding(mesh, P())


def _act_rules(mesh: Mesh, *, sp: bool = False) -> Dict[str, Any]:
    """Logical activation axes -> mesh axes (DESIGN.md §2.5): batch on the
    DP axes, head/mlp/vocab projections on "model", x replicated over
    "model" between blocks (Megatron layout).  Non-divisible dims fall back
    to replication inside ``nn.constrain``.

    ``sp=True`` enables *sequence parallelism*: the residual stream between
    blocks is sharded over "model" on the sequence dim.  This shards the
    per-layer remat carry stack (L x B_loc x S x D bf16 — the dominant
    training buffer; 30.6 GiB/device for arctic-480b without SP, /16 with)
    at the cost of per-layer all-gather/reduce-scatter pairs GSPMD inserts
    around the TP projections — the Megatron-LM SP layout."""
    dp = shd.batch_axes(mesh)
    return {"act_batch": dp, "act_seq": ("model" if sp else None),
            "act_embed": None,
            "act_heads": "model", "act_kv_heads": "model",
            "act_mlp": "model", "act_vocab": "model",
            "act_expert": "model",
            "act_rows": dp + ("model",)}


def _with_act(fn: Callable, mesh: Mesh, rules: Optional[Dict] = None, *,
              sp: bool = False):
    """Wrap a step so tracing happens under the activation-sharding context."""
    rules = rules if rules is not None else _act_rules(mesh, sp=sp)

    def wrapped(*args):
        with nn.activation_sharding(mesh, rules):
            return fn(*args)

    return wrapped


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def _count(tree) -> int:
    return int(sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

# full-config execution knobs used ONLY for the big dry-run configs
# (smoke tests keep dataclass defaults). vocab_chunk keeps the (tokens, V)
# logits tensor off HBM; q_chunk bounds the attention working set.
_LM_DRYRUN_KNOBS = dict(remat=True, q_chunk=512, vocab_chunk=8192)


def _lm_cfg(arch_id: str, *, variant: str, kind: str,
            cost_layers: int = 1) -> tfm.TransformerConfig:
    cfg = registry.get(arch_id).full_config()
    knobs = dict(_LM_DRYRUN_KNOBS)
    if kind != "train":
        knobs["remat"] = False
    if variant == "cost":
        # reduced-depth, fully-unrolled cost-extraction variant
        n_dense = cfg.first_k_dense if cfg.is_moe else cost_layers
        n_moe = cost_layers if cfg.is_moe else 0
        knobs.update(n_layers=n_dense + n_moe,
                     layer_unroll=0, attn_unroll=0, xent_unroll=0)
        if cfg.is_moe and cfg.first_k_dense == 0:
            knobs["n_layers"] = cost_layers           # all-MoE stacks (arctic)
    return dataclasses.replace(cfg, **knobs)


def _lm_active_params(cfg: tfm.TransformerConfig, params_abs) -> Tuple[int, int]:
    """(total, active) parameter counts. Active replaces the routed-expert
    block with top_k experts (MoE forward touches top_k + shared only)."""
    total = _count(params_abs)
    if not cfg.is_moe:
        return total, total
    n_moe_layers = cfg.n_layers - cfg.first_k_dense
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = n_moe_layers * (cfg.moe_num_experts - cfg.moe_top_k) * per_expert
    return total, total - inactive


def _lm_attn_flops(cfg, S_q: int, T_kv: int, batch: int, causal_avg: bool) -> float:
    """QK^T + PV matmul flops for one forward pass."""
    t_eff = (T_kv + 1) / 2 if causal_avg else T_kv
    if cfg.mla:
        d_qk, d_v = cfg.qk_nope_dim + cfg.qk_rope_dim, cfg.v_head_dim
        per = 2 * cfg.n_heads * (d_qk + d_v) * t_eff
    else:
        per = 4 * cfg.n_heads * cfg.head_dim * t_eff
    return cfg.n_layers * batch * S_q * per


def _lm_model_flops(cfg, kind: str, B: int, S: int, params_abs) -> Dict[str, float]:
    total, active = _lm_active_params(cfg, params_abs)
    if kind == "train":
        tokens = B * S
        mf = 6.0 * active * tokens + 3 * _lm_attn_flops(cfg, S, S, B, True)
    elif kind == "prefill":
        tokens = B * S
        mf = 2.0 * active * tokens + _lm_attn_flops(cfg, S, S, B, True)
    else:  # decode: one token against a T=S cache
        tokens = B
        mf = 2.0 * active * tokens + _lm_attn_flops(cfg, 1, S, B, False)
    return {"model_flops": mf, "params": total, "active_params": active,
            "tokens": tokens}


def _lm_abstract_params(cfg, mesh, rules):
    shapes, axes = nn.abstract_init(tfm.init, jax.random.PRNGKey(0), cfg)
    return shapes, shd.tree_shardings(shapes, axes, rules, mesh)


def _make_optimizer(arch_id: str):
    if arch_id == "arctic-480b":        # full Adam state doesn't fit 256 chips
        return optim.adafactor(1e-4), "adafactor"
    return optim.adamw(optim.warmup_cosine(3e-4, 2000, 100_000)), "adamw"


def lm_train_spec(arch_id: str, shape: dict, mesh: Mesh, *,
                  variant: str = "full", cost_layers: int = 1,
                  sp: Optional[bool] = None,
                  cfg_overrides: Optional[Dict[str, Any]] = None) -> StepSpec:
    B, S = shape["global_batch"], shape["seq_len"]
    cfg = _lm_cfg(arch_id, variant=variant, kind="train",
                  cost_layers=cost_layers)
    if cfg_overrides:
        ov = dict(cfg_overrides)
        for key in ("param_dtype", "compute_dtype"):
            if key in ov:
                ov[key] = {"bf16": jnp.bfloat16, "f32": jnp.float32}[ov[key]]
        cfg = dataclasses.replace(cfg, **ov)
    full_cfg = registry.get(arch_id).full_config()
    if sp is None:
        # sequence parallelism on when the remat carry stack would not fit:
        # L x (B/dp) x S x D bf16 against a ~16 GiB HBM budget
        dp = int(np.prod([mesh.shape[a] for a in shd.batch_axes(mesh)]))
        carry_gib = (full_cfg.n_layers * (B // dp) * S * full_cfg.d_model
                     * 2 / 2**30)
        sp = carry_gib > 4.0
    rules = shd.lm_train_rules()
    params_abs, params_sh = _lm_abstract_params(cfg, mesh, rules)
    opt, opt_name = _make_optimizer(arch_id)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    opt_sh = shd.opt_state_shardings(opt_abs, params_abs, params_sh, mesh)

    batch_abs = {"tokens": SDS((B, S), jnp.int32)}
    batch_sh = {"tokens": _named(mesh, shd.lm_batch_spec(mesh, B))}

    step = make_train_step(lambda p, b: tfm.lm_loss(p, cfg, b), opt)
    meta = _lm_model_flops(cfg, "train", B, S, params_abs)
    meta.update(optimizer=opt_name, n_layers=cfg.n_layers, variant=variant,
                sequence_parallel=bool(sp))

    return StepSpec(
        cell=f"{arch_id}/train", kind="train", fn=_with_act(step, mesh, sp=sp),
        abstract_args=(params_abs, opt_abs, batch_abs),
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, _repl(mesh)),
        donate_argnums=(0, 1), meta=meta)


def _serve_params(cfg, mesh, layout: str = "2d"):
    """Serving weights in bf16.

    layout="2d": TP on "model" + "data" on the embed dim (fits 70B+/480B on
    16 GiB chips at the cost of per-layer weight all-gathers — measured to
    dominate decode collectives).
    layout="tp": weights resident per TP group (replicated over "data") —
    zero weight gathers; only valid when params_bf16/TP fits HBM.
    """
    if layout == "tp":
        rules = shd.Rules({
            "embed": None, "embed2": None, "heads": "model",
            "kv_heads": "model", "mlp": "model", "vocab": "model",
            "expert": "model", "kv_lora": None,
            "table_rows": [("data", "model"), "data", "model"],
            "pos": None, "seq": None, "interests": None,
        })
    else:
        rules = shd.lm_serve_rules()
    shapes, axes = nn.abstract_init(tfm.init, jax.random.PRNGKey(0), cfg)
    shapes = jax.tree_util.tree_map(
        lambda s: SDS(s.shape, jnp.bfloat16)
        if jnp.issubdtype(s.dtype, jnp.floating) else s, shapes)
    return shapes, shd.tree_shardings(shapes, axes, rules, mesh)


def lm_prefill_spec(arch_id: str, shape: dict, mesh: Mesh, *,
                    variant: str = "full", cost_layers: int = 1,
                    serve_layout: str = "2d") -> StepSpec:
    B, S = shape["global_batch"], shape["seq_len"]
    cfg = _lm_cfg(arch_id, variant=variant, kind="prefill",
                  cost_layers=cost_layers)
    params_abs, params_sh = _serve_params(cfg, mesh, serve_layout)
    tokens_abs = SDS((B, S), jnp.int32)
    tokens_sh = _named(mesh, shd.lm_batch_spec(mesh, B))

    def prefill_step(params, tokens):
        return tfm.prefill(params, cfg, tokens, max_len=S)

    cache_abs = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, S, dtype=cfg.compute_dtype))
    cache_sh = jax.tree_util.tree_map(
        lambda l: _named(mesh, shd.cache_spec(mesh, l.shape, B)), cache_abs)
    meta = _lm_model_flops(cfg, "prefill", B, S, params_abs)
    meta.update(n_layers=cfg.n_layers, variant=variant)
    return StepSpec(
        cell=f"{arch_id}/prefill", kind="prefill", fn=_with_act(prefill_step, mesh),
        abstract_args=(params_abs, tokens_abs),
        in_shardings=(params_sh, tokens_sh),
        out_shardings=(_named(mesh, shd.lm_batch_spec(mesh, B)), cache_sh),
        donate_argnums=(), meta=meta)


def lm_decode_spec(arch_id: str, shape: dict, mesh: Mesh, *,
                   variant: str = "full", cost_layers: int = 1,
                   serve_layout: str = "2d") -> StepSpec:
    B, T = shape["global_batch"], shape["seq_len"]
    cfg = _lm_cfg(arch_id, variant=variant, kind="decode",
                  cost_layers=cost_layers)
    params_abs, params_sh = _serve_params(cfg, mesh, serve_layout)
    cache_abs = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, T, dtype=cfg.compute_dtype))
    cache_sh = jax.tree_util.tree_map(
        lambda l: _named(mesh, shd.cache_spec(mesh, l.shape, B)), cache_abs)
    tok_abs = SDS((B, 1), jnp.int32)
    tok_sh = _named(mesh, shd.lm_batch_spec(mesh, B))
    idx_abs = SDS((), jnp.int32)

    def decode(params, caches, token, index):
        return tfm.decode_step(params, cfg, caches, token, index)

    meta = _lm_model_flops(cfg, "decode", B, T, params_abs)
    meta.update(n_layers=cfg.n_layers, variant=variant)
    return StepSpec(
        cell=f"{arch_id}/decode", kind="decode", fn=_with_act(decode, mesh),
        abstract_args=(params_abs, cache_abs, tok_abs, idx_abs),
        in_shardings=(params_sh, cache_sh, tok_sh, _repl(mesh)),
        out_shardings=(tok_sh, cache_sh),
        donate_argnums=(1,), meta=meta)


# ---------------------------------------------------------------------------
# GNN family (graphcast)
# ---------------------------------------------------------------------------


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _gnn_shapes(shape: dict, mesh: Mesh) -> Tuple[int, int, int]:
    """(n_nodes, n_edges, d_feat) on device, padded to shard evenly."""
    n_dev = int(np.prod(list(mesh.shape.values())))
    if shape["kind"] == "minibatch":
        b, (f1, f2) = shape["batch_nodes"], shape["fanout"]
        n = b * (1 + f1 + f1 * f2)
        e = b * (f1 + f1 * f2)
        d = 602                              # reddit feature dim
    elif shape["kind"] == "batched_graphs":
        n = shape["batch"] * shape["n_nodes"]
        e = shape["batch"] * shape["n_edges"]
        d = 9                                # molecule atom features
    else:
        n, e, d = shape["n_nodes"], shape["n_edges"], shape.get("d_feat", 128)
    return _pad_to(n, n_dev), _pad_to(e, n_dev), d


def gnn_train_spec(arch_id: str, shape: dict, mesh: Mesh, *,
                   variant: str = "full", cost_layers: int = 1) -> StepSpec:
    cfg = registry.get(arch_id).full_config()
    N, E, d_feat = _gnn_shapes(shape, mesh)
    cfg = dataclasses.replace(cfg, d_feat=d_feat, remat=True)
    if variant == "cost":
        cfg = dataclasses.replace(cfg, n_layers=cost_layers, layer_unroll=0)

    shapes, axes = nn.abstract_init(gcast.init, jax.random.PRNGKey(0), cfg)
    rules = shd.lm_train_rules()
    params_sh = shd.tree_shardings(shapes, axes, rules, mesh)
    opt = optim.adamw(1e-3)
    opt_abs = jax.eval_shape(opt.init, shapes)
    opt_sh = shd.opt_state_shardings(opt_abs, shapes, params_sh, mesh)

    row = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    batch_abs = {"node_feat": SDS((N, d_feat), jnp.float32),
                 "src": SDS((E,), jnp.int32), "dst": SDS((E,), jnp.int32),
                 "target": SDS((N, cfg.n_vars), jnp.float32),
                 "node_mask": SDS((N,), jnp.float32)}
    batch_sh = {"node_feat": _named(mesh, P(row)),
                "src": _named(mesh, P(row)), "dst": _named(mesh, P(row)),
                "target": _named(mesh, P(row)),
                "node_mask": _named(mesh, P(row))}

    step = make_train_step(lambda p, b: gcast.loss_fn(p, cfg, b), opt)
    D = cfg.d_hidden
    mlp2 = lambda d_in, d_out: 2 * d_in * D + 2 * D * d_out
    fwd = (N * mlp2(d_feat, D) + E * mlp2(2 * D, D)          # encoders
           + cfg.n_layers * (E * mlp2(3 * D, D) + N * mlp2(2 * D, D))
           + N * mlp2(D, cfg.n_vars))                        # decoder
    meta = {"model_flops": 3.0 * fwd, "params": _count(shapes),
            "active_params": _count(shapes), "tokens": N,
            "n_layers": cfg.n_layers, "optimizer": "adamw",
            "variant": variant, "padded_nodes": N, "padded_edges": E}
    return StepSpec(
        cell=f"{arch_id}/train", kind="train", fn=_with_act(step, mesh),
        abstract_args=(shapes, opt_abs, batch_abs),
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, _repl(mesh)),
        donate_argnums=(0, 1), meta=meta)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def _recsys_batch(cfg: rcs.RecsysConfig, kind: str, shape: dict,
                  mesh: Mesh, variant: str = "full"
                  ) -> Tuple[dict, dict, Callable]:
    """(abstract batch, shardings, fn(params, batch))."""
    dp = shd.batch_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    rep = P()

    def sh(spec):
        return _named(mesh, spec)

    if cfg.model_type == "deepfm":
        if kind == "retrieval":
            B = shape["n_candidates"]            # pointwise-score candidates
        else:
            B = shape["batch"]
        B = _pad_to(B, dp_size)
        F, M = cfg.n_fields, cfg.max_hot
        batch = {"ids": SDS((B, F, M), jnp.int32),
                 "valid": SDS((B, F, M), jnp.bool_)}
        specs = {"ids": sh(P(dp)), "valid": sh(P(dp))}
        if kind == "train":
            batch["label"] = SDS((B,), jnp.float32)
            specs["label"] = sh(P(dp))
            return batch, specs, None
        fn = lambda p, b: rcs.deepfm_scores(p, cfg, b["ids"], b["valid"])
        return batch, specs, fn

    S = cfg.seq_len
    if kind == "train":
        B = _pad_to(shape["batch"], dp_size)
        if cfg.model_type == "sasrec":
            batch = {"hist": SDS((B, S), jnp.int32),
                     "pos": SDS((B, S), jnp.int32),
                     "neg_ids": SDS((cfg.n_negatives,), jnp.int32)}
            specs = {"hist": sh(P(dp)), "pos": sh(P(dp)),
                     "neg_ids": sh(rep)}
        elif cfg.model_type == "bert4rec":
            M = max(1, S * 15 // 100)
            batch = {"tokens": SDS((B, S), jnp.int32),
                     "mlm_positions": SDS((B, M), jnp.int32),
                     "mlm_labels": SDS((B, M), jnp.int32),
                     "mlm_mask": SDS((B, M), jnp.float32),
                     "neg_ids": SDS((cfg.n_negatives,), jnp.int32)}
            specs = {k: sh(P(dp)) for k in batch}
            specs["neg_ids"] = sh(rep)
        else:  # mind
            batch = {"hist": SDS((B, S), jnp.int32),
                     "target": SDS((B,), jnp.int32),
                     "neg_ids": SDS((cfg.n_negatives,), jnp.int32)}
            specs = {"hist": sh(P(dp)), "target": sh(P(dp)),
                     "neg_ids": sh(rep)}
        return batch, specs, None

    if kind == "serve":
        B = _pad_to(shape["batch"], dp_size)
        C = cfg.n_serve_candidates
        batch = {"hist": SDS((B, S), jnp.int32),
                 "cand_ids": SDS((C,), jnp.int32)}
        specs = {"hist": sh(P(dp)), "cand_ids": sh(rep)}
        return batch, specs, lambda p, b: rcs.serve_fn(p, cfg, b)

    # retrieval: one query user against the full item corpus, exact top-k
    n_cand = shape["n_candidates"]
    batch = {"hist": SDS((1, S), jnp.int32),
             "cand_ids": SDS((n_cand,), jnp.int32)}
    specs = {"hist": sh(rep), "cand_ids": sh(P(dp))}

    unroll = 0 if variant == "cost" else 1       # cost variant unrolls scans

    def retrieval_fn(params, b):
        from repro.core.retrieval import topk_exact
        u = rcs.user_embed(params, cfg, b["hist"])
        if u.ndim == 3:                       # mind interests -> max over K
            u = u.reshape(-1, u.shape[-1])
        table = rcs._item_table(params, cfg).astype(jnp.float32)
        cand = jnp.take(table, b["cand_ids"], axis=0)
        scores, idx = topk_exact(u, cand, k=100, block=65536, unroll=unroll)
        return scores, idx

    return batch, specs, retrieval_fn


def recsys_spec(arch_id: str, shape: dict, mesh: Mesh, *,
                variant: str = "full", cost_layers: int = 1) -> StepSpec:
    cfg = registry.get(arch_id).full_config()
    if variant == "cost" and cfg.model_type in ("bert4rec", "sasrec"):
        cfg = dataclasses.replace(cfg, n_blocks=cost_layers)
    kind = shape["kind"]
    shapes, axes = nn.abstract_init(rcs.init, jax.random.PRNGKey(0), cfg)
    rules = shd.lm_train_rules() if kind == "train" else shd.lm_serve_rules()
    params_sh = shd.tree_shardings(shapes, axes, rules, mesh)
    batch_abs, batch_sh, serve_fn = _recsys_batch(cfg, kind, shape, mesh,
                                                  variant)

    total = _count(shapes)
    B = next(iter(batch_abs.values())).shape[0]
    D = cfg.embed_dim
    if cfg.model_type in ("bert4rec", "sasrec"):
        S = cfg.seq_len
        dense = cfg.n_blocks * (4 * D * D + 2 * D * (cfg.d_ff or
                (4 * D if cfg.model_type == "bert4rec" else D)))
        fwd = B * S * 2 * dense + B * cfg.n_blocks * 4 * S * S * D
    elif cfg.model_type == "mind":
        fwd = B * cfg.capsule_iters * 4 * cfg.n_interests * cfg.seq_len * D
    else:
        dims = (cfg.n_fields * D,) + tuple(cfg.mlp_dims) + (1,)
        fwd = B * sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    if kind == "retrieval" and cfg.model_type != "deepfm":
        fwd += 2 * shape["n_candidates"] * D
    mf = 3.0 * fwd if kind == "train" else float(fwd)

    meta = {"model_flops": mf, "params": total, "active_params": total,
            "tokens": B, "optimizer": "adamw", "variant": variant,
            "embedding_rows": (cfg.total_rows if cfg.model_type == "deepfm"
                               else cfg.item_vocab)}

    if kind == "train":
        opt = optim.adamw(1e-3)
        opt_abs = jax.eval_shape(opt.init, shapes)
        opt_sh = shd.opt_state_shardings(opt_abs, shapes, params_sh, mesh)
        step = make_train_step(lambda p, b: rcs.loss_fn(p, cfg, b), opt)
        return StepSpec(
            cell=f"{arch_id}/train", kind="train", fn=step,
            abstract_args=(shapes, opt_abs, batch_abs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, _repl(mesh)),
            donate_argnums=(0, 1), meta=meta)

    return StepSpec(
        cell=f"{arch_id}/{kind}", kind=kind, fn=_with_act(serve_fn, mesh),
        abstract_args=(shapes, batch_abs),
        in_shardings=(params_sh, batch_sh),
        out_shardings=None,
        donate_argnums=(), meta=meta)


# ---------------------------------------------------------------------------
# Bi-encoder (the paper's own architecture) — encode / retrieve cells
# ---------------------------------------------------------------------------


def biencoder_spec_cell(arch_id: str, shape: dict, mesh: Mesh, *,
                        variant: str = "full", cost_layers: int = 1,
                        encode_weights: str = "fsdp") -> StepSpec:
    cfg = registry.get(arch_id).full_config()
    knobs = {}
    if variant == "cost":
        knobs = dict(n_layers=cost_layers, layer_unroll=0, attn_unroll=0)
    cfg = dataclasses.replace(cfg, remat=(shape["kind"] == "train"), **knobs)
    kind = shape["kind"]
    if kind == "train":
        rules = shd.lm_train_rules()
    elif encode_weights == "replicated":
        # BERT-base is 110M params = 220 MB bf16: replicating beats
        # per-layer FSDP gathers on the validator mesh (§Perf iter c2)
        rules = shd.Rules({}, default=None)
    else:
        rules = shd.fsdp_only_rules()
    shapes, axes = nn.abstract_init(tfm.init, jax.random.PRNGKey(0), cfg)
    if kind != "train" and encode_weights == "replicated":
        shapes = jax.tree_util.tree_map(
            lambda l: SDS(l.shape, jnp.bfloat16)
            if jnp.issubdtype(l.dtype, jnp.floating) else l, shapes)
    params_sh = shd.tree_shardings(shapes, axes, rules, mesh)
    dp = shd.batch_axes(mesh)
    total = _count(shapes)

    if kind == "train":
        B, Lq, Lp, npsg = (shape["global_batch"], shape["q_len"],
                           shape["p_len"], shape["n_passages"])
        spec = biencoder_spec(cfg, q_max_len=Lq, p_max_len=Lp)
        batch_abs = {"q_tokens": SDS((B, Lq), jnp.int32),
                     "q_mask": SDS((B, Lq), jnp.bool_),
                     "p_tokens": SDS((B, npsg, Lp), jnp.int32),
                     "p_mask": SDS((B, npsg, Lp), jnp.bool_)}
        batch_sh = {k: _named(mesh, P(dp)) for k in batch_abs}
        opt = optim.adamw(2e-5)
        opt_abs = jax.eval_shape(opt.init, shapes)
        opt_sh = shd.opt_state_shardings(opt_abs, shapes, params_sh, mesh)
        step = make_train_step(
            lambda p, b: contrastive_loss(p, spec, b), opt)
        tokens = B * (Lq + npsg * Lp)
        meta = {"model_flops": 6.0 * total * tokens, "params": total,
                "active_params": total, "tokens": tokens,
                "optimizer": "adamw", "variant": variant}
        return StepSpec(
            cell=f"{arch_id}/train", kind="train", fn=step,
            abstract_args=(shapes, opt_abs, batch_abs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, _repl(mesh)),
            donate_argnums=(0, 1), meta=meta)

    if kind == "encode":
        B, Lp = shape["batch"], shape["p_len"]
        # corpus encoding is embarrassingly parallel: batch shards over the
        # WHOLE mesh (data x model jointly).  Sharding over "data" only
        # replicates each sequence across the 16 model-column devices —
        # measured 16.6x redundant FLOPs (EXPERIMENTS.md §Perf iter c1).
        row_all = tuple(a for a in ("pod", "data", "model")
                        if a in mesh.axis_names)
        batch_abs = (SDS((B, Lp), jnp.int32), SDS((B, Lp), jnp.bool_))
        batch_sh = (_named(mesh, P(row_all)), _named(mesh, P(row_all)))
        enc_rules = _act_rules(mesh)
        enc_rules["act_batch"] = row_all

        def encode_step(params, tokens, mask):
            return tfm.encode(params, cfg, tokens, mask, "cls")

        tokens = B * Lp
        meta = {"model_flops": 2.0 * total * tokens, "params": total,
                "active_params": total, "tokens": tokens, "variant": variant}
        return StepSpec(
            cell=f"{arch_id}/encode", kind="encode",
            fn=_with_act(encode_step, mesh, enc_rules),
            abstract_args=(shapes,) + batch_abs,
            in_shardings=(params_sh,) + batch_sh,
            out_shardings=_named(mesh, P(row_all)),
            donate_argnums=(), meta=meta)

    # retrieve: sharded exact MIPS over the encoded corpus
    nq, corpus, dim, k = (shape["n_queries"], shape["corpus"], shape["dim"],
                          shape["k"])
    n_dev = int(np.prod(list(mesh.shape.values())))
    corpus = _pad_to(corpus, n_dev)
    q_abs = SDS((_pad_to(nq, 1), dim), jnp.float32)
    c_abs = SDS((corpus, dim), jnp.float32)
    row = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)

    unroll = 0 if variant == "cost" else 1

    def retrieve_step(q, c):
        # sharded exact MIPS: local top-k per corpus shard + hierarchical
        # merge (DESIGN.md §2.1) — topk_exact's block reshape would lose the
        # row sharding and replicate the 27 GiB corpus per device.
        from repro.core.retrieval import topk_sharded
        return topk_sharded(mesh, q, c, k=k, axis_names=row, block=65536)

    meta = {"model_flops": 2.0 * nq * corpus * dim, "params": 0,
            "active_params": 0, "tokens": nq, "variant": variant,
            "corpus_padded": corpus}
    return StepSpec(
        cell=f"{arch_id}/retrieve", kind="retrieval", fn=_with_act(retrieve_step, mesh),
        abstract_args=(q_abs, c_abs),
        in_shardings=(_repl(mesh), _named(mesh, P(row))),
        out_shardings=None,
        donate_argnums=(), meta=meta)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

_LM_KIND_BUILDER = {"train": lm_train_spec, "prefill": lm_prefill_spec,
                    "decode": lm_decode_spec}


def build_step(arch_id: str, shape_name: str, mesh: Mesh, *,
               variant: str = "full", cost_layers: int = 1,
               sp=None, serve_layout: str = "2d",
               cfg_overrides: Optional[Dict[str, Any]] = None) -> StepSpec:
    spec = registry.get(arch_id)
    shape = spec.shapes[shape_name]
    kw = dict(variant=variant, cost_layers=cost_layers)
    if spec.family == "lm":
        if shape["kind"] == "train":
            if sp is not None:
                kw["sp"] = sp
            if cfg_overrides:
                kw["cfg_overrides"] = cfg_overrides
        else:
            kw["serve_layout"] = serve_layout
        s = _LM_KIND_BUILDER[shape["kind"]](arch_id, shape, mesh, **kw)
    elif spec.family == "gnn":
        s = gnn_train_spec(arch_id, shape, mesh, **kw)
    elif spec.family == "recsys":
        s = recsys_spec(arch_id, shape, mesh, **kw)
    elif spec.family == "biencoder":
        if cfg_overrides and "encode_weights" in (cfg_overrides or {}):
            kw["encode_weights"] = cfg_overrides["encode_weights"]
        s = biencoder_spec_cell(arch_id, shape, mesh, **kw)
    else:
        raise ValueError(spec.family)
    s.cell = f"{arch_id}/{shape_name}"
    return s


def all_cells(include_paper_arch: bool = True):
    archs = list(registry.ASSIGNED_ARCH_IDS)
    if include_paper_arch:
        archs.append("dr-bert-base")
    out = []
    for a in archs:
        for sname in registry.get(a).shapes:
            out.append((a, sname))
    return out
