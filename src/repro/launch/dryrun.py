import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract the roofline terms (no device allocation — all inputs are
ShapeDtypeStructs).

Per cell:
  1. FULL variant (scan-over-layers, remat) on the single-pod 16x16 mesh
     AND the 2x16x16 multi-pod mesh -> compile proof + memory analysis.
  2. COST variants (reduced depth, fully unrolled scans) on the single-pod
     mesh -> per-layer FLOPs/bytes/collective-wire-bytes, extrapolated to
     full depth (XLA counts scan bodies once — DESIGN.md §2.7).
  3. Roofline terms + bottleneck -> JSON under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --all [--jobs 4]     # every cell, subprocesses
  python -m repro.launch.dryrun --report             # aggregate JSON -> table
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


def _enable_compile_cache():
    import jax
    jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 2)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)


def _run_cell(arch: str, shape: str, out_dir: str, *, skip_multipod: bool,
              mesh_override=None, knobs=None, tag: str = "") -> dict:
    # imports deferred: jax must init after XLA_FLAGS (512 host devices)
    import jax
    _enable_compile_cache()
    from repro.launch import analysis
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    rec = {"arch": arch, "shape": shape, "ok": False, "tag": tag,
           "knobs": knobs or {}, "timings": {}}

    def lower_compile(mesh, variant, cost_layers=1):
        t0 = time.time()
        kw = dict(knobs or {})
        # config-field overrides (everything not a builder kwarg)
        builder_keys = {"sp", "serve_layout"}
        cfg_ov = {k: v for k, v in kw.items() if k not in builder_keys}
        kw = {k: v for k, v in kw.items() if k in builder_keys}
        if cfg_ov:
            kw["cfg_overrides"] = cfg_ov
        spec = build_step(arch, shape, mesh, variant=variant,
                          cost_layers=cost_layers, **kw)
        jitted = jax.jit(spec.fn,
                         in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate_argnums)
        lowered = jitted.lower(*spec.abstract_args)
        compiled = lowered.compile()
        dt = time.time() - t0
        return spec, compiled, dt

    world = 256
    single = make_production_mesh(multi_pod=False)

    # -- 1. FULL compile proof + memory analysis (single pod) --------------
    spec, compiled, dt = lower_compile(single, "full")
    rec["timings"]["full_single_s"] = dt
    rec["meta"] = {k: v for k, v in spec.meta.items()}
    ma = compiled.memory_analysis()
    mem = {attr: float(getattr(ma, attr)) for attr in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes") if hasattr(ma, attr)}
    mem["per_device_total"] = (mem.get("argument_size_in_bytes", 0)
                               + mem.get("output_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0)
                               - mem.get("alias_size_in_bytes", 0))
    rec["memory"] = mem
    full_meas = analysis.measure(compiled, world)
    rec["full_raw"] = {"flops": full_meas.flops,
                       "bytes": full_meas.bytes_accessed,
                       "coll_wire_bytes": full_meas.coll_wire_bytes}
    del compiled

    # -- 2. multi-pod compile proof (the "pod" axis shards) -----------------
    if not skip_multipod:
        multi = make_production_mesh(multi_pod=True)
        _, compiled_mp, dt = lower_compile(multi, "full")
        rec["timings"]["full_multipod_s"] = dt
        ma = compiled_mp.memory_analysis()
        rec["memory_multipod_per_device"] = float(
            getattr(ma, "argument_size_in_bytes", 0.0)
            + getattr(ma, "output_size_in_bytes", 0.0)
            + getattr(ma, "temp_size_in_bytes", 0.0)
            - getattr(ma, "alias_size_in_bytes", 0.0))
        del compiled_mp

    # -- 3. cost extraction (single pod) ------------------------------------
    n_scaled = _scaled_layers(arch, spec.meta)
    spec1, c1, dt1 = lower_compile(single, "cost", cost_layers=1)
    rec["timings"]["cost1_s"] = dt1
    q1 = analysis.measure(c1, world)
    del c1
    q2 = None
    if n_scaled > 1:
        _, c2, dt2 = lower_compile(single, "cost", cost_layers=2)
        rec["timings"]["cost2_s"] = dt2
        q2 = analysis.measure(c2, world)
        del c2
    full = analysis.extrapolate(q1, q2, n_scaled)
    rec["per_device"] = {"flops": full.flops, "bytes": full.bytes_accessed,
                         "coll_wire_bytes": full.coll_wire_bytes,
                         "n_scaled_layers": n_scaled}
    mf_per_dev = spec.meta["model_flops"] / world
    rec["roofline"] = analysis.roofline(full, mf_per_dev)
    # collective op histogram (from the 1-layer cost variant)
    hist = {}
    for op in q1.coll_ops:
        key = op["kind"]
        hist.setdefault(key, {"count": 0, "wire_bytes": 0.0})
        hist[key]["count"] += 1
        hist[key]["wire_bytes"] += op["wire_bytes"]
    rec["collectives_1layer"] = hist
    rec["ok"] = True
    return rec


def _scaled_layers(arch: str, meta: dict) -> int:
    """Size of the homogeneous layer stack the cost variant extrapolates."""
    from repro.configs import registry
    spec = registry.get(arch)
    cfg = spec.full_config()
    if spec.family in ("lm", "biencoder"):
        if getattr(cfg, "moe_num_experts", 0) > 0 and cfg.first_k_dense > 0:
            return cfg.n_layers - cfg.first_k_dense
        return cfg.n_layers
    if spec.family == "gnn":
        return cfg.n_layers
    if spec.family == "recsys":
        return 1          # cost variant keeps real depth, fully unrolled
    return 1


def run_one(args) -> int:
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    tag = args.tag or "baseline"
    path = os.path.join(out_dir,
                        f"{args.arch}__{args.shape}__{tag}.json")
    knobs = {}
    for kv in (args.knobs.split(",") if args.knobs else []):
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "false"):
            v = v == "true"
        knobs[k] = v
    try:
        rec = _run_cell(args.arch, args.shape, out_dir,
                        skip_multipod=args.skip_multipod, tag=tag,
                        knobs=knobs or None)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape, "ok": False,
               "tag": tag, "error": repr(e),
               "traceback": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["ok"]:
        r = rec["roofline"]
        print(f"[dryrun] {args.arch}/{args.shape}: OK  "
              f"mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB  "
              f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
              f"coll={r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}  "
              f"roofline_frac={r['roofline_frac']:.3f}")
        return 0
    print(f"[dryrun] {args.arch}/{args.shape}: FAIL {rec['error']}")
    print(rec.get("traceback", ""))
    return 1


def run_all(args) -> int:
    """Run every cell in its own subprocess (isolation + parallelism)."""
    from repro.launch.steps import all_cells
    cells = all_cells(include_paper_arch=not args.assigned_only)
    if args.filter:
        cells = [c for c in cells if args.filter in f"{c[0]}/{c[1]}"]
    if args.skip_existing:
        tag = args.tag or "baseline"

        def done(a, s):
            p = os.path.join(args.out, f"{a}__{s}__{tag}.json")
            if not os.path.exists(p):
                return False
            with open(p) as f:
                return json.load(f).get("ok", False)

        cells = [c for c in cells if not done(*c)]
        print(f"[dryrun --all] {len(cells)} cells remaining")
    procs, pending, failures = [], list(cells), []
    results = []
    while pending or procs:
        while pending and len(procs) < args.jobs:
            arch, shape = pending.pop(0)
            tagpart = ["--tag", args.tag] if args.tag else []
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", args.out] \
                + (["--skip-multipod"] if args.skip_multipod else []) + tagpart
            p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True)
            procs.append((arch, shape, p))
        for item in list(procs):
            arch, shape, p = item
            if p.poll() is not None:
                procs.remove(item)
                out = p.stdout.read()
                print(out.strip())
                results.append((arch, shape, p.returncode))
                if p.returncode != 0:
                    failures.append((arch, shape))
        time.sleep(0.5)
    print(f"\n[dryrun --all] {len(results) - len(failures)}/{len(results)} OK")
    for a, s in failures:
        print(f"  FAILED: {a}/{s}")
    return 1 if failures else 0


def report(args) -> int:
    import glob
    rows = []
    for path in sorted(glob.glob(os.path.join(args.out, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            rows.append((rec["arch"], rec["shape"], rec.get("tag", ""),
                         "FAIL", "", "", "", "", "", "", "", ""))
            continue
        r = rec["roofline"]
        mp = rec.get("memory_multipod_per_device")
        rows.append((rec["arch"], rec["shape"], rec.get("tag", ""),
                     r["bottleneck"],
                     f"{r['compute_s']*1e3:.2f}",
                     f"{r['memory_s']*1e3:.2f}",
                     f"{r.get('memory_raw_s', 0)*1e3:.2f}",
                     f"{r['collective_s']*1e3:.2f}",
                     f"{rec['memory']['per_device_total']/2**30:.2f}",
                     f"{mp/2**30:.2f}" if mp else "-",
                     f"{r['useful_flops_frac']:.2f}",
                     f"{r['roofline_frac']:.3f}"))
    hdr = ("arch", "shape", "tag", "bound", "comp_ms", "mem_ms", "memraw_ms",
           "coll_ms", "GiB/dev", "GiB/dev@512", "useful", "roofline")
    if getattr(args, "md", False):
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for r in rows:
            print("| " + " | ".join(str(x) for x in r) + " |")
    else:
        widths = [max(len(str(r[i])) for r in rows + [hdr])
                  for i in range(len(hdr))]
        for r in [hdr] + rows:
            print("  ".join(str(x).ljust(w) for x, w in zip(r, widths)))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--assigned-only", action="store_true")
    ap.add_argument("--filter", default="")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-multipod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--knobs", default="",
                    help="k=v[,k=v...] builder/config overrides "
                         "(sp=1, serve_layout=tp, param_dtype=bf16, ...)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if args.report:
        return report(args)
    if args.all:
        return run_all(args)
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    return run_one(args)


if __name__ == "__main__":
    sys.exit(main())
