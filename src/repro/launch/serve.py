"""Retrieval serving entry point — queries against promoted checkpoints.

The serving half of the asyncval loop: builds a device-resident index
from the best (or latest) committed checkpoint through the validator's
own encode/score machinery (``repro.serve``), answers a query file
through the micro-batching :class:`~repro.serve.service.QueryService`,
and — with ``--watch`` — keeps a :class:`~repro.serve.promoter.Promoter`
tailing the control plane's ``select`` events so every newly promoted
checkpoint hot-swaps into service with zero downtime.

    python -m repro.launch.serve \\
        --candidate_dir corpus_dir --query_file q.jsonl \\
        --ckpts_dir ckpts/ --events logs/run_control.jsonl \\
        --k 10 --score_dtype f32 --max_batch 8 --flush_ms 4 \\
        --encoder mymodule:my_spec_builder [--watch]

Answers are bit-identical to what the validator scored for the same
checkpoint (tests/test_serve_parity.py) — validation numbers ARE serving
numbers.  The old LM prefill/decode demo this module used to host lives
on at ``repro.launch.lm_demo``; its ``serve_batch`` is re-exported here
for compatibility.
"""

from __future__ import annotations

import argparse
import glob
import os
import sys
import time

# compatibility re-export: the LM generation demo predates the serving
# tier and external callers import its batch helper from this module
from repro.launch.lm_demo import serve_batch  # noqa: F401


def _percentile(xs, q):
    xs = sorted(xs)
    if not xs:
        return float("nan")
    pos = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[pos]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="serve dense-retrieval queries against control-plane-"
                    "promoted checkpoints, through the validator's exact "
                    "scoring path")
    ap.add_argument("--query_file", nargs="+", required=True)
    ap.add_argument("--candidate_dir", required=True)
    ap.add_argument("--ckpts_dir", required=True)
    ap.add_argument("--step", type=int, default=None,
                    help="serve this checkpoint step (default: the newest "
                         "'select' winner in --events, else the latest "
                         "committed checkpoint)")
    ap.add_argument("--events", default=None,
                    help="control-plane event JSONL to tail for 'select' "
                         "promotions (the validator CLI writes "
                         "<logdir>/<run>_control.jsonl)")
    ap.add_argument("--serve_events", default=None,
                    help="where to record replayable swap events "
                         "(default: <ckpts_dir>/serve_events.jsonl)")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--score_dtype", default="f32",
                    choices=["f32", "bf16", "int8"])
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--batch_size", type=int, default=64,
                    help="corpus encode chunk rows (index build)")
    ap.add_argument("--max_batch", type=int, default=8,
                    help="query micro-batch size")
    ap.add_argument("--flush_ms", type=float, default=4.0,
                    help="max-latency flush for partial micro-batches")
    ap.add_argument("--max_pending", type=int, default=256,
                    help="admission bound on in-flight requests")
    ap.add_argument("--q_max_len", type=int, default=32)
    ap.add_argument("--p_max_len", type=int, default=128)
    ap.add_argument("--encoder", default=None,
                    help="module:function -> EncoderSpec")
    ap.add_argument("--arch", default="dr-bert-base")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--watch", action="store_true",
                    help="keep polling --events and hot-swap newly "
                         "promoted checkpoints (zero downtime)")
    ap.add_argument("--poll_interval", type=float, default=1.0)
    args = ap.parse_args(argv)

    from repro.core.cli import build_encoder, load_texts
    from repro.serve import (AdmissionController, IndexBuilder, Promoter,
                             QueryService, ServeConfig)

    spec = build_encoder(args)
    corpus = load_texts(sorted(
        glob.glob(os.path.join(args.candidate_dir, "*.json*"))))
    queries = load_texts(args.query_file)
    print(f"[serve] corpus={len(corpus)} queries={len(queries)}",
          file=sys.stderr)

    cfg = ServeConfig(k=args.k, score_dtype=args.score_dtype,
                      impl=args.impl, batch_size=args.batch_size,
                      max_batch=args.max_batch, flush_ms=args.flush_ms,
                      max_pending=args.max_pending)
    builder = IndexBuilder(spec, corpus, cfg)
    service = QueryService(spec, k=cfg.k, max_batch=cfg.max_batch,
                           flush_ms=cfg.flush_ms,
                           admission=AdmissionController(cfg.max_pending))
    promoter = Promoter(
        builder, service, args.ckpts_dir,
        target_fn=(lambda: args.step) if args.step is not None else None,
        control_events=args.events,
        log=args.serve_events or os.path.join(args.ckpts_dir,
                                              "serve_events.jsonl"),
        poll_interval_s=args.poll_interval)
    if not promoter.poll_once():
        print("[serve] no committed checkpoint to promote", file=sys.stderr)
        return 1
    print(f"[serve] live step {service.live_step()} "
          f"({builder.store.n_texts} docs, score_dtype={cfg.score_dtype})",
          file=sys.stderr)

    responses = service.answer(list(queries.items()))
    lats = [r.latency_s for r in responses]
    print(f"[serve] answered {len(responses)} queries: "
          f"p50={_percentile(lats, 50)*1e3:.2f}ms "
          f"p99={_percentile(lats, 99)*1e3:.2f}ms "
          f"step={service.live_step()}")

    if args.watch:
        print("[serve] watching", args.events or args.ckpts_dir,
              file=sys.stderr)
        service.start()
        try:
            while True:
                if promoter.poll_once():
                    prev, now = promoter.swaps[-1]
                    print(f"[serve] hot-swapped {prev} -> {now}",
                          file=sys.stderr)
                time.sleep(args.poll_interval)
        except KeyboardInterrupt:
            pass
        finally:
            service.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
