"""Roofline extraction from compiled dry-run artifacts (DESIGN.md §2.7).

Sources:
  * ``compiled.cost_analysis()``  -> HLO FLOPs + bytes accessed (per-device:
    the compiled module is the per-device SPMD program).
  * ``compiled.as_text()``        -> optimized HLO; collective wire bytes are
    summed from every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute with ring-algorithm factors over the parsed
    replica-group size.

Scan correction: XLA counts a ``while`` (scan) body ONCE, not x trip-count
(verified empirically — see EXPERIMENTS.md §Dry-run).  The dry-run therefore
measures *cost variants* (reduced depth, fully unrolled) and extrapolates:

    Q_full = Q(1 scaled layer) + (L_scaled - 1) * [Q(2) - Q(1)]

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# "f32[128,1024]{1,0}" or "bf16[8,16]" or scalar "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))                       # [groups, group_size]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    return world


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group: int
    wire_bytes: float      # per-device, ring algorithm

    def as_dict(self):
        return dataclasses.asdict(self)


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    """Per-device wire traffic under ring algorithms."""
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * result_bytes
    if kind == "all-gather":
        return (g - 1) / g * result_bytes            # result = g x shard
    if kind == "reduce-scatter":
        return (g - 1) * result_bytes                # operand = g x result
    if kind == "all-to-all":
        return (g - 1) / g * result_bytes
    if kind == "collective-permute":
        return float(result_bytes)                   # one hop send+recv
    return 0.0


def parse_collectives(hlo_text: str, world: int) -> List[CollectiveOp]:
    """Collective ops with per-device wire bytes from optimized HLO text."""
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match "= TYPE <op>(" — defining instructions only, skip *-start/done
        m = re.search(r"=\s+(\S+(?:\([^)]*\))?)\s+(" + "|".join(_COLLECTIVES)
                      + r")(?:\.\d+)?\(", ls)
        if not m:
            continue
        if re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)-(start|done)", ls):
            # async pairs: count the -start (has the shape), skip -done
            if "-done" in m.group(2) or re.search(r"-done\(", ls):
                continue
        type_str, kind = m.group(1), m.group(2)
        rb = _shape_bytes(type_str)
        if rb == 0:
            continue
        g = _group_size(ls, world)
        out.append(CollectiveOp(kind, rb, g, _wire_bytes(kind, rb, g)))
    return out


# ops whose results cross HBM even after TPU fusion: matmuls, data movement,
# gather/scatter, loop/fusion boundaries.  Pure elementwise (add/mul/convert/
# select/exp/broadcast) fuses into producers on TPU and is excluded — this is
# the fusion-adjusted HBM-bytes estimate reported alongside the raw
# ``cost_analysis()["bytes accessed"]`` (XLA:CPU fuses far less than TPU, so
# the raw number overestimates TPU HBM traffic; EXPERIMENTS.md §Roofline
# reports both).
_HBM_BOUNDARY_OPS = ("dot", "fusion", "gather", "scatter", "convolution",
                     "copy", "transpose", "dynamic-slice",
                     "dynamic-update-slice", "while", "sort", "reduce")
_HBM_RE = re.compile(
    r"=\s+(\S+(?:\([^)]*\))?)\s+(" + "|".join(_HBM_BOUNDARY_OPS)
    + r")(?:\.\d+)?\(")


def fusion_adjusted_bytes(hlo_text: str) -> float:
    """Sum of result bytes over fusion-boundary ops (TPU HBM-traffic proxy)."""
    total = 0
    for line in hlo_text.splitlines():
        m = _HBM_RE.search(line.strip())
        if m:
            total += _shape_bytes(m.group(1))
    return float(total)


@dataclasses.dataclass
class Measurement:
    """Per-device cost numbers from one compiled artifact."""
    flops: float
    bytes_accessed: float
    coll_wire_bytes: float
    coll_ops: List[Dict[str, Any]]
    hbm_bytes_est: float = 0.0
    peak_memory_bytes: Optional[float] = None

    def combine(self, other: "Measurement", scale: float) -> "Measurement":
        """self + scale * other (for per-layer extrapolation)."""
        return Measurement(
            flops=self.flops + scale * other.flops,
            bytes_accessed=self.bytes_accessed + scale * other.bytes_accessed,
            coll_wire_bytes=self.coll_wire_bytes + scale * other.coll_wire_bytes,
            coll_ops=self.coll_ops,
            hbm_bytes_est=self.hbm_bytes_est + scale * other.hbm_bytes_est,
        )

    @staticmethod
    def delta(q2: "Measurement", q1: "Measurement") -> "Measurement":
        return Measurement(
            flops=max(0.0, q2.flops - q1.flops),
            bytes_accessed=max(0.0, q2.bytes_accessed - q1.bytes_accessed),
            coll_wire_bytes=max(0.0, q2.coll_wire_bytes - q1.coll_wire_bytes),
            coll_ops=[],
            hbm_bytes_est=max(0.0, q2.hbm_bytes_est - q1.hbm_bytes_est),
        )


def measure(compiled, world: int) -> Measurement:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # older JAX: one dict per device
        cost = cost[0] if cost else {}
    text = compiled.as_text()
    colls = parse_collectives(text, world)
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(getattr(ma, "temp_size_in_bytes", 0)
                        + getattr(ma, "argument_size_in_bytes", 0)
                        + getattr(ma, "output_size_in_bytes", 0)
                        - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Measurement(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        coll_wire_bytes=sum(c.wire_bytes for c in colls),
        coll_ops=[c.as_dict() for c in colls],
        hbm_bytes_est=fusion_adjusted_bytes(text),
        peak_memory_bytes=mem,
    )


def extrapolate(q1: Measurement, q2: Optional[Measurement],
                n_scaled: int) -> Measurement:
    """Q_full = Q1 + (n_scaled - 1) * (Q2 - Q1); Q2=None -> exact (no scan)."""
    if q2 is None or n_scaled <= 1:
        return q1
    return q1.combine(Measurement.delta(q2, q1), float(n_scaled - 1))


def roofline(m: Measurement, model_flops_per_dev: float) -> Dict[str, float]:
    compute_s = m.flops / PEAK_FLOPS
    memory_raw_s = m.bytes_accessed / HBM_BW          # prescribed metric
    memory_s = m.hbm_bytes_est / HBM_BW               # fusion-adjusted
    coll_s = m.coll_wire_bytes / ICI_BW
    bound = max((compute_s, "compute"), (memory_s, "memory"),
                (coll_s, "collective"))
    step_s = max(compute_s, memory_s, coll_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_raw_s": memory_raw_s,
        "collective_s": coll_s,
        "bottleneck": bound[1],
        "step_time_s": step_s,                      # no-overlap upper bound
        "model_flops_per_dev": model_flops_per_dev,
        "useful_flops_frac": (model_flops_per_dev / m.flops
                              if m.flops > 0 else 0.0),
        # achieved fraction of the compute roofline if the dominant term
        # were the wall clock (the score the perf loop drives up):
        "roofline_frac": (model_flops_per_dev / PEAK_FLOPS / step_s
                          if step_s > 0 else 0.0),
    }
