"""End-to-end training driver with asynchronous checkpoint validation.

The Asyncval deployment (paper Fig. 1b): the trainer commits checkpoints to
a directory; a decoupled validator (its own mesh — on this box a thread over
the disaggregated device halves) watches the directory and validates each
checkpoint while training continues.  Training NEVER blocks on validation.

    python -m repro.launch.train --arch dr-bert-base --steps 60 \
        --ckpt-every 10 --workdir /tmp/asyncval_run [--sync]

``--sync`` runs the paper's Figure-1a baseline instead (validation inline
in the training loop) so the wall-clock pipelining win is measurable —
see benchmarks/bench_async_schedule.py.

Any registry arch trains (reduced smoke config on CPU); the retrieval
validation loop attaches to embedding-producing archs (biencoder, lm,
recsys-sequential); others validate by held-out loss.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.reporting import JSONLLogger
from repro.core.samplers import FullCorpus, RunFileTopK
from repro.core.validator import AsyncValidator
from repro.data import corpus as synthetic_ds
from repro.models import nn
from repro.models import transformer as tfm
from repro.models.biencoder import biencoder_spec, contrastive_loss
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig


def _contrastive_batches(ds, spec, batch_size: int, n_psg: int = 2):
    """Deterministic step -> batch function from the synthetic dataset."""
    qids = sorted(ds.qrels)
    docids = list(ds.corpus)
    by_qid_gold = {q: next(iter(ds.qrels[q])) for q in qids}

    def make(step: int):
        rng = np.random.default_rng(1000 + step)
        pick = rng.choice(len(qids), size=batch_size)
        q_tok, p_tok = [], []
        for i in pick:
            qid = qids[i]
            q_tok.append(ds.queries[qid])
            gold = by_qid_gold[qid]
            negs = rng.choice(len(docids), size=n_psg - 1)
            p_tok.append([ds.corpus[gold]]
                         + [ds.corpus[docids[j]] for j in negs])
        from repro.data.corpus import pad_batch
        qt, qm = pad_batch(q_tok, spec.q_max_len)
        flat = [t for ps in p_tok for t in ps]
        pt, pm = pad_batch(flat, spec.p_max_len)
        B = batch_size
        return {"q_tokens": jnp.asarray(qt), "q_mask": jnp.asarray(qm),
                "p_tokens": jnp.asarray(pt).reshape(B, n_psg, -1),
                "p_mask": jnp.asarray(pm).reshape(B, n_psg, -1)}

    return make


def run(args) -> dict:
    os.makedirs(args.workdir, exist_ok=True)
    ckpt_dir = os.path.join(args.workdir, "ckpts")

    arch = registry.get(args.arch)
    assert arch.family == "biencoder", \
        "train.py end-to-end driver targets the paper's DR bi-encoder; " \
        "other families train via examples/ or the Trainer API directly"
    cfg = arch.smoke_config() if not args.full else arch.full_config()
    cfg = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    spec = biencoder_spec(cfg, q_max_len=args.q_max_len,
                          p_max_len=args.p_max_len)

    ds = synthetic_ds.synthetic_retrieval_dataset(
        args.seed, n_passages=args.corpus_size, n_queries=args.n_queries,
        vocab=cfg.vocab_size)
    baseline_run = synthetic_ds.lexical_baseline_run(ds, k=args.depth)

    params = nn.materialize(spec.init(jax.random.PRNGKey(args.seed)))
    opt = optim.adamw(args.lr)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=ckpt_dir, log_every=args.ckpt_every,
                         async_save=True)
    trainer = Trainer(tcfg, lambda p, b: contrastive_loss(p, spec, b),
                      opt, params,
                      _contrastive_batches(ds, spec, args.batch_size),
                      logger=JSONLLogger(os.path.join(args.workdir,
                                                      "train.jsonl")))

    sampler = (RunFileTopK(depth=args.depth) if args.subset else FullCorpus())
    vcfg = ValidationConfig(metrics=("MRR@10", "Recall@100"),
                            k=100, batch_size=args.batch_size)
    pipeline = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels, vcfg,
                                  sampler=sampler, baseline_run=baseline_run)
    validator = AsyncValidator(
        ckpt_dir, pipeline,
        logger=JSONLLogger(os.path.join(args.workdir, "valid.jsonl")),
        ledger_path=os.path.join(args.workdir, "ledger.jsonl"))

    t0 = time.time()
    if args.sync:
        # paper Fig. 1a: validate inline after each checkpoint
        def on_metrics(step, m):
            if step % args.ckpt_every == 0:
                trainer.saver.wait()
                validator.validate_pending()
        trainer.run(on_metrics=on_metrics)
        validator.validate_pending()
    else:
        # paper Fig. 1b: validation decoupled, runs while training continues
        validator.start()
        trainer.run()
        validator.stop(drain=True)
    wall = time.time() - t0

    results = {
        "wall_time_s": wall,
        "mode": "sync" if args.sync else "async",
        "validated_steps": validator.ledger.validated_steps,
        "metrics": {r.step: r.metrics for r in validator.results},
        "errors": validator.errors,
    }
    with open(os.path.join(args.workdir, "summary.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(json.dumps(results, indent=1))
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dr-bert-base")
    ap.add_argument("--workdir", default="/tmp/asyncval_train")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--corpus-size", type=int, default=600)
    ap.add_argument("--n-queries", type=int, default=50)
    ap.add_argument("--q-max-len", type=int, default=12)
    ap.add_argument("--p-max-len", type=int, default=28)
    ap.add_argument("--depth", type=int, default=20)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--subset", action="store_true")
    ap.add_argument("--sync", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
