"""End-to-end training driver with asynchronous checkpoint validation.

The Asyncval deployment (paper Fig. 1b): the trainer commits checkpoints to
a directory; a decoupled validator (its own mesh — on this box a thread over
the disaggregated device halves) watches the directory and validates each
checkpoint while training continues.  Training NEVER blocks on validation.

    python -m repro.launch.train --arch dr-bert-base --steps 60 \
        --ckpt-every 10 --workdir /tmp/asyncval_run [--sync]

``--sync`` runs the paper's Figure-1a baseline instead (validation inline
in the training loop) so the wall-clock pipelining win is measurable —
see benchmarks/bench_async_schedule.py.

Any registry arch trains (reduced smoke config on CPU); the retrieval
validation loop attaches to embedding-producing archs (biencoder, lm,
recsys-sequential); others validate by held-out loss.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs import registry
from repro.control import ControlConfig, ControlPlane
from repro.core.reporting import JSONLLogger
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.samplers import FullCorpus, RunFileTopK
from repro.core.validator import AsyncValidator
from repro.core.watcher import BudgetPolicy, Policy
from repro.data import corpus as synthetic_ds
from repro.models import nn
from repro.models import transformer as tfm
from repro.models.biencoder import biencoder_spec, contrastive_loss
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig


def _contrastive_batches(ds, spec, batch_size: int, n_psg: int = 2):
    """Deterministic step -> batch function from the synthetic dataset."""
    qids = sorted(ds.qrels)
    docids = list(ds.corpus)
    by_qid_gold = {q: next(iter(ds.qrels[q])) for q in qids}

    def make(step: int):
        rng = np.random.default_rng(1000 + step)
        pick = rng.choice(len(qids), size=batch_size)
        q_tok, p_tok = [], []
        for i in pick:
            qid = qids[i]
            q_tok.append(ds.queries[qid])
            gold = by_qid_gold[qid]
            negs = rng.choice(len(docids), size=n_psg - 1)
            p_tok.append([ds.corpus[gold]]
                         + [ds.corpus[docids[j]] for j in negs])
        from repro.data.corpus import pad_batch
        qt, qm = pad_batch(q_tok, spec.q_max_len)
        flat = [t for ps in p_tok for t in ps]
        pt, pm = pad_batch(flat, spec.p_max_len)
        B = batch_size
        return {"q_tokens": jnp.asarray(qt), "q_mask": jnp.asarray(qm),
                "p_tokens": jnp.asarray(pt).reshape(B, n_psg, -1),
                "p_mask": jnp.asarray(pm).reshape(B, n_psg, -1)}

    return make


def run(args) -> dict:
    os.makedirs(args.workdir, exist_ok=True)
    ckpt_dir = os.path.join(args.workdir, "ckpts")

    arch = registry.get(args.arch)
    assert arch.family == "biencoder", \
        "train.py end-to-end driver targets the paper's DR bi-encoder; " \
        "other families train via examples/ or the Trainer API directly"
    cfg = arch.smoke_config() if not args.full else arch.full_config()
    cfg = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    spec = biencoder_spec(cfg, q_max_len=args.q_max_len,
                          p_max_len=args.p_max_len)

    ds = synthetic_ds.synthetic_retrieval_dataset(
        args.seed, n_passages=args.corpus_size, n_queries=args.n_queries,
        vocab=cfg.vocab_size)
    baseline_run = synthetic_ds.lexical_baseline_run(ds, k=args.depth)

    params = nn.materialize(spec.init(jax.random.PRNGKey(args.seed)))
    opt = optim.adamw(args.lr)
    stop_file = os.path.join(args.workdir, "STOP")
    # control flags default off so pre-control callers (plain Args objects,
    # benchmarks) keep the classic produce-only behaviour.
    patience = getattr(args, "early_stop_patience", 0)
    min_delta = getattr(args, "early_stop_min_delta", 0.0)
    overfit_window = getattr(args, "overfit_window", 0)
    keep_top_k = getattr(args, "keep_top_k", 0)
    ensemble_top_k = getattr(args, "ensemble_top_k", 0)
    policy_kind = getattr(args, "policy", "fifo")
    handoff = getattr(args, "handoff", False)
    control_on = patience > 0 or keep_top_k > 0 or ensemble_top_k > 0
    # lazy snapshot hand-off: the trainer publishes each checkpoint's host
    # copy to a bounded channel the moment it lands; the validator scores
    # it while the durable save is still racing.  Watcher stays fallback.
    snapshots = None
    if handoff:
        from repro.handoff import SnapshotChannel, SnapshotSpool
        spool_root = getattr(args, "handoff_spool", "") or None
        snapshots = SnapshotChannel(
            capacity=getattr(args, "handoff_capacity", 2),
            spool=SnapshotSpool(spool_root) if spool_root else None)
    # a STOP marker is one run's verdict, not the workdir's: clear a stale
    # one so a restarted/continued run trains instead of halting at step 0.
    if os.path.exists(stop_file):
        os.remove(stop_file)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=ckpt_dir, log_every=args.ckpt_every,
                         async_save=True,
                         stop_file=stop_file if patience > 0 else None,
                         snapshots=snapshots)
    trainer = Trainer(tcfg, lambda p, b: contrastive_loss(p, spec, b),
                      opt, params,
                      _contrastive_batches(ds, spec, args.batch_size),
                      logger=JSONLLogger(os.path.join(args.workdir,
                                                      "train.jsonl")))

    sampler = (RunFileTopK(depth=args.depth) if args.subset else FullCorpus())
    vcfg = ValidationConfig(metrics=("MRR@10", "Recall@100"),
                            k=100, batch_size=args.batch_size)
    # single-task suite named "default": ledger rows, metric names and the
    # control plane's "MRR@10" spec are exactly the legacy pipeline's.
    suite = ValidationSuite(spec, [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels,
                       sampler=sampler, baseline_run=baseline_run),
    ], vcfg)
    # fail fast on deterministic engine-config errors instead of having
    # every checkpoint's validation swallowed by the retry loop
    suite.build_engines()

    # convergence control plane: ledger-driven selection + quality-aware GC,
    # async early stop via the STOP marker, post-run checkpoint ensembling.
    control = None
    if control_on:
        ccfg = ControlConfig(metric="MRR@10", mode="max",
                             keep_top_k=keep_top_k,
                             early_stop=patience > 0,
                             patience=max(patience, 1),
                             min_delta=min_delta,
                             overfit_window=overfit_window,
                             ensemble_top_k=ensemble_top_k)
        control = ControlPlane(ckpt_dir, ccfg, stop_path=stop_file,
                               event_path=os.path.join(args.workdir,
                                                       "control.jsonl"),
                               durability=snapshots.durability
                               if snapshots is not None else None)
    policy = BudgetPolicy() if policy_kind == "budget" \
        else Policy(kind=policy_kind, stride=getattr(args, "stride", 1))
    validator = AsyncValidator(
        ckpt_dir, suite, policy=policy, controller=control,
        logger=JSONLLogger(os.path.join(args.workdir, "valid.jsonl")),
        ledger_path=os.path.join(args.workdir, "ledger.jsonl"),
        snapshots=snapshots)
    if control is not None:
        # restart: warm the ranking from the prior session's ledger so
        # quality-aware GC never forgets already-validated checkpoints
        # (old steps are skipped by idempotency and would otherwise be
        # invisible to a cold selector).
        control.rehydrate(validator.ledger.rows(),
                          expected_tasks=suite.task_names)

    def feed_control(step, m):
        if control is not None:
            control.note_train(step, m)     # overfit detector's train side

    t0 = time.time()
    if args.sync:
        # paper Fig. 1a: validate inline after each checkpoint
        def on_metrics(step, m):
            feed_control(step, m)
            if step % args.ckpt_every == 0:
                trainer.saver.wait()
                validator.validate_pending()
        trainer.run(on_metrics=on_metrics)
        validator.validate_pending()
    else:
        # paper Fig. 1b: validation decoupled, runs while training continues
        validator.start()
        trainer.run(on_metrics=feed_control)
        validator.stop(drain=True)
    if control is not None:
        # every durable save has landed (trainer.run waits the saver out):
        # release any durability-gated GC held on snapshot-scored evidence
        control.maybe_gc(validator)

    ensemble = None
    if control is not None and ensemble_top_k > 0:
        vstep = control.build_ensemble(
            lambda p: suite.validate_params(
                p, write_runs=False).metrics["MRR@10"])
        if vstep is not None:
            # policy-proof: score the soup via the normal path even when a
            # stride/budget policy would never select its step id
            validator.validate_step(vstep)
            res = next((r for r in validator.results if r.step == vstep),
                       None)
            ensemble = {"step": vstep, "members": control.ensemble_members,
                        "metrics": res.log_metrics if res else None}
    wall = time.time() - t0

    results = {
        "wall_time_s": wall,
        "mode": "sync" if args.sync else "async",
        "validated_steps": validator.ledger.validated_steps,
        "metrics": {r.step: r.log_metrics for r in validator.results},
        "errors": list(validator.errors),
        "stopped_early": trainer.stopped_early,
        "stop_verdict": trainer.stop_verdict,
        "best_step": control.selector.best_step if control else None,
        "kept_checkpoints": ckpt.list_steps(ckpt_dir) if control_on else None,
        "ensemble": ensemble,
    }
    with open(os.path.join(args.workdir, "summary.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(json.dumps(results, indent=1))
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dr-bert-base")
    ap.add_argument("--workdir", default="/tmp/asyncval_train")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--corpus-size", type=int, default=600)
    ap.add_argument("--n-queries", type=int, default=50)
    ap.add_argument("--q-max-len", type=int, default=12)
    ap.add_argument("--p-max-len", type=int, default=28)
    ap.add_argument("--depth", type=int, default=20)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--subset", action="store_true")
    ap.add_argument("--sync", action="store_true")
    ap.add_argument("--full", action="store_true")
    # convergence control plane (repro.control)
    ap.add_argument("--early-stop-patience", type=int, default=0,
                    help="evaluations without improvement before the "
                         "validator publishes the STOP marker (0 = off)")
    ap.add_argument("--early-stop-min-delta", type=float, default=0.0)
    ap.add_argument("--overfit-window", type=int, default=0,
                    help="history-based overfit detector window (>= 3; "
                         "0 = off)")
    ap.add_argument("--keep-top-k", type=int, default=0,
                    help="quality-aware GC: keep top-k checkpoints by "
                         "MRR@10 plus unvalidated ones (0 = keep all)")
    ap.add_argument("--ensemble-top-k", type=int, default=0,
                    help="greedy-soup the top-k checkpoints into a virtual "
                         "checkpoint after training (0 = off)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "latest_first", "stride", "budget"])
    ap.add_argument("--stride", type=int, default=1)
    # lazy snapshot hand-off (repro.handoff)
    ap.add_argument("--handoff", action="store_true",
                    help="validate checkpoints from host-resident snapshots "
                         "the moment the device->host copy lands, before "
                         "the durable save commits (watcher stays the "
                         "fallback; GC/soup/promotion still wait for the "
                         "durable COMMIT)")
    ap.add_argument("--handoff-capacity", type=int, default=2,
                    help="snapshot ring size; over capacity the oldest "
                         "unclaimed snapshot is dropped and its step falls "
                         "back to the watcher path (training never blocks)")
    ap.add_argument("--handoff-spool", default="",
                    help="spill directory (e.g. under /dev/shm) mirroring "
                         "the ring for cross-process fleet workers; empty "
                         "= in-process hand-off only")
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
