"""Neighbor sampler for minibatch GNN training (GraphSAGE-style fanout).

Host-side (numpy) as in production systems: the sampler runs in the input
pipeline; the device step consumes fixed-shape padded subgraph tensors, so
the jitted train step never recompiles.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Compressed adjacency (out-edges)."""
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray, n_nodes: int):
        order = np.argsort(src, kind="stable")
        s, d = src[order], dst[order]
        counts = np.bincount(s, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return cls(indptr=indptr, indices=d.astype(np.int32))

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]


def sampled_subgraph_shape(batch_nodes: int, fanout: Sequence[int]
                           ) -> Tuple[int, int]:
    """Padded (n_nodes, n_edges) of a fanout-sampled subgraph (worst case)."""
    n_nodes, n_edges, frontier = batch_nodes, 0, batch_nodes
    for f in fanout:
        n_edges += frontier * f
        frontier = frontier * f
        n_nodes += frontier
    return n_nodes, n_edges


def sample_subgraph(graph: CSRGraph, seeds: np.ndarray, fanout: Sequence[int],
                    rng: np.random.Generator):
    """Layer-wise fanout sampling; returns a padded, relabeled subgraph.

    Returns dict: local_nodes (global ids, padded with -1), src/dst (local
    ids, padded self-loops on node 0), edge_mask, seed_count.  Padding keeps
    shapes static across batches (fixed-shape jit).
    """
    max_nodes, max_edges = sampled_subgraph_shape(len(seeds), fanout)
    nodes = list(seeds)
    local = {int(g): i for i, g in enumerate(seeds)}
    src_l, dst_l = [], []
    frontier = list(seeds)
    for f in fanout:
        nxt = []
        for u in frontier:
            nbr = graph.neighbors(int(u))
            if len(nbr) == 0:
                continue
            take = nbr if len(nbr) <= f else rng.choice(nbr, size=f,
                                                        replace=False)
            for v in take:
                v = int(v)
                if v not in local:
                    local[v] = len(nodes)
                    nodes.append(v)
                    nxt.append(v)
                # message flows neighbor -> center
                src_l.append(local[v])
                dst_l.append(local[int(u)])
        frontier = nxt

    n, e = len(nodes), len(src_l)
    out_nodes = np.full(max_nodes, -1, np.int64)
    out_nodes[:n] = np.asarray(nodes, np.int64)
    src = np.zeros(max_edges, np.int32)
    dst = np.zeros(max_edges, np.int32)
    src[:e] = np.asarray(src_l, np.int32)
    dst[:e] = np.asarray(dst_l, np.int32)
    edge_mask = np.zeros(max_edges, bool)
    edge_mask[:e] = True
    node_mask = np.zeros(max_nodes, bool)
    node_mask[:n] = True
    return {"nodes": out_nodes, "src": src, "dst": dst,
            "edge_mask": edge_mask, "node_mask": node_mask,
            "n_seeds": len(seeds)}
