"""The paper's corpus/query format + a synthetic retrieval dataset.

Asyncval §3: every line is ``{"text_id": str, "text": List[int]}`` — texts are
*pre-tokenized* (reason 1: custom tokenizers; reason 2: tokenize once, not per
checkpoint).  We keep that format exactly.

The synthetic dataset is a topic model designed so that (a) a small DR trained
with in-batch negatives actually learns it, (b) a lexical-overlap scorer is a
meaningful "BM25" stand-in, and (c) an oracle-plus-noise scorer provides a
tunable-strength "strong DR" baseline (TCT-ColBERTv2 stand-in) — everything
the paper's Figure-2 fidelity study needs, CPU-sized.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple

import numpy as np

Tokens = List[int]


def write_jsonl(path: str, texts: Dict[str, Tokens]) -> None:
    with open(path, "w") as f:
        for tid, toks in texts.items():
            f.write(json.dumps({"text_id": str(tid),
                                "text": [int(t) for t in toks]}) + "\n")


def read_jsonl(path: str) -> Dict[str, Tokens]:
    out: Dict[str, Tokens] = {}
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            obj = json.loads(line)
            out[str(obj["text_id"])] = list(obj["text"])
    return out


def pad_batch(token_lists: List[Tokens], max_len: int,
              pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """-> (tokens (B, max_len) int32, mask (B, max_len) bool)."""
    B = len(token_lists)
    toks = np.full((B, max_len), pad_id, np.int32)
    mask = np.zeros((B, max_len), bool)
    for i, t in enumerate(token_lists):
        t = t[:max_len]
        toks[i, :len(t)] = t
        mask[i, :len(t)] = True
    return toks, mask


@dataclasses.dataclass
class RetrievalDataset:
    corpus: Dict[str, Tokens]            # docid -> tokens
    queries: Dict[str, Tokens]           # qid -> tokens
    qrels: Dict[str, Dict[str, int]]     # qid -> {docid: gain}
    doc_topic: Dict[str, int]
    query_topic: Dict[str, int]
    vocab: int
    n_topics: int


def synthetic_retrieval_dataset(seed: int, *, n_passages: int = 2000,
                                n_queries: int = 100, vocab: int = 503,
                                n_topics: int = 25, p_len: int = 24,
                                q_len: int = 8, topic_frac_p: float = 0.5,
                                topic_frac_q: float = 0.7) -> RetrievalDataset:
    rng = np.random.default_rng(seed)
    # reserve 0=pad, 1=CLS; topic blocks partition part of the vocab
    common_lo, common_hi = 2, vocab // 3
    block = (vocab - common_hi) // n_topics
    assert block >= 2, "vocab too small for n_topics"

    def topic_tokens(t, n, frac):
        lo = common_hi + t * block
        choose_topic = rng.random(n) < frac
        toks = np.where(choose_topic,
                        rng.integers(lo, lo + block, n),
                        rng.integers(common_lo, common_hi, n))
        return toks.astype(np.int32).tolist()

    corpus, doc_topic = {}, {}
    for i in range(n_passages):
        t = int(rng.integers(n_topics))
        corpus[f"d{i}"] = [1] + topic_tokens(t, p_len - 1, topic_frac_p)
        doc_topic[f"d{i}"] = t

    # ensure every topic has at least a few docs
    queries, qrels, query_topic = {}, {}, {}
    by_topic: Dict[int, List[str]] = {}
    for d, t in doc_topic.items():
        by_topic.setdefault(t, []).append(d)
    topics_avail = [t for t, ds in by_topic.items() if ds]
    for i in range(n_queries):
        t = int(topics_avail[int(rng.integers(len(topics_avail)))])
        qid = f"q{i}"
        queries[qid] = [1] + topic_tokens(t, q_len - 1, topic_frac_q)
        gold = by_topic[t][int(rng.integers(len(by_topic[t])))]
        qrels[qid] = {gold: 1}
        query_topic[qid] = t
    return RetrievalDataset(corpus=corpus, queries=queries, qrels=qrels,
                            doc_topic=doc_topic, query_topic=query_topic,
                            vocab=vocab, n_topics=n_topics)


def lexical_baseline_run(ds: RetrievalDataset, k: int = 100, *,
                         drop_frac: float = 0.0,
                         seed: int = 0) -> Dict[str, List[tuple]]:
    """BM25 stand-in: idf-weighted token-overlap scores.

    ``drop_frac`` drops that fraction of each query's tokens before scoring —
    the vocabulary-mismatch failure mode that separates lexical retrievers
    from dense ones.  Dropped topical tokens make the run miss some
    same-topic documents entirely (exactly the hard negatives a trained DR
    confuses), so subsets induced from a dropped-token run track the
    full-corpus validation curve strictly *worse* than subsets from the
    topic-oracle run — the quality gap the paper's Figure-2 "stronger
    baselines track closer" claim needs.  ``drop_frac=0`` (default) is the
    original noiseless scorer."""
    rng = np.random.default_rng(seed)
    df = {}
    for toks in ds.corpus.values():
        for t in set(toks):
            df[t] = df.get(t, 0) + 1
    n_docs = len(ds.corpus)
    idf = {t: np.log(1 + n_docs / c) for t, c in df.items()}
    doc_sets = {d: set(toks) for d, toks in ds.corpus.items()}
    run = {}
    for qid, qtoks in ds.queries.items():
        qset = set(qtoks)
        if drop_frac > 0.0:
            qset = {t for t in qset if rng.random() >= drop_frac}
        scored = []
        for d, dset in doc_sets.items():
            overlap = qset & dset
            if overlap:
                scored.append((d, float(sum(idf.get(t, 0.0) for t in overlap))))
        scored.sort(key=lambda x: -x[1])
        run[qid] = scored[:k]
    return run


def oracle_noisy_baseline_run(ds: RetrievalDataset, noise: float, seed: int = 0,
                              k: int = 100, *,
                              overlap_weight: float = 0.0
                              ) -> Dict[str, List[tuple]]:
    """Tunable-strength DR baseline: topic-match oracle + Gaussian noise.
    noise≈0.3 behaves like a strong DR (TCT-ColBERTv2 stand-in); noise≈1.5
    approaches the lexical baseline's quality.

    ``overlap_weight`` > 0 adds an idf-weighted token-overlap term (scaled to
    [0, overlap_weight]) under the topic oracle, making the run *DR-like*
    rather than merely topic-aware: within (and across) topics it prefers
    the lexically-closest documents — the same documents a trained
    bag-of-embeddings DR scores highest.  Subsets induced from such a run
    contain the DR's actual hard negatives, which is what makes strong
    baselines track the full-corpus validation curve closer (paper Fig. 2);
    with the default 0.0 the within-topic order is pure noise and that
    claim degenerates to a coin flip on small corpora."""
    rng = np.random.default_rng(seed)
    docs = list(ds.corpus)
    doc_t = np.array([ds.doc_topic[d] for d in docs])
    overlap = np.zeros(len(docs))
    run = {}
    if overlap_weight > 0.0:
        df: Dict[int, int] = {}
        for toks in ds.corpus.values():
            for t in set(toks):
                df[t] = df.get(t, 0) + 1
        idf = {t: np.log(1 + len(docs) / c) for t, c in df.items()}
        doc_sets = [set(ds.corpus[d]) for d in docs]
    for qid in ds.queries:
        if overlap_weight > 0.0:
            qset = set(ds.queries[qid])
            raw = np.array([sum(idf.get(t, 0.0) for t in qset & dset)
                            for dset in doc_sets])
            overlap = overlap_weight * raw / max(raw.max(), 1e-9)
        base = (doc_t == ds.query_topic[qid]).astype(np.float64)
        scores = base + overlap + noise * rng.standard_normal(len(docs))
        order = np.argsort(-scores)[:k]
        run[qid] = [(docs[i], float(scores[i])) for i in order]
    return run
