"""Synthetic batch generators — one per architecture family.

Used by smoke tests, examples and the CPU end-to-end drivers.  Dry-run input
*specs* (ShapeDtypeStructs, no allocation) live in ``repro.launch.specs``;
these functions produce real (small) arrays.
"""

from __future__ import annotations

import numpy as np


def lm_batch(rng: np.random.Generator, vocab: int, batch: int, seq: int):
    return {"tokens": rng.integers(1, vocab, size=(batch, seq), dtype=np.int32)}


def biencoder_batch(rng, vocab: int, batch: int, q_len: int, p_len: int,
                    n_psg: int = 2):
    return {
        "q_tokens": rng.integers(1, vocab, size=(batch, q_len), dtype=np.int32),
        "q_mask": np.ones((batch, q_len), bool),
        "p_tokens": rng.integers(1, vocab, size=(batch, n_psg, p_len),
                                 dtype=np.int32),
        "p_mask": np.ones((batch, n_psg, p_len), bool),
    }


def graph_batch(rng, n_nodes: int, n_edges: int, d_feat: int, n_vars: int):
    src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    return {
        "node_feat": rng.standard_normal((n_nodes, d_feat), np.float32),
        "src": src, "dst": dst,
        "target": rng.standard_normal((n_nodes, n_vars), np.float32),
    }


def batched_molecule_graphs(rng, n_graphs: int, nodes_per: int, edges_per: int,
                            d_feat: int, n_vars: int):
    """Block-diagonal batching of small graphs into one disjoint graph."""
    N, E = n_graphs * nodes_per, n_graphs * edges_per
    offs = np.repeat(np.arange(n_graphs) * nodes_per, edges_per)
    src = rng.integers(0, nodes_per, size=E).astype(np.int32) + offs.astype(np.int32)
    dst = rng.integers(0, nodes_per, size=E).astype(np.int32) + offs.astype(np.int32)
    return {
        "node_feat": rng.standard_normal((N, d_feat), np.float32),
        "src": src, "dst": dst,
        "target": rng.standard_normal((N, n_vars), np.float32),
    }


def sasrec_batch(rng, item_vocab: int, batch: int, seq: int, n_neg: int):
    hist = rng.integers(1, item_vocab, size=(batch, seq), dtype=np.int32)
    pos = rng.integers(1, item_vocab, size=(batch, seq), dtype=np.int32)
    # left-pad some sequences to exercise masking
    lens = rng.integers(1, seq + 1, size=batch)
    for i, L in enumerate(lens):
        hist[i, L:] = 0
        pos[i, L:] = 0
    return {"hist": hist, "pos": pos,
            "neg_ids": rng.integers(1, item_vocab, size=n_neg, dtype=np.int32)}


def bert4rec_batch(rng, item_vocab: int, batch: int, seq: int, n_mask: int,
                   n_neg: int):
    tokens = rng.integers(2, item_vocab, size=(batch, seq), dtype=np.int32)
    pos = np.stack([rng.choice(seq, size=n_mask, replace=False)
                    for _ in range(batch)]).astype(np.int32)
    labels = np.take_along_axis(tokens, pos, axis=1)
    mask_token = 1
    for i in range(batch):
        tokens[i, pos[i]] = mask_token
    return {"tokens": tokens, "mlm_positions": pos, "mlm_labels": labels,
            "mlm_mask": np.ones((batch, n_mask), bool),
            "neg_ids": rng.integers(2, item_vocab, size=n_neg, dtype=np.int32)}


def mind_batch(rng, item_vocab: int, batch: int, seq: int, n_neg: int):
    return {"hist": rng.integers(1, item_vocab, size=(batch, seq), dtype=np.int32),
            "target": rng.integers(1, item_vocab, size=batch, dtype=np.int32),
            "neg_ids": rng.integers(1, item_vocab, size=n_neg, dtype=np.int32)}


def deepfm_batch(rng, field_vocabs, batch: int, max_hot: int):
    F = len(field_vocabs)
    offsets = np.concatenate([[0], np.cumsum(field_vocabs)[:-1]])
    ids = np.zeros((batch, F, max_hot), np.int32)
    valid = np.zeros((batch, F, max_hot), bool)
    for f, (v, off) in enumerate(zip(field_vocabs, offsets)):
        ids[:, f] = rng.integers(0, v, size=(batch, max_hot)) + off
        valid[:, f, 0] = True
        if max_hot > 1:
            valid[:, f, 1:] = rng.random((batch, max_hot - 1)) < 0.3
    return {"ids": ids, "valid": valid,
            "label": (rng.random(batch) < 0.3).astype(np.float32)}
