"""Walkthrough: training with the convergence control plane in the loop.

The paper's async validator answers "how good is checkpoint N?" — this
example shows the *feedback* half: the validation ledger driving decisions
back at the run, without validation ever touching the training hot path.

What happens, end to end:

  1. A trainer commits two-phase checkpoints every ``--ckpt-every`` steps
     and polls a STOP marker file between steps (``TrainerConfig.stop_file``
     — one ``os.path.exists`` per step, never a wait on validation).
  2. An ``AsyncValidator`` on its own thread validates each checkpoint and
     appends a ledger row; its ``controller=`` hook hands every row to the
     :class:`repro.control.ControlPlane`:
       * ``CheckpointSelector`` re-ranks checkpoints by MRR@10 and prunes
         storage to the top-k ∪ still-unvalidated (quality-aware GC);
       * ``EarlyStopController`` watches for a plateau (patience/min-delta)
         or a widening train-vs-validation gap (history-based overfit
         detection) and atomically publishes the STOP marker;
       * every decision lands in ``control.jsonl`` — replayable offline
         with :func:`repro.control.replay_ledger`.
  3. The trainer notices the marker and halts early.
  4. The top-k surviving checkpoints are greedy-souped into a *virtual*
     checkpoint (Checkpoint Ensembles), committed through the ordinary
     two-phase ``ckpt.save``, and re-validated through the exact same
     watcher -> validator -> ledger path as any trained checkpoint.

    PYTHONPATH=src python examples/train_with_control.py

Expect: training stops well before the step budget, only the best
checkpoints survive on disk, and the ensemble scores at least as well as
the best single checkpoint.
"""

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import run  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--steps", type=int, default=400)
    args_in = ap.parse_args()
    workdir = args_in.workdir or tempfile.mkdtemp(prefix="asyncval_control_")

    class Args:
        arch = "dr-bert-base"
        steps = args_in.steps              # budget CAP — expect to stop early
        ckpt_every = 10
        batch_size = 8
        corpus_size = 150
        n_queries = 25
        q_max_len = 10
        p_max_len = 26
        depth = 15
        lr = 2e-3
        seed = 0
        subset = True
        sync = False
        full = False
        # control plane
        early_stop_patience = 3
        early_stop_min_delta = 1e-4
        overfit_window = 0                 # plateau detection only
        keep_top_k = 3
        ensemble_top_k = 3
        policy = "budget"                  # stride self-tunes to val latency
        stride = 1

    Args.workdir = workdir
    res = run(Args())

    print("\n=== control plane walkthrough ===")
    print(f"stopped early : {res['stopped_early']} "
          f"(verdict: {res['stop_verdict']})")
    print(f"trained steps : {max(res['validated_steps'] or [0])} "
          f"of a {Args.steps}-step budget")
    print(f"best step     : {res['best_step']}")
    print(f"ckpts on disk : {res['kept_checkpoints']} (top-k ∪ protected)")
    if res["ensemble"]:
        print(f"ensemble      : step {res['ensemble']['step']} = soup of "
              f"{res['ensemble']['members']} -> {res['ensemble']['metrics']}")
    with open(os.path.join(workdir, "control.jsonl")) as f:
        kinds = [json.loads(l)["kind"] for l in f if l.strip()]
    print(f"decision log  : {len(kinds)} events "
          f"({', '.join(sorted(set(kinds)))}) in {workdir}/control.jsonl")


if __name__ == "__main__":
    main()
