"""Distributed validation internals: sharded exact MIPS + straggler-tolerant
chunked corpus encoding — the pieces that turn the paper's single-GPU
validator into a pod-scale one.

Runs on 8 simulated host devices (re-execs itself with XLA_FLAGS).

    PYTHONPATH=src python examples/distributed_validation.py
"""

import os
import sys

if os.environ.get("XLA_FLAGS", "").find("host_platform_device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.retrieval import topk_exact, topk_sharded
from repro.distributed import compat
from repro.distributed.fault import run_chunked


def main():
    assert len(jax.devices()) == 8, "expected 8 simulated devices"
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    Q, N, D, k = 16, 40_000, 64, 100
    q = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)

    # -- sharded exact MIPS: row-sharded corpus, hierarchical top-k merge --
    s_ref, i_ref = topk_exact(q, c, k=k)
    s_sh, i_sh = topk_sharded(mesh, q, c, k=k)
    agree = float((np.asarray(i_sh) == np.asarray(i_ref)).mean())
    print(f"[distributed] sharded top-{k} over {N} rows x 8 devices: "
          f"index agreement with single-device = {agree:.4f}")
    assert agree > 0.99

    # -- straggler-tolerant chunked encode ---------------------------------
    # one worker is 10x slower; speculation hides it.
    def encode_chunk(idxs):
        return np.asarray(c)[idxs].sum(axis=1)        # stand-in for encode

    items = list(range(N))
    chunks = [items[i:i + 2500] for i in range(0, N, 2500)]

    delays = {"w0": 0.02}                              # w0 is the straggler
    t0 = time.time()
    out = run_chunked(items, encode_chunk, n_workers=4, over_factor=4,
                      worker_delay=lambda w: delays.get(w, 0.0))
    dt = time.time() - t0
    total = sum(len(o) for o in out)
    print(f"[distributed] chunked encode of {total} items with a 1-in-4 "
          f"straggler + speculation: {dt:.2f}s, results exact = "
          f"{total == N}")
    assert total == N


if __name__ == "__main__":
    main()
