"""Quickstart — the paper's closed-loop protocol end to end (§3).

The user contract, exactly as in Asyncval:
  1. corpus + validation queries as pre-tokenized JSONL
     ({"text_id": str, "text": [int]}),
  2. a TREC qrel file,
  3. an Encoder implementation (here: the JAX EncoderSpec twin),
and the toolkit owns everything else: directory watching, corpus encoding,
retrieval, metrics, reporting.

    PYTHONPATH=src python examples/quickstart.py

Fleet validation: this example runs ONE validator; to scale validation
across N (possibly heterogeneous) workers, the same ledger doubles as a
claimable (step, task) work queue — run N copies of
``python -m repro.core.cli --worker`` against one checkpoint dir (or
``python -m repro.launch.fleet --workers N -- <worker argv>``), and see
``examples/fleet_validation.py`` for the full walkthrough: 1 trainer +
2 capability-tagged workers + control plane, with crash-safe lease
reclaim and byte-identical offline replay of every fleet decision.

Lazy hand-off: pass ``--handoff`` to ``python -m repro.launch.train`` to
validate each checkpoint from a host-resident snapshot the moment the
device->host copy lands — before the durable save commits — with
bit-identical verdicts (add ``--handoff-spool DIR`` to share snapshots
with ``repro.core.cli --handoff_spool DIR`` validator processes); see
``examples/lazy_handoff.py`` for the measured snapshot-vs-durable
verdict latency gap.
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from benchmarks.common import toy_spec, train_toy_dr
from repro.ckpt import checkpoint as ckpt
from repro.core.metrics import read_trec_qrels
from repro.core.reporting import CSVLogger
from repro.core.samplers import RunFileTopK, write_subset_jsonl
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import AsyncValidator
from repro.data import corpus as corpus_lib


def main():
    workdir = tempfile.mkdtemp(prefix="asyncval_quickstart_")
    print(f"[quickstart] workdir: {workdir}")

    # -- 1. user-side data prep: pre-tokenized JSONL + TREC qrels ----------
    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=1200,
                                                n_queries=60)
    corpus_path = os.path.join(workdir, "corpus.jsonl")
    query_path = os.path.join(workdir, "queries.jsonl")
    qrel_path = os.path.join(workdir, "qrels.txt")
    corpus_lib.write_jsonl(corpus_path, ds.corpus)
    corpus_lib.write_jsonl(query_path, ds.queries)
    with open(qrel_path, "w") as f:
        for qid, docs in ds.qrels.items():
            for did, gain in docs.items():
                f.write(f"{qid} 0 {did} {gain}\n")

    # -- 2. the splitter (paper: python -m asyncval.splitter) --------------
    baseline = corpus_lib.lexical_baseline_run(ds, k=100)
    subset = RunFileTopK(depth=20).sample(list(ds.corpus), baseline, ds.qrels)
    write_subset_jsonl(subset, ds.corpus, os.path.join(workdir,
                                                       "subset.jsonl"))
    print(f"[quickstart] splitter: {len(ds.corpus)} passages -> "
          f"{subset.size} in the depth-20 subset")

    # -- 3. train, dropping checkpoints into --ckpts_dir -------------------
    spec = toy_spec(ds.vocab)
    ckdir = os.path.join(workdir, "ckpts")
    _, snapshots = train_toy_dr(ds, spec, steps=60, snapshot_every=20)
    for step, params in snapshots:
        ckpt.save(ckdir, step, {"params": params})

    # -- 4. the closed loop: watch -> stream encode→top-k -> report --------
    # The public API is the ValidationSuite: a list of ValidationTasks (one
    # here — add more to validate several query sets / corpora per
    # checkpoint in one pass, sharing TokenStores).  The default
    # engine="streaming" fuses corpus encoding with the running top-k on
    # device, chunk by chunk: the (N, D) embedding matrix is never
    # materialized, so the corpus can outgrow host RAM.  chunk_size sets the
    # streaming granularity (defaults to batch_size).  score_dtype
    # ("f32" default | "bf16" | "int8", CLI: --score_dtype) quantizes only
    # the SCORING matmul — bf16 halves / int8 quarters the embedding bytes
    # the top-k stage moves; precision is a fidelity knob exactly like
    # subset depth (recorded per ledger row, rank-correlation measured in
    # benchmarks/bench_fidelity.py), never a silent default.
    corpus = corpus_lib.read_jsonl(corpus_path)       # round-trip the files
    queries = corpus_lib.read_jsonl(query_path)
    qrels = read_trec_qrels(qrel_path)
    suite = ValidationSuite(spec, [
        ValidationTask("default", corpus, queries, qrels,
                       sampler=RunFileTopK(depth=20), baseline_run=baseline,
                       metrics=("MRR@10", "Recall@100"), k=100),
    ], ValidationConfig(metrics=("MRR@10", "Recall@100"), k=100,
                        batch_size=128, engine="streaming", chunk_size=128,
                        write_run=True,
                        output_dir=os.path.join(workdir, "runs")))
    engine = suite.engine("default")
    print(f"[quickstart] engine: {engine.name} "
          f"({engine.doc_store.n_chunks} corpus chunks of "
          f"{engine.doc_store.chunk})")
    validator = AsyncValidator(
        ckdir, suite, logger=CSVLogger(os.path.join(workdir, "metrics.csv")),
        ledger_path=os.path.join(workdir, "ledger.jsonl"))
    n = validator.validate_pending()

    print(f"[quickstart] validated {n} checkpoints:")
    for r in validator.results:
        print(f"  step {r.step:>4}: MRR@10={r.metrics['MRR@10']:.4f} "
              f"Recall@100={r.metrics['Recall@100']:.4f} "
              f"({r.timings['total_s']:.2f}s on {r.subset_size} passages)")
    best = max(validator.results, key=lambda r: r.metrics["MRR@10"])
    print(f"[quickstart] best checkpoint: step {best.step} "
          f"(MRR@10={best.metrics['MRR@10']:.4f})")
    print(f"[quickstart] metrics CSV + TREC runs under {workdir}")
    assert validator.results[-1].metrics["MRR@10"] > \
        validator.results[0].metrics["MRR@10"], "training should help"


if __name__ == "__main__":
    main()
