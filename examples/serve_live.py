"""Live serving walkthrough — train, validate, promote, answer. One process.

The full Asyncval loop with the PR-8 serving tier closed over it:

  * a **trainer** thread runs real contrastive steps and commits a
    checkpoint every N steps (``ckpt.save``'s two-phase commit);
  * an **async validator** scores each committed checkpoint and feeds a
    :class:`ControlPlane` that ranks them (selection events);
  * a **promoter** follows the control plane's live best pick and
    hot-swaps the serving index in two phases — build off to the side,
    verify, atomic pointer flip — so the query path never blocks;
  * a **client** thread hammers :meth:`QueryService.submit` the whole
    time; every answer it gets attributes exactly one promoted
    checkpoint, scored through the validator's own encode/top-k path
    (bitwise the numbers the ledger records — see
    tests/test_serve_parity.py);
  * checkpoint **GC** runs with the serving tier's ``protect_set`` so
    the live index's backing checkpoint is never deleted out from under
    a restart.

    PYTHONPATH=src python examples/serve_live.py
"""

import os
import sys
import tempfile
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import contrastive_step, toy_spec
from repro.ckpt import checkpoint as ckpt
from repro.control import ControlConfig, ControlPlane
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import AsyncValidator
from repro.data import corpus as corpus_lib
from repro.serve import (AdmissionController, IndexBuilder, Promoter,
                         QueryService, ServeConfig, replay_swaps)

N_CKPTS = 3
STEPS_PER_CKPT = 20


def main():
    workdir = tempfile.mkdtemp(prefix="asyncval_serve_live_")
    ckdir = os.path.join(workdir, "ckpts")
    print(f"[serve-live] workdir: {workdir}")

    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=600,
                                                n_queries=30)
    spec = toy_spec(ds.vocab)

    # -- validation + control: rank every committed checkpoint ------------
    suite = ValidationSuite(spec, [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels),
    ], ValidationConfig(metrics=("MRR@10",), k=10, batch_size=64))
    control = ControlPlane(
        ckdir, ControlConfig(metric="MRR@10", mode="max", keep_top_k=2),
        event_path=os.path.join(workdir, "control.jsonl"))

    # -- serving tier: same spec, same corpus, same scoring knobs ----------
    builder = IndexBuilder(spec, ds.corpus,
                           ServeConfig(k=10, batch_size=64))
    service = QueryService(spec, k=10, max_batch=8, flush_ms=2.0,
                           admission=AdmissionController(max_pending=256))
    promoter = Promoter(builder, service, ckdir,
                        target_fn=lambda: control.selector.best_step,
                        log=os.path.join(workdir, "serve.jsonl"))
    validator = AsyncValidator(ckdir, suite, controller=control,
                               ledger_path=os.path.join(workdir,
                                                        "ledger.jsonl"),
                               extra_protect=promoter.protect_set)

    # -- trainer thread: real contrastive steps, committed on a cadence ---
    def trainer():
        params = spec.init(jax.random.PRNGKey(0))
        step_fn = contrastive_step(spec)
        rng = np.random.default_rng(0)
        qids = sorted(ds.qrels)
        step = 0
        for _ in range(N_CKPTS):
            for _ in range(STEPS_PER_CKPT):
                step += 1
                pick = rng.choice(len(qids), size=32)
                q_tok = [ds.queries[qids[j]] for j in pick]
                p_tok = [ds.corpus[next(iter(ds.qrels[qids[j]]))]
                         for j in pick]
                qt, qm = corpus_lib.pad_batch(q_tok, spec.q_max_len)
                pt, pm = corpus_lib.pad_batch(p_tok, spec.p_max_len)
                params, _ = step_fn(
                    params, {"q_tokens": jnp.asarray(qt),
                             "q_mask": jnp.asarray(qm),
                             "p_tokens": jnp.asarray(pt),
                             "p_mask": jnp.asarray(pm)})
            ckpt.save(ckdir, step, {"params": params})
            print(f"[trainer] committed step {step}")

    # -- client thread: queries never stop while indexes swap under them --
    stop = threading.Event()
    responses, drops = [], []

    def client():
        qids = list(ds.queries)
        j = 0
        while not stop.is_set():
            if service.live is None:       # nothing promoted yet
                time.sleep(0.01)
                continue
            qid = qids[j % len(qids)]
            j += 1
            try:
                responses.append(service.submit(qid, ds.queries[qid],
                                                timeout=30))
            except BaseException as e:
                drops.append(repr(e))
                return

    service.start()
    t_train = threading.Thread(target=trainer)
    t_client = threading.Thread(target=client)
    t_train.start()
    t_client.start()

    # -- drive the loop: validate what lands, promote what wins -----------
    deadline = time.monotonic() + 120
    validated = set()
    while time.monotonic() < deadline:
        validator.validate_pending()
        for r in validator.results:
            if r.step not in validated:
                validated.add(r.step)
                print(f"[validator] step {r.step}: "
                      f"MRR@10={r.metrics['MRR@10']:.4f}")
        if promoter.poll_once():
            print(f"[promoter] hot-swap -> step {service.live_step()} "
                  f"(protects {sorted(promoter.protect_set())})")
        if not t_train.is_alive() and len(validated) >= N_CKPTS \
                and service.live_step() == control.selector.best_step:
            break
        time.sleep(0.05)
    time.sleep(0.5)          # let the client serve against the final pick
    stop.set()
    t_train.join()
    t_client.join()
    service.stop()

    # -- GC with the serving tier protected --------------------------------
    removed = ckpt.gc_checkpoints(ckdir, keep_last=1,
                                  protect=validator.protect_set())
    live = service.live_step()
    print(f"[gc] removed {sorted(removed)}; live step {live} survives: "
          f"{live in ckpt.list_steps(ckdir)}")

    # -- the audit: every answer came from a then-promoted checkpoint ------
    swaps = replay_swaps(os.path.join(workdir, "serve.jsonl"))
    promoted = {s["step"] for s in swaps}
    served = {r.step for r in responses}
    print(f"[audit] {len(responses)} responses, {len(drops)} drops, "
          f"swap timeline {[s['step'] for s in swaps]}, "
          f"served steps {sorted(served)}")
    assert not drops, drops
    assert served <= promoted, served - promoted
    assert live in ckpt.list_steps(ckdir), "GC deleted the live checkpoint"
    print("[serve-live] OK — zero-downtime promotion, full attribution")


if __name__ == "__main__":
    main()
