"""Multi-task validation — several validation sets, one checkpoint pass.

"Bridging the Training-Inference Gap for Dense Phrase Retrieval" (Cho et
al. 2022) validates checkpoints against *multiple* efficient validation
sets and picks the checkpoint that transfers.  The ValidationSuite is that
protocol on Asyncval's asynchronous loop:

  * two tasks ("dev" and "heldout" query splits) over the SAME corpus —
    the suite pads the corpus TokenStore exactly ONCE and both engines
    stream it (``suite.store_builds == 1``);
  * the async validator writes one ledger row per (step, task);
  * the control plane selects and early-stops on a composite metric spec:
    the weighted aggregate ``0.5*dev:MRR@10 + 0.5*heldout:MRR@10``.

    PYTHONPATH=src python examples/multi_task_validation.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import toy_spec, train_toy_dr
from repro.ckpt import checkpoint as ckpt
from repro.control import ControlConfig, ControlPlane
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import AsyncValidator
from repro.data import corpus as corpus_lib


def split_queries(ds, frac=0.5):
    """Two disjoint (queries, qrels) splits over one corpus."""
    qids = sorted(ds.queries)
    cut = int(len(qids) * frac)
    mk = lambda ids: ({q: ds.queries[q] for q in ids},
                      {q: ds.qrels[q] for q in ids if q in ds.qrels})
    return mk(qids[:cut]), mk(qids[cut:])


def main():
    workdir = tempfile.mkdtemp(prefix="asyncval_multitask_")
    print(f"[multi-task] workdir: {workdir}")

    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=900,
                                                n_queries=80)
    (dev_q, dev_qrels), (ho_q, ho_qrels) = split_queries(ds)

    # -- train, committing checkpoints --------------------------------------
    spec = toy_spec(ds.vocab)
    ckdir = os.path.join(workdir, "ckpts")
    _, snapshots = train_toy_dr(ds, spec, steps=80, snapshot_every=10)
    for step, params in snapshots:
        ckpt.save(ckdir, step, {"params": params})

    # -- the suite: two tasks, one shared corpus store ----------------------
    suite = ValidationSuite(spec, [
        ValidationTask("dev", ds.corpus, dev_q, dev_qrels,
                       metrics=("MRR@10", "Recall@100"), k=100),
        ValidationTask("heldout", ds.corpus, ho_q, ho_qrels,
                       metrics=("MRR@10",), k=100),
    ], ValidationConfig(batch_size=128, chunk_size=128))
    suite.engine("dev"), suite.engine("heldout")    # build both engines
    assert suite.store_builds == 1, "same corpus -> ONE TokenStore build"
    print(f"[multi-task] 2 tasks share {suite.store_builds} corpus "
          f"TokenStore ({suite.engine('dev').doc_store.n_chunks} chunks)")

    # -- control plane on a composite metric spec ---------------------------
    cmetric = "0.5*dev:MRR@10 + 0.5*heldout:MRR@10"
    control = ControlPlane(
        ckdir,
        ControlConfig(metric=cmetric, mode="max", keep_top_k=3,
                      early_stop=True, patience=3),
        stop_path=os.path.join(workdir, "STOP"),
        event_path=os.path.join(workdir, "control.jsonl"))

    validator = AsyncValidator(
        ckdir, suite, controller=control,
        ledger_path=os.path.join(workdir, "ledger.jsonl"))
    n = validator.validate_pending()

    print(f"[multi-task] validated {n} checkpoints x "
          f"{len(suite.task_names)} tasks:")
    for r in validator.results:
        agg = 0.5 * r.metrics["dev:MRR@10"] + 0.5 * r.metrics["heldout:MRR@10"]
        print(f"  step {r.step:>4}: dev={r.metrics['dev:MRR@10']:.4f} "
              f"heldout={r.metrics['heldout:MRR@10']:.4f} "
              f"composite={agg:.4f}")
    print(f"[multi-task] ledger rows are keyed (step, task): "
          f"{[(row['step'], row['task']) for row in validator.ledger.rows()][:4]} ...")
    print(f"[multi-task] best step by composite spec: "
          f"{control.selector.best_step} "
          f"(value {control.selector.best_value:.4f})")
    if control.stopped:
        print(f"[multi-task] early stop published: "
              f"{control.earlystop.reason} at step "
              f"{control.earlystop.stop_step}")
    assert all(len(validator.ledger.tasks_for(s)) == 2
               for s in validator.ledger.validated_steps)


if __name__ == "__main__":
    main()
