"""Validator fleet — N workers, one ledger work queue, one control plane.

Asyncval's core move is validating on "another GPU" so training never
pauses.  The fleet generalizes it: validation work is decomposed into
claimable **(step, task) work units** published to the ledger itself, and
any number of workers — possibly heterogeneous — claim, execute, and
record them.  Everything coordinating the fleet lives in ONE append-only
JSONL file (``repro.core.workqueue`` documents the claim-record schema),
so there is no coordinator service, crashes never lose correctness (a
dead worker's lease expires and a peer reclaims the unit), and the whole
decision history replays offline bit-for-bit.

This walkthrough runs the full topology in one process:

  * a **trainer** thread committing toy-DR checkpoints on a cadence;
  * a **fleet supervisor** publishing each committed step's units and
    pumping completed steps into a :class:`ControlPlane` (selection +
    early-stop + claim-aware checkpoint GC);
  * two **heterogeneous workers**: a full-fidelity worker that alone has
    the ``max_depth`` capability the "deep" task requires, and a smoke
    worker that can only run the cheap "dev" task — capability tags are
    matched against unit requirements at claim time.

    PYTHONPATH=src python examples/fleet_validation.py
"""

import os
import sys
import tempfile
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import toy_spec, train_toy_dr
from repro.ckpt import checkpoint as ckpt
from repro.control import ControlConfig, ControlPlane, replay_ledger
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import ValidationLedger, ValidatorWorker
from repro.core.workqueue import WorkQueue, replay
from repro.data import corpus as corpus_lib
from repro.launch.fleet import FleetSupervisor


def main():
    workdir = tempfile.mkdtemp(prefix="asyncval_fleet_")
    ckdir = os.path.join(workdir, "ckpts")
    ledger_path = os.path.join(workdir, "ledger.jsonl")
    print(f"[fleet] workdir: {workdir}")

    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=600,
                                                n_queries=60)
    spec = toy_spec(ds.vocab)

    # -- the suite: a cheap smoke task plus a deep task only SOME workers
    # are equipped for (requires flow into each unit's claim requirements)
    suite = ValidationSuite(spec, [
        ValidationTask("dev", ds.corpus, ds.queries, ds.qrels,
                       metrics=("MRR@10",), k=100),
        ValidationTask("deep", ds.corpus, ds.queries, ds.qrels,
                       metrics=("MRR@10", "Recall@100"), k=100,
                       requires={"max_depth": 100}),
    ], ValidationConfig(batch_size=128, chunk_size=128))
    suite.build_engines()

    # -- control plane: select on the deep metric, GC to top-3 ---------------
    control = ControlPlane(
        ckdir,
        ControlConfig(metric="deep:MRR@10", mode="max", keep_top_k=3),
        event_path=os.path.join(workdir, "control.jsonl"))

    # -- supervisor: publishes units, pumps completions, claim-aware GC ------
    sup = FleetSupervisor(ckdir, ledger_path, suite.task_names,
                          control=control, plan_units=suite.plan_units,
                          lease_ttl=32)

    # -- two heterogeneous workers ------------------------------------------
    def make_worker(worker_id, capabilities):
        queue = WorkQueue(ledger_path, worker_id,
                          capabilities=capabilities, lease_ttl=32)
        return ValidatorWorker(
            ckdir, suite,
            ledger=ValidationLedger(ledger_path,
                                    expected_tasks=suite.task_names),
            queue=queue, worker_id=worker_id)

    workers = [
        make_worker("full-0", {"mesh_size": 1, "max_depth": 200}),
        make_worker("smoke-0", {"mesh_size": 1}),   # cannot claim "deep"
    ]

    stop = threading.Event()

    def worker_loop(worker):
        while not stop.is_set():
            if not worker.run_once():
                time.sleep(0.02)

    threads = [threading.Thread(target=worker_loop, args=(w,), daemon=True)
               for w in workers]
    for t in threads:
        t.start()

    # -- the trainer: commit checkpoints while the fleet drains them ---------
    print("[fleet] training while 2 workers validate asynchronously...")
    _, snapshots = train_toy_dr(ds, spec, steps=60, snapshot_every=15)
    for step, params in snapshots:
        ckpt.save(ckdir, step, {"params": params})
        sup.run_once()                      # publish + pump + reap
    n_steps = len(snapshots)

    # -- drain: wait until every published step is fully validated -----------
    deadline = time.monotonic() + 300
    while time.monotonic() < deadline:
        sup.run_once()
        state = sup.queue.refresh()
        if len(state.completed_units()) == n_steps * 2:
            break
        time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join(timeout=10)

    # -- what happened -------------------------------------------------------
    led = ValidationLedger(ledger_path, expected_tasks=suite.task_names)
    print(f"[fleet] {len(led.validated_steps)} steps x "
          f"{len(suite.task_names)} tasks validated")
    by_worker = {}
    for row in led.rows():
        by_worker.setdefault(row["worker_id"], []).append(
            (row["step"], row["task"]))
    for wid, units in sorted(by_worker.items()):
        print(f"  {wid}: {len(units)} units -> {sorted(units)}")
    deep_workers = {row["worker_id"] for row in led.rows()
                    if row["task"] == "deep"}
    assert deep_workers == {"full-0"}, \
        "only the max_depth-capable worker may run the deep task"
    print(f"[fleet] best step by {control.cfg.metric}: "
          f"{control.selector.best_step} "
          f"(value {control.selector.best_value:.4f})")

    # -- the ledger IS the coordination record: replay it offline ------------
    state = replay(ledger_path, lease_ttl=32)
    assert state.completed_units() == sorted(
        (s, t) for s in led.validated_steps for t in suite.task_names)
    replayed = replay_ledger(led.rows(), control.cfg,
                             expected_tasks=suite.task_names,
                             group="completion")
    online = [e.to_json() for e in control.events.decisions()]
    offline = [e.to_json() for e in replayed.events.decisions()]
    assert online == offline, "fleet decisions must replay byte-identically"
    print(f"[fleet] {len(online)} control decisions replayed "
          f"byte-identically from the ledger")


if __name__ == "__main__":
    main()
