"""Paper Figure 1, live: the same train+validate workload run both ways.

  * sync  (Fig. 1a): training pauses for each checkpoint's validation.
  * async (Fig. 1b): a decoupled validator consumes checkpoints while
    training continues; total time collapses to ~train + last validation.

    PYTHONPATH=src python examples/async_vs_sync.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_async_schedule import run


def main():
    rows = run(n_ckpts=4, steps_per_ckpt=40, corpus_size=2500, n_queries=60,
               depth=60)
    sync = next(r for r in rows if r["mode"] == "sync")
    asyn = next(r for r in rows if r["mode"] == "async")
    print(f"{'mode':<8} {'total':>8} {'train':>8} {'validate':>9} "
          f"{'#validated':>10} {'final MRR@10':>13}")
    for r in rows:
        print(f"{r['mode']:<8} {r['total_s']:>7.2f}s {r['train_s']:>7.2f}s "
              f"{r['validate_s']:>8.2f}s {r['n_validated']:>10} "
              f"{r['mrr_last']:>13.4f}")
    print(f"\nasync speedup: {sync['total_s'] / asyn['total_s']:.2f}x "
          f"(paper Fig. 1: validation time hides behind training)")


if __name__ == "__main__":
    main()
