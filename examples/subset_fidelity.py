"""Paper Figure 2, live: subset-sampling fidelity across checkpoints.

Prints the MRR@10 validation curves (full corpus vs weak/strong-baseline
subsets at two depths) plus the fidelity statistics — rank correlation,
overestimation bias, best-checkpoint agreement.

    PYTHONPATH=src python examples/subset_fidelity.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_fidelity import run


def main():
    out = run()
    full = out["full"]["curve"]
    steps = list(range(len(full)))
    names = [k for k in out if k != "full"]
    print("MRR@10 per checkpoint (paper Fig. 2 left):")
    print(f"{'ckpt':>5} {'full':>8} " + " ".join(f"{n:>14}" for n in names))
    for i in steps:
        row = f"{i:>5} {full[i]:>8.4f} "
        row += " ".join(f"{out[n]['curve'][i]:>14.4f}" for n in names)
        print(row)
    print("\nfidelity vs full-corpus validation:")
    print(f"{'subset':>14} {'passages':>9} {'spearman':>9} {'overest.':>9} "
          f"{'best-agree':>10}")
    for n in names:
        r = out[n]
        print(f"{n:>14} {r['size']:>9} {r['spearman']:>9.3f} "
              f"{r['mean_delta']:>9.4f} {r['best_ckpt_agreement']:>10.0f}")
    print("\npaper claims reproduced: subsets preserve the checkpoint "
          "ranking,\noverestimate absolute MRR, and stronger-baseline "
          "subsets track the full curve closer (at depth 100).")


if __name__ == "__main__":
    main()
