"""Asyncval beyond text retrieval: validating a sequential recommender.

bert4rec/sasrec ARE dense retrievers over an item corpus — the
``retrieval_cand`` serving shape (one user against 1M items) is literally
the Asyncval validation step. This example trains a small SASRec,
checkpoints it, and validates every checkpoint with the SAME
watcher/validator machinery the paper uses for passage retrieval:
encode the item corpus with the checkpoint's item tower, retrieve top-k
per held-out user, score MRR@10 against the next-item "qrels".

    PYTHONPATH=src python examples/recsys_asyncval.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs import registry
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import AsyncValidator
from repro.models import nn
from repro.models import recsys as rcs
from repro.models.biencoder import EncoderSpec
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig

N_ITEMS = 300
SEQ = 12


def make_dataset(seed=0, n_users=400):
    """Markov-chain item sequences: item i tends to be followed by i+1
    (mod groups) — learnable next-item structure."""
    rng = np.random.default_rng(seed)
    seqs = []
    for _ in range(n_users):
        x = [int(rng.integers(1, N_ITEMS))]
        for _ in range(SEQ):
            nxt = x[-1] % (N_ITEMS - 1) + 1 if rng.random() < 0.8 \
                else int(rng.integers(1, N_ITEMS))
            x.append(nxt)
        seqs.append(x)
    return np.asarray(seqs, np.int32)          # (users, SEQ+1)


def main():
    workdir = tempfile.mkdtemp(prefix="recsys_asyncval_")
    cfg = registry.get("sasrec").smoke_config()
    cfg = dataclasses.replace(cfg, item_vocab=N_ITEMS, seq_len=SEQ,
                              n_negatives=64, compute_dtype=jnp.float32)
    seqs = make_dataset()
    train_seqs, valid_seqs = seqs[:320], seqs[320:]

    # ----- trainer: produces checkpoints ---------------------------------
    def batch_for(step):
        rng = np.random.default_rng(step)
        pick = rng.choice(len(train_seqs), 32)
        s = train_seqs[pick]
        return {"hist": jnp.asarray(s[:, :-1]), "pos": jnp.asarray(s[:, 1:]),
                "neg_ids": jnp.asarray(rng.integers(1, N_ITEMS, (64,)),
                                       jnp.int32)}

    params = nn.materialize(rcs.init(jax.random.PRNGKey(0), cfg))
    ckdir = os.path.join(workdir, "ckpts")
    trainer = Trainer(TrainerConfig(total_steps=120, ckpt_every=40,
                                    ckpt_dir=ckdir, async_save=False),
                      lambda p, b: rcs.loss_fn(p, cfg, b),
                      optim.adamw(3e-3), params, batch_for)

    # ----- the Asyncval mapping ------------------------------------------
    # corpus  = item ids (the "passages"); the item tower embeds them.
    # queries = held-out user histories; the user tower embeds them.
    # qrels   = the true next item per held-out user.
    corpus = {f"i{i}": [i] for i in range(1, N_ITEMS)}
    queries = {f"u{j}": valid_seqs[j, :-1].tolist()
               for j in range(len(valid_seqs))}
    qrels = {f"u{j}": {f"i{int(valid_seqs[j, -1])}": 1}
             for j in range(len(valid_seqs))}

    def encode_items(params, tokens, mask):
        ids = tokens[:, 0]
        return rcs.item_embeddings(params, cfg, ids)

    def encode_users(params, tokens, mask):
        u = rcs.user_embed(params, cfg, tokens, mask)
        return u / jnp.clip(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)

    spec = EncoderSpec(name="sasrec-dr", dim=cfg.embed_dim,
                       encode_query=encode_users,
                       encode_passage=encode_items,
                       init=lambda rng: rcs.init(rng, cfg),
                       q_max_len=SEQ, p_max_len=1)
    suite = ValidationSuite(spec, [
        ValidationTask("default", corpus, queries, qrels,
                       metrics=("MRR@10", "Recall@100"), k=100),
    ], ValidationConfig(metrics=("MRR@10", "Recall@100"), k=100,
                        batch_size=64))
    validator = AsyncValidator(ckdir, suite, poll_interval_s=0.05)

    validator.start()
    trainer.run()
    validator.stop(drain=True)

    print("[recsys-asyncval] SASRec checkpoints validated as a dense "
          "retriever over the item corpus:")
    for r in validator.results:
        print(f"  step {r.step:>4}: MRR@10={r.metrics['MRR@10']:.4f} "
              f"Recall@100={r.metrics['Recall@100']:.4f}")
    first, last = validator.results[0], validator.results[-1]
    assert last.metrics["MRR@10"] > first.metrics["MRR@10"], \
        "training should improve next-item retrieval"
    print("[recsys-asyncval] the paper's technique is architecture-"
          "agnostic: same watcher/pipeline, different towers.")


if __name__ == "__main__":
    main()
