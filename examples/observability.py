"""Checkpoint-lifecycle telemetry, end to end: trace a full asyncval run.

Every stage a checkpoint moves through — ``produced`` by the trainer,
``discovered`` by the watcher, ``published``/``claimed`` through the fleet
work queue, ``store_build``/``staged``/``encoded``/``scored``/``recorded``
inside validation, ``selected`` by the control plane, ``promoted`` and
``served`` by the serving tier — is recorded as a span or event in
per-process JSONL trace files (``repro.obs``), merged into a single
Chrome trace-event JSON you can open in Perfetto (https://ui.perfetto.dev)
or ``chrome://tracing``.

This walkthrough runs the whole topology in one process:

  * a real :class:`~repro.train.trainer.Trainer` committing toy-DR
    checkpoints (``produced`` events);
  * a fleet supervisor (watcher + control plane) publishing each step's
    units and selecting the best checkpoint;
  * two validator workers, each with its OWN tracer (distinct
    ``worker_id``) sharing one ledger work queue;
  * a serving tier promoting the control plane's pick and answering
    queries off it.

Afterwards it exports the merged Chrome trace, prints the per-stage
latency breakdown (inclusive + self time), and the metrics-registry
report with the headline checkpoint-to-verdict p50/p99 — the paper's
"how stale is validation?" number, continuously measured.

    PYTHONPATH=src python examples/observability.py
"""

import os
import sys
import tempfile
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from repro.control import ControlConfig, ControlPlane
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import (CKPT_TO_VERDICT_METRIC, ValidationLedger,
                                  ValidatorWorker)
from repro.core.workqueue import WorkQueue
from repro.data import corpus as corpus_lib
from repro.launch.fleet import FleetSupervisor
from repro.launch.train import _contrastive_batches
from repro.models import nn
from repro.models.biencoder import biencoder_spec, contrastive_loss
from repro.obs import LIFECYCLE_STAGES, MetricsRegistry, Telemetry
from repro.obs.export import breakdown_table, load_traces, write_chrome
from repro.serve import (AdmissionController, IndexBuilder, Promoter,
                         QueryService, ServeConfig)
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig
from repro.configs import registry


def main():
    workdir = tempfile.mkdtemp(prefix="asyncval_obs_")
    ckdir = os.path.join(workdir, "ckpts")
    ledger_path = os.path.join(workdir, "ledger.jsonl")
    print(f"[obs] workdir: {workdir}")

    # one shared registry: the trainer, supervisor, workers, and serving
    # tier all aggregate into the same --obs_report-style snapshot, while
    # each component writes its OWN trace file (merged at export time)
    registry_shared = MetricsRegistry()

    def telemetry(name):
        return Telemetry(os.path.join(workdir, f"trace_{name}.jsonl"),
                         registry=registry_shared, process=name,
                         attrs={"worker_id": name})

    tel_main = telemetry("main")

    # -- model + data --------------------------------------------------------
    arch = registry.get("dr-bert-base")
    cfg = arch.smoke_config()
    spec = biencoder_spec(cfg, q_max_len=12, p_max_len=28)
    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=400,
                                                n_queries=40,
                                                vocab=cfg.vocab_size)

    # -- two fleet workers, each tracing to its own file ---------------------
    def make_worker(wid):
        tel = telemetry(wid)
        vcfg = ValidationConfig(metrics=("MRR@10", "Recall@100"),
                                batch_size=32, telemetry=tel)
        suite = ValidationSuite(spec, [
            ValidationTask("default", ds.corpus, ds.queries, ds.qrels)],
            vcfg)
        queue = WorkQueue(ledger_path, wid, lease_ttl=32,
                          capabilities={"mesh_size": jax.device_count()},
                          telemetry=tel)
        return ValidatorWorker(
            ckdir, suite,
            ledger=ValidationLedger(ledger_path,
                                    expected_tasks=suite.task_names,
                                    telemetry=tel),
            queue=queue, worker_id=wid, telemetry=tel), suite, tel

    w0, suite, _ = make_worker("w0")
    w1, _, _ = make_worker("w1")

    # -- control plane + supervisor (watcher publishes, control selects) ----
    control = ControlPlane(
        ckdir, ControlConfig(metric="MRR@10", mode="max"),
        event_path=os.path.join(workdir, "control.jsonl"),
        telemetry=tel_main)
    sup = FleetSupervisor(ckdir, ledger_path, suite.task_names,
                          control=control, plan_units=suite.plan_units,
                          lease_ttl=32, telemetry=tel_main)

    stop = threading.Event()

    def worker_loop(worker):
        while not stop.is_set():
            if not worker.run_once():
                time.sleep(0.02)

    threads = [threading.Thread(target=worker_loop, args=(w,), daemon=True)
               for w in (w0, w1)]
    for t in threads:
        t.start()

    # -- train: the Trainer emits a `produced` event per commit --------------
    print("[obs] training while 2 traced workers validate asynchronously...")
    params = nn.materialize(spec.init(jax.random.PRNGKey(0)))
    trainer = Trainer(
        TrainerConfig(total_steps=40, ckpt_every=10, ckpt_dir=ckdir,
                      log_every=10, async_save=False),
        lambda p, b: contrastive_loss(p, spec, b),
        optim.adamw(2e-3), params,
        _contrastive_batches(ds, spec, 16), telemetry=tel_main)
    trainer.run()

    # -- drain the fleet backlog --------------------------------------------
    n_ckpts = 4
    deadline = time.monotonic() + 300
    while time.monotonic() < deadline:
        sup.run_once()                      # discover + publish + pump
        state = sup.queue.refresh()
        if len(state.completed_units()) == n_ckpts:
            break
        time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    sup.run_once()                          # pump the last completions

    # -- serve off the control plane's pick (promoted + served spans) -------
    service = QueryService(spec, k=10, max_batch=8,
                           admission=AdmissionController(64),
                           telemetry=tel_main)
    promoter = Promoter(
        IndexBuilder(spec, ds.corpus, ServeConfig(k=10, batch_size=32)),
        service, ckdir,
        target_fn=lambda: control.selector.best_step,
        log=os.path.join(workdir, "serve.jsonl"), telemetry=tel_main)
    assert promoter.poll_once(), "promotion of the selected step failed"
    responses = service.answer(sorted(ds.queries.items())[:16])
    print(f"[obs] served {len(responses)} queries from step "
          f"{service.live_step()} (best by {control.cfg.metric}: "
          f"step {control.selector.best_step})")

    # -- export: one merged Chrome trace over all four timelines -------------
    for w in (w0, w1):
        w.telemetry.flush()
    tel_main.flush()
    traces = sorted(
        os.path.join(workdir, f) for f in os.listdir(workdir)
        if f.startswith("trace_"))
    chrome = os.path.join(workdir, "lifecycle_trace.json")
    doc = write_chrome(traces, chrome)
    records = load_traces(traces)
    seen = {r["name"] for r in records}
    missing = [s for s in LIFECYCLE_STAGES if s not in seen]
    assert not missing, f"lifecycle stages missing from trace: {missing}"
    workers_tracing = {r.get("worker_id") for r in records
                       if r["name"] == "scored"}
    assert len(workers_tracing) >= 2, "expected scored spans from 2 workers"
    print(f"\n[obs] wrote {chrome} ({len(doc['traceEvents'])} events; "
          f"open in https://ui.perfetto.dev)")
    print(f"[obs] all {len(LIFECYCLE_STAGES)} lifecycle stages traced "
          f"across workers {sorted(workers_tracing)}\n")

    # -- per-stage latency breakdown (inclusive vs self time) ----------------
    print(breakdown_table(records))

    # -- the metrics report (what `repro.core.cli --obs_report` prints) ------
    # NB: fleet.* counters are per-HANDLE mirrors of the global ledger fold;
    # three queue handles (supervisor, w0, w1) share this registry, so they
    # read 3x the per-run unit count.  In the normal deployment each process
    # has its own registry and reports the global count once.
    print()
    print(registry_shared.render())
    hist = registry_shared.get(CKPT_TO_VERDICT_METRIC)
    print(f"\n[obs] checkpoint-to-verdict: p50={hist.percentile(50):.3f}s "
          f"p99={hist.percentile(99):.3f}s over {hist.count} verdicts")


if __name__ == "__main__":
    main()
