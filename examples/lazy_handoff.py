"""Lazy snapshot hand-off — validate checkpoints BEFORE they are durable.

Asyncval scores each checkpoint on "another GPU"; the classic hand-off is
the filesystem: the trainer's two-phase ``ckpt.save`` commits, and the
validator's watcher discovers the COMMIT marker on its next poll.  That
puts the durable serialization AND up to a poll interval between "the
params exist" and "a verdict exists".

The lazy hand-off (``repro.handoff``) removes both from the critical
path.  The trainer's async saver issues the device->host copies, and the
moment the host tree is materialized — before a single byte is fsync'd —
it publishes a :class:`ParamSnapshot` into a bounded
:class:`SnapshotChannel`.  The validator wakes on the publish, scores the
snapshot, and writes its ledger row with ``handoff="snapshot"``
provenance while the durable save is still racing in the background.

The contracts this walkthrough demonstrates:

  * **bit-parity** — re-validating the same step from its durable
    checkpoint reproduces the snapshot verdict bit-for-bit;
  * **training never blocks** — the channel applies drop-oldest-unclaimed
    backpressure; a slow validator costs verdicts (the watcher fallback
    scores the dropped steps later), never training throughput;
  * **durability gating** — selection/early-stop act on provisional
    snapshot-scored rows immediately, but the control plane defers
    irreversible actions (quality GC) until the step's save commits;
  * **the measured win** — the same checkpoint cadence is run twice, and
    the checkpoint-to-verdict latency (telemetry's
    ``validate.ckpt_to_verdict_s``) is printed for the watcher route vs
    the snapshot route.

    PYTHONPATH=src python examples/lazy_handoff.py

CLI equivalent: ``python -m repro.launch.train --handoff`` (add
``--handoff-spool DIR`` to spill snapshots for cross-process validator
workers, which read it via ``repro.core.cli --handoff_spool DIR``).
"""

import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import toy_spec, train_toy_dr
from repro.ckpt import checkpoint as ckpt
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import (CKPT_TO_VERDICT_METRIC, AsyncValidator,
                                  ValidationLedger, ValidatorWorker)
from repro.data import corpus as corpus_lib
from repro.handoff import ParamSnapshot, SnapshotChannel
from repro.obs import Telemetry


def build_suite(ds, spec):
    return ValidationSuite(spec, [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels),
    ], ValidationConfig(metrics=("MRR@10",), k=50, batch_size=64))


def run_route(snaps, ds, spec, *, handoff: bool):
    """Replay one checkpoint cadence through one hand-off route."""
    workdir = tempfile.mkdtemp(
        prefix=f"asyncval_{'handoff' if handoff else 'watcher'}_")
    ckdir = os.path.join(workdir, "ckpts")
    tel = Telemetry(None)       # metrics only — no trace file needed
    channel = SnapshotChannel(capacity=4, telemetry=tel) \
        if handoff else None
    validator = AsyncValidator(ckdir, build_suite(ds, spec),
                               poll_interval_s=0.05, telemetry=tel,
                               snapshots=channel)
    validator.start()
    saver = ckpt.AsyncSaver()
    try:
        for step, params in snaps:
            state = {"params": params}
            tel.mark("produced", step)
            if channel is not None:
                # exactly the trainer's async-saver hook wiring: publish
                # the host copy first, commit durably behind it
                saver.save(ckdir, step, state,
                           on_host_copy=lambda s, host: channel.publish(
                               ParamSnapshot.from_tree(s, host)),
                           on_durable=channel.mark_durable,
                           on_failure=channel.mark_failed)
            else:
                saver.save(ckdir, step, state)
            # wait the verdict out, like a trainer outpacing validation
            # would via the next training phase — each step's latency is
            # then the pure route cost, not queueing behind a backlog
            deadline = time.monotonic() + 60.0
            while step not in validator.ledger:
                if time.monotonic() > deadline:
                    raise RuntimeError(f"no verdict for step {step}")
                time.sleep(0.005)
        saver.wait()
    finally:
        validator.stop(drain=True)
    hist = tel.metrics.get(CKPT_TO_VERDICT_METRIC)
    p50 = hist.percentile(50) if hist is not None and hist.count else None
    return validator, workdir, ckdir, p50


def main():
    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=500,
                                                n_queries=50)
    spec = toy_spec(ds.vocab)
    # one training run, one checkpoint cadence — replayed through BOTH
    # routes so the latency comparison scores identical params
    _, snaps = train_toy_dr(ds, spec, steps=120, snapshot_every=30)
    snaps = [(s, p) for s, p in snaps if s > 0]
    print(f"[train] {len(snaps)} checkpoints on a 30-step cadence")

    # -- route 1: the classic watcher path (durable commit -> poll) --------
    v_watch, _, _, watcher_p50 = run_route(snaps, ds, spec, handoff=False)
    print(f"[watcher] {len(v_watch.results)} verdicts, "
          f"ckpt-to-verdict p50 = {watcher_p50:.3f}s")

    # -- route 2: the lazy snapshot hand-off -------------------------------
    v_hand, _, ckdir, handoff_p50 = run_route(snaps, ds, spec,
                                              handoff=True)
    rows = v_hand.ledger.rows()
    n_snap = sum(1 for r in rows if r.get("handoff") == "snapshot")
    print(f"[handoff] {len(v_hand.results)} verdicts "
          f"({n_snap} scored pre-durable), "
          f"ckpt-to-verdict p50 = {handoff_p50:.3f}s")

    # -- the measured win --------------------------------------------------
    gap = watcher_p50 / handoff_p50
    print(f"[handoff] verdict latency gap: {gap:.1f}x faster "
          f"({watcher_p50:.3f}s -> {handoff_p50:.3f}s)")

    # -- bit-parity: re-score one snapshot-validated step from its durable
    # checkpoint and compare verdicts exactly
    snap_steps = [r["step"] for r in rows
                  if r.get("handoff") == "snapshot"]
    if snap_steps:
        step = snap_steps[-1]
        suite = build_suite(ds, spec)
        worker = ValidatorWorker(
            ckdir, suite,
            ledger=ValidationLedger(None, expected_tasks=suite.task_names))
        durable = worker.run_step(step)
        snap_row = next(r for r in rows if r["step"] == step)
        assert durable.tasks["default"].metrics == snap_row["metrics"], \
            "snapshot verdict must be bit-identical to durable restore"
        print(f"[parity] step {step}: snapshot == durable "
              f"({snap_row['metrics']})")


if __name__ == "__main__":
    main()
