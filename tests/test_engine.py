"""Streaming ValidationEngine: TokenStore chunking, fused encode→top-k parity
with the materialized path (bit-for-bit against ``topk_exact``), rerank
streaming, pallas chunk-carry, sharded streaming, and engine injection."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as E
from repro.core import retrieval as R
from repro.core.encoder import encode_texts, jitted_encoder
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.samplers import QrelPool, RerankTopK, RunFileTopK
from repro.data import corpus as corpus_lib
from repro.models.biencoder import EncoderSpec

DIM = 16
VOCAB = 64


def _gather_encode(params, tokens, mask):
    """Pure-gather encoder: emb row = table[tokens[:, 0]] — no arithmetic, so
    streamed and materialized embeddings are bitwise identical by
    construction and any parity failure is the engine's fault."""
    del mask
    return jnp.take(params["table"], tokens[:, 0], axis=0)


def _gather_setup(N, Q, seed=0):
    rng = np.random.default_rng(seed)
    params = {"table": jnp.asarray(rng.normal(size=(VOCAB, DIM)), jnp.float32)}
    doc_texts = [[int(i % VOCAB)] for i in range(N)]
    c_emb = jnp.take(params["table"],
                     jnp.asarray([t[0] for t in doc_texts]), axis=0)
    q_emb = jnp.asarray(rng.normal(size=(Q, DIM)), jnp.float32)
    return params, doc_texts, c_emb, q_emb


def _stream_topk(stage_cls, params, q_emb, doc_texts, *, chunk, **kw):
    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    stage = stage_cls(_gather_encode,
                      query_ids=[f"q{i}" for i in range(q_emb.shape[0])],
                      doc_ids=[f"d{i}" for i in range(len(doc_texts))], **kw)
    carry = stage.init(q_emb)
    for toks, mask, base, n_valid in store.chunks():
        carry = stage.step(params, q_emb, carry, toks, mask, base, n_valid)
    return carry


# ---------------------------------------------------------------------------
# TokenStore
# ---------------------------------------------------------------------------


def test_token_store_fixed_shapes_and_ragged_tail():
    texts = [[i, i + 1] for i in range(10)]
    store = E.TokenStore.build(texts, max_len=4, chunk=4)
    assert store.n_chunks == 3
    assert store.tokens.shape == (3, 4, 4)        # every chunk one shape
    assert store.rows_valid(0) == 4 and store.rows_valid(2) == 2
    seen = []
    for toks, mask, base, n_valid in store.chunks():
        assert toks.shape == (4, 4) and mask.shape == (4, 4)
        for r in range(n_valid):
            seen.append(list(np.asarray(toks[r, :2])))
        assert not np.asarray(mask[n_valid:]).any()   # padding rows masked out
    assert seen == texts


def test_token_store_empty_and_oversized_chunk():
    assert E.TokenStore.build([], max_len=3, chunk=8).n_chunks == 0
    store = E.TokenStore.build([[1], [2]], max_len=3, chunk=100)
    assert store.n_chunks == 1 and store.rows_valid(0) == 2


# ---------------------------------------------------------------------------
# Fused streaming top-k == materialized topk_exact, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,chunk,k", [
    (60, 16, 10),     # ragged final chunk (60 = 3*16 + 12)
    (64, 16, 10),     # exact chunking
    (23, 7, 40),      # k > N and k > chunk
    (50, 8, 13),      # k > chunk
    (40, 40, 5),      # single chunk
    (40, 64, 5),      # chunk > N
])
def test_stream_topk_bitwise_vs_topk_exact(N, chunk, k):
    params, doc_texts, c_emb, q_emb = _gather_setup(N, Q=6)
    run_s, run_i = _stream_topk(E.StreamTopKStage, params, q_emb, doc_texts,
                                chunk=chunk, k=k)
    es, ei = R.topk_exact(q_emb, c_emb, k=k, block=chunk)
    # same chunk decomposition + same merge sequence -> identical programs:
    # scores AND indices must agree exactly, not just within tolerance.
    np.testing.assert_array_equal(np.asarray(run_s), np.asarray(es))
    np.testing.assert_array_equal(np.asarray(run_i), np.asarray(ei))


@pytest.mark.parametrize("N,chunk,k,window", [
    (60, 4, 10, 8),    # 15 chunks: 1 full window + 7-chunk tail
    (64, 4, 10, 8),    # 16 chunks: 2 full windows exactly
    (50, 3, 40, 4),    # ragged final chunk + k > chunk, windows engaged
])
def test_stream_topk_window_bitwise_vs_topk_exact(N, chunk, k, window):
    """The scan-window fast path folds the same per-chunk math in the same
    order — bit-for-bit equal to both the per-chunk path and topk_exact."""
    params, doc_texts, c_emb, q_emb = _gather_setup(N, Q=5)
    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    stage = E.StreamTopKStage(_gather_encode, k=k, window=window,
                              query_ids=[f"q{i}" for i in range(5)],
                              doc_ids=[f"d{i}" for i in range(N)])
    carry = stage.init(q_emb)
    ci = 0
    while ci < store.n_chunks:                    # mirror the engine loop
        if ci + window <= store.n_chunks:
            bases = store.chunk * np.arange(ci, ci + window, dtype=np.int32)
            nvs = np.asarray([store.rows_valid(j)
                              for j in range(ci, ci + window)], np.int32)
            carry = stage.step_window(
                params, q_emb, carry, jnp.asarray(store.tokens[ci:ci + window]),
                jnp.asarray(store.mask[ci:ci + window]), bases, nvs)
            ci += window
        else:
            carry = stage.step(params, q_emb, carry,
                               jnp.asarray(store.tokens[ci]),
                               jnp.asarray(store.mask[ci]),
                               store.chunk * ci, store.rows_valid(ci))
            ci += 1
    es, ei = R.topk_exact(q_emb, c_emb, k=k, block=chunk)
    np.testing.assert_array_equal(np.asarray(carry[0]), np.asarray(es))
    np.testing.assert_array_equal(np.asarray(carry[1]), np.asarray(ei))


def test_stream_pallas_matches_xla_stream():
    params, doc_texts, c_emb, q_emb = _gather_setup(45, Q=4)
    xs, xi = _stream_topk(E.StreamTopKStage, params, q_emb, doc_texts,
                          chunk=16, k=12)
    ps, pi = _stream_topk(E.PallasStreamTopKStage, params, q_emb, doc_texts,
                          chunk=16, k=12)
    np.testing.assert_allclose(np.asarray(ps), np.asarray(xs), rtol=1e-6)
    assert (np.asarray(pi) == np.asarray(xi)).mean() > 0.99


def test_stream_never_materializes_corpus_embeddings():
    """Every embedding block the encoder ever produces is chunk-sized; the
    final carry is (Q, k) — peak embedding memory O(chunk x D + Q x k)."""
    N, chunk, k, Q = 100, 16, 7, 5
    shapes = []

    def spy_encode(params, tokens, mask):
        shapes.append(tuple(tokens.shape))
        return _gather_encode(params, tokens, mask)

    params, doc_texts, _, q_emb = _gather_setup(N, Q=Q)
    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    stage = E.StreamTopKStage(spy_encode, k=k,
                              query_ids=[f"q{i}" for i in range(Q)],
                              doc_ids=[f"d{i}" for i in range(N)])
    carry = stage.init(q_emb)
    for toks, mask, base, n_valid in store.chunks():
        carry = stage.step(params, q_emb, carry, toks, mask, base, n_valid)
    assert all(s == (chunk, 2) for s in shapes)     # never (N, L)
    assert carry[0].shape == (Q, k) and carry[1].shape == (Q, k)


# ---------------------------------------------------------------------------
# Rerank streaming == vectorized rerank_run
# ---------------------------------------------------------------------------


def test_stream_rerank_matches_rerank_run():
    N, Q, k = 50, 6, 5
    params, doc_texts, c_emb, q_emb = _gather_setup(N, Q=Q)
    qids = [f"q{i}" for i in range(Q)]
    dids = [f"d{i}" for i in range(N)]
    rng = np.random.default_rng(3)
    per_query = {qid: [f"d{j}" for j in rng.choice(N, size=12, replace=False)]
                 for qid in qids}
    per_query[qids[-1]] = []                       # empty candidate list
    ref_run, ref_scores = R.rerank_run(qids, q_emb, dids, c_emb, per_query,
                                       k=k)
    store = E.TokenStore.build(doc_texts, max_len=2, chunk=16)
    stage = E.StreamRerankStage(_gather_encode, k=k, query_ids=qids,
                                doc_ids=dids, per_query=per_query)
    carry = stage.init(q_emb)
    for toks, mask, base, n_valid in store.chunks():
        carry = stage.step(params, q_emb, carry, toks, mask, base, n_valid)
    run, scores = stage.finalize(carry)
    assert run == ref_run
    for qid in qids:
        np.testing.assert_allclose(scores[qid], ref_scores[qid], rtol=1e-6)


def test_rerank_run_vectorized_matches_manual_loop():
    """The padded batched-matmul rerank matches a straightforward per-query
    reference (the old implementation's semantics)."""
    rng = np.random.default_rng(0)
    Q, N, D, k = 5, 40, 8, 6
    q = rng.normal(size=(Q, D)).astype(np.float32)
    c = rng.normal(size=(N, D)).astype(np.float32)
    qids = [f"q{i}" for i in range(Q)]
    dids = [f"d{i}" for i in range(N)]
    per_query = {qid: [f"d{j}" for j in rng.choice(N, size=9, replace=False)]
                 for qid in qids}
    per_query[qids[0]] = ["d3"]                      # single candidate
    per_query[qids[1]] = []                          # none
    per_query[qids[2]].append("unknown_doc")         # filtered out
    run, scores = R.rerank_run(qids, q, dids, c, per_query, k=k)
    doc_pos = {d: i for i, d in enumerate(dids)}
    for qi, qid in enumerate(qids):
        cands = [d for d in per_query[qid] if d in doc_pos]
        s = np.asarray([c[doc_pos[d]] @ q[qi] for d in cands])
        order = np.argsort(-s)[:k]
        assert run[qid] == [cands[j] for j in order]
        np.testing.assert_allclose(scores[qid], s[order], rtol=1e-6)


# ---------------------------------------------------------------------------
# Whole-pipeline parity: streaming engine vs legacy materialized engine
# ---------------------------------------------------------------------------


def _toy_spec():
    def enc(params, tokens, mask):
        emb = jnp.take(params["t"], tokens, axis=0)
        m = mask.astype(emb.dtype)[..., None]
        v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
        return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)

    return EncoderSpec(
        name="toy", dim=DIM, encode_query=enc, encode_passage=enc,
        init=lambda rng: {"t": 0.1 * jax.random.normal(rng, (503, DIM))},
        q_max_len=8, p_max_len=20)


@pytest.fixture(scope="module")
def ds():
    return corpus_lib.synthetic_retrieval_dataset(0, n_passages=300,
                                                  n_queries=30)


@pytest.fixture(scope="module")
def baseline_run(ds):
    return corpus_lib.lexical_baseline_run(ds, k=50)


@pytest.mark.parametrize("mode,sampler_fn,impl", [
    ("retrieval", lambda: None, "xla"),
    ("retrieval", lambda: RunFileTopK(depth=10), "xla"),
    ("retrieval", lambda: None, "pallas"),
    ("rerank", lambda: RerankTopK(depth=10), "xla"),
    ("average_rank", lambda: QrelPool(pool=10), "xla"),
])
def test_pipeline_streaming_matches_materialized(ds, baseline_run, mode,
                                                 sampler_fn, impl):
    spec = _toy_spec()
    params = spec.init(jax.random.PRNGKey(1))
    kw = dict(metrics=("MRR@10", "Recall@100"), mode=mode, k=100,
              batch_size=64, impl=impl)
    for chunk in (64, 96):                         # 96 -> ragged final chunk
        ps = ValidationPipeline(
            spec, ds.corpus, ds.queries, ds.qrels,
            ValidationConfig(engine="streaming", chunk_size=chunk, **kw),
            sampler=sampler_fn(), baseline_run=baseline_run)
        pm = ValidationPipeline(
            spec, ds.corpus, ds.queries, ds.qrels,
            ValidationConfig(engine="materialized", **kw),
            sampler=sampler_fn(), baseline_run=baseline_run)
        rs = ps.validate_params(params)
        rm = pm.validate_params(params)
        assert rs.metrics == rm.metrics
        assert set(rs.timings) == set(rm.timings)  # stable ledger/CSV schema


# ---------------------------------------------------------------------------
# Encoder jit cache (the per-checkpoint retrace bug)
# ---------------------------------------------------------------------------


def test_jitted_encoder_cached_across_calls():
    traces = []

    def enc(params, tokens, mask):
        traces.append(tuple(tokens.shape))
        return jnp.take(params["t"], tokens[:, 0], axis=0)

    params = {"t": jnp.ones((8, 4), jnp.float32)}
    texts = [[1], [2], [3]]
    encode_texts(enc, params, texts, max_len=2, batch_size=2)
    n_first = len(traces)
    assert n_first >= 1
    # second checkpoint: same shapes must NOT retrace (old code re-jitted)
    encode_texts(enc, {"t": 2.0 * params["t"]}, texts, max_len=2,
                 batch_size=2)
    assert len(traces) == n_first
    assert jitted_encoder(enc) is jitted_encoder(enc)


# ---------------------------------------------------------------------------
# Sharded streaming (forced multi-device, subprocess)
# ---------------------------------------------------------------------------


def test_stream_sharded_multidevice_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine as E
        from repro.core import retrieval as R
        from repro.distributed import compat

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        N, Q, D, k, chunk = 100, 5, 16, 17, 24
        params = {"table": jnp.asarray(rng.normal(size=(64, D)), jnp.float32)}
        doc_texts = [[int(i % 64)] for i in range(N)]
        c_emb = jnp.take(params["table"],
                         jnp.asarray([t[0] for t in doc_texts]), axis=0)
        q_emb = jnp.asarray(rng.normal(size=(Q, D)), jnp.float32)

        def enc(params, tokens, mask):
            return jnp.take(params["table"], tokens[:, 0], axis=0)

        store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
        stage = E.ShardedStreamTopKStage(
            enc, mesh, k=k, query_ids=[f"q{i}" for i in range(Q)],
            doc_ids=[f"d{i}" for i in range(N)])
        carry = stage.init(q_emb)
        for toks, mask, base, n_valid in store.chunks():
            carry = stage.step(params, q_emb, carry, toks, mask, base,
                               n_valid)
        es, ei = R.topk_exact(q_emb, c_emb, k=k)
        np.testing.assert_allclose(np.asarray(carry[0]), np.asarray(es),
                                   rtol=1e-5)
        assert (np.asarray(carry[1]) == np.asarray(ei)).mean() > 0.99
        print("STREAM_SHARDED_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "STREAM_SHARDED_OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# Engine injection into the validator
# ---------------------------------------------------------------------------


def test_validator_engine_injection(tmp_path, ds, baseline_run):
    from repro.ckpt import checkpoint as ckpt
    from repro.core.validator import AsyncValidator

    spec = _toy_spec()
    root = str(tmp_path / "ck")
    params = spec.init(jax.random.PRNGKey(0))
    ckpt.save(root, 1, {"params": params})

    pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                              ValidationConfig(batch_size=64),
                              sampler=RunFileTopK(depth=5),
                              baseline_run=baseline_run)
    assert pipe.engine.name == "streaming"
    legacy = E.MaterializedEngine(
        spec, pipe.doc_texts, pipe.query_texts, mode="retrieval", k=100,
        impl="xla", batch_size=64, query_ids=pipe.query_ids,
        doc_ids=pipe.doc_ids)

    class SpyEngine:                               # proves injection is used
        name = "spy"
        runs = 0

        def run(self, params):
            SpyEngine.runs += 1
            return legacy.run(params)

    v = AsyncValidator(root, pipe, engine=SpyEngine())
    assert v.validate_pending() == 1
    assert SpyEngine.runs == 1                     # injected engine ran
    assert pipe.engine.name == "streaming"         # pipeline NOT mutated
    stream_res = ValidationPipeline(
        spec, ds.corpus, ds.queries, ds.qrels, ValidationConfig(batch_size=64),
        sampler=RunFileTopK(depth=5),
        baseline_run=baseline_run).validate_params(params, step=1)
    assert v.results[0].metrics == stream_res.metrics
