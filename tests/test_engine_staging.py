"""Out-of-core TokenStore + double-buffered staging: mmap parity (bit for
bit vs the in-memory path), staging-schedule/prefetch-depth invariants,
double-buffered vs synchronous parity, ragged final chunk on disk, and the
sharded query-encoding path."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as E
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.data import corpus as corpus_lib
from repro.models.biencoder import EncoderSpec

DIM = 16


def _toy_spec():
    def enc(params, tokens, mask):
        emb = jnp.take(params["t"], tokens, axis=0)
        m = mask.astype(emb.dtype)[..., None]
        v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
        return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)

    return EncoderSpec(
        name="toy", dim=DIM, encode_query=enc, encode_passage=enc,
        init=lambda rng: {"t": 0.1 * jax.random.normal(rng, (503, DIM))},
        q_max_len=8, p_max_len=20)


@pytest.fixture(scope="module")
def ds():
    return corpus_lib.synthetic_retrieval_dataset(0, n_passages=300,
                                                  n_queries=30)


# ---------------------------------------------------------------------------
# TokenStore mmap backing
# ---------------------------------------------------------------------------


def test_token_store_mmap_bitwise_parity_and_ragged_tail(tmp_path):
    texts = [[i % 50, i + 1, i + 2] for i in range(43)]    # 43 = 2*16 + 11
    mem = E.TokenStore.build(texts, max_len=5, chunk=16)
    mm = E.TokenStore.build(texts, max_len=5, chunk=16, backing="mmap",
                            cache_dir=str(tmp_path / "cache"))
    assert isinstance(mm.tokens, np.memmap) and isinstance(mm.mask, np.memmap)
    assert mm.n_chunks == mem.n_chunks == 3
    assert mm.rows_valid(2) == 11                          # ragged final chunk
    np.testing.assert_array_equal(np.asarray(mm.tokens), mem.tokens)
    np.testing.assert_array_equal(np.asarray(mm.mask), mem.mask)
    # per-chunk iteration parity too (what the engine actually consumes)
    for (ta, ma, ba, va), (tb, mb, bb, vb) in zip(mem.chunks(), mm.chunks()):
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
        assert (ba, va) == (bb, vb)


def test_token_store_mmap_cache_reused_across_builds(tmp_path):
    texts = [[i, i + 1] for i in range(10)]
    cache = str(tmp_path / "cache")
    first = E.TokenStore.build(texts, max_len=4, chunk=4, backing="mmap",
                               cache_dir=cache)
    assert not first.reused
    meta = json.load(open(os.path.join(cache, "store_meta.json")))
    assert meta["n_texts"] == 10 and meta["n_chunks"] == 3
    # second build (next checkpoint / restarted process): files are reused
    second = E.TokenStore.build(texts, max_len=4, chunk=4, backing="mmap",
                                cache_dir=cache)
    assert second.reused
    np.testing.assert_array_equal(np.asarray(second.tokens),
                                  np.asarray(first.tokens))
    # different content with same geometry must NOT reuse
    other = [[i + 7, i] for i in range(10)]
    third = E.TokenStore.build(other, max_len=4, chunk=4, backing="mmap",
                               cache_dir=cache)
    assert not third.reused
    assert np.asarray(third.tokens)[0, 0, 0] == 7


def test_token_store_mmap_survives_torn_meta(tmp_path):
    """A crash mid-build (torn/truncated store_meta.json) must trigger a
    rebuild on the next build, not a permanent JSONDecodeError."""
    texts = [[i, i + 1] for i in range(10)]
    cache = str(tmp_path / "cache")
    E.TokenStore.build(texts, max_len=4, chunk=4, backing="mmap",
                       cache_dir=cache)
    with open(os.path.join(cache, "store_meta.json"), "w") as f:
        f.write('{"version": 1, "n_te')                    # torn write
    store = E.TokenStore.build(texts, max_len=4, chunk=4, backing="mmap",
                               cache_dir=cache)
    assert not store.reused                                # rebuilt
    mem = E.TokenStore.build(texts, max_len=4, chunk=4)
    np.testing.assert_array_equal(np.asarray(store.tokens), mem.tokens)
    # and the rebuild re-committed a valid marker
    assert E.TokenStore.build(texts, max_len=4, chunk=4, backing="mmap",
                              cache_dir=cache).reused
    # a valid marker with missing/truncated bins must also rebuild (a
    # partially copied cache_dir), not crash on the memmap open
    os.remove(os.path.join(cache, "tokens.int32.bin"))
    store = E.TokenStore.build(texts, max_len=4, chunk=4, backing="mmap",
                               cache_dir=cache)
    assert not store.reused
    np.testing.assert_array_equal(np.asarray(store.tokens), mem.tokens)


def test_token_store_full_fingerprint_catches_middle_mutation(tmp_path):
    """The mmap-cache middle-mutation hazard (ROADMAP): the O(1) "fast"
    fingerprint only sees geometry + edge texts, so an in-place mutation of
    a middle document is a DOCUMENTED stale hit; the opt-in "full" content
    hash must rebuild instead."""
    texts = [[i, i + 1] for i in range(40)]
    mutated = [list(t) for t in texts]
    mutated[20] = [999, 998]                       # middle doc, edges intact

    # fast (default): stale reuse — the documented hazard, asserted so the
    # contract is pinned, not accidental.
    fast = str(tmp_path / "fast")
    E.TokenStore.build(texts, max_len=4, chunk=8, backing="mmap",
                       cache_dir=fast)
    stale = E.TokenStore.build(mutated, max_len=4, chunk=8, backing="mmap",
                               cache_dir=fast)
    assert stale.reused                            # cache NOT invalidated
    assert np.asarray(stale.tokens)[2, 4, 0] == 20  # still the old content

    # full: the same mutation rebuilds the cache
    full = str(tmp_path / "full")
    first = E.TokenStore.build(texts, max_len=4, chunk=8, backing="mmap",
                               cache_dir=full, fingerprint="full")
    assert not first.reused
    # unchanged content still reuses under "full" (the amortization holds)
    assert E.TokenStore.build(texts, max_len=4, chunk=8, backing="mmap",
                              cache_dir=full, fingerprint="full").reused
    fresh = E.TokenStore.build(mutated, max_len=4, chunk=8, backing="mmap",
                               cache_dir=full, fingerprint="full")
    assert not fresh.reused                        # mutation detected
    assert np.asarray(fresh.tokens)[2, 4, 0] == 999
    # switching fingerprint modes never trusts the other mode's marker
    assert not E.TokenStore.build(mutated, max_len=4, chunk=8,
                                  backing="mmap", cache_dir=full).reused
    with pytest.raises(ValueError):
        E.TokenStore.build(texts, max_len=4, chunk=8, fingerprint="bogus")


def test_token_store_mmap_readonly_and_empty(tmp_path):
    store = E.TokenStore.build([[1], [2]], max_len=3, chunk=2,
                               backing="mmap", cache_dir=str(tmp_path / "c"))
    with pytest.raises(ValueError):
        store.tokens[0, 0, 0] = 99                         # mode="r" maps
    empty = E.TokenStore.build([], max_len=3, chunk=8, backing="mmap",
                               cache_dir=str(tmp_path / "e"))
    assert empty.n_chunks == 0
    with pytest.raises(ValueError):
        E.TokenStore.build([[1]], max_len=2, chunk=1, backing="mmap")
    with pytest.raises(ValueError):
        E.TokenStore.build([[1]], max_len=2, chunk=1, backing="bogus")


# ---------------------------------------------------------------------------
# Staging schedule + prefetch depth
# ---------------------------------------------------------------------------


def test_plan_schedule_halving_tail():
    # 15 chunks, window 8: one full window then a halving tail 4+2+1
    assert E.plan_schedule(15, 8) == [(0, 8), (8, 4), (12, 2), (14, 1)]
    assert E.plan_schedule(16, 8) == [(0, 8), (8, 8)]
    assert E.plan_schedule(3, 1) == [(0, 1), (1, 1), (2, 1)]
    assert E.plan_schedule(0, 8) == []
    # covers every chunk exactly once, in order
    for n, w in [(37, 8), (5, 4), (9, 16)]:
        plan = E.plan_schedule(n, w)
        rows = [ci + j for ci, ww in plan for j in range(ww)]
        assert rows == list(range(n))


def test_staged_batches_prefetches_ahead_of_consumption():
    """With depth=2 the stager has already issued batch i+1's put when batch
    i is consumed (the double buffer); with depth=1 it has not (sync)."""
    texts = [[i] for i in range(12)]
    store = E.TokenStore.build(texts, max_len=2, chunk=3)
    schedule = E.plan_schedule(store.n_chunks, 1)

    for depth, max_lead in ((1, 0), (2, 1), (3, 2)):
        staged = []
        it = E.staged_batches(store, schedule, depth=depth,
                              _put=lambda x: staged.append(len(staged)) or x)
        consumed = 0
        for toks, mask in it:
            consumed += 1
            # puts come in (tokens, mask) pairs: staged batches = staged/2
            lead = staged[-1] // 2 + 1 - consumed if staged else 0
            assert lead <= max_lead
        assert consumed == len(schedule)


def test_staged_batches_values_identical_to_direct_load():
    texts = [[i, i + 3] for i in range(26)]                # ragged tail
    store = E.TokenStore.build(texts, max_len=3, chunk=4)
    schedule = E.plan_schedule(store.n_chunks, 4)
    out = list(E.staged_batches(store, schedule, depth=2))
    assert len(out) == len(schedule)
    for (ci, w), (toks, mask) in zip(schedule, out):
        ref_t = store.tokens[ci] if w == 1 else store.tokens[ci:ci + w]
        ref_m = store.mask[ci] if w == 1 else store.mask[ci:ci + w]
        np.testing.assert_array_equal(np.asarray(toks), ref_t)
        np.testing.assert_array_equal(np.asarray(mask), ref_m)


# ---------------------------------------------------------------------------
# Whole-pipeline parity: mmap + double-buffered == in-memory sync, bit for bit
# ---------------------------------------------------------------------------


def _run_pipeline(ds, spec, params, **vcfg_kw):
    vcfg = ValidationConfig(metrics=("MRR@10", "Recall@100"), k=100,
                            batch_size=64, **vcfg_kw)
    pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels, vcfg)
    run, scores, _ = pipe.engine.run(params)
    res = pipe.validate_params(params)
    return run, scores, res


@pytest.mark.parametrize("chunk", [64, 96])                # 96 -> ragged tail
def test_pipeline_mmap_double_buffered_bitwise_parity(tmp_path, ds, chunk):
    """The acceptance bar: mmap-backed + double-buffered streaming produces
    bit-for-bit identical runs/scores/metrics to in-memory sync streaming."""
    spec = _toy_spec()
    params = spec.init(jax.random.PRNGKey(1))
    base = _run_pipeline(ds, spec, params, chunk_size=chunk,
                         staging="sync", token_backing="memory")
    oooc = _run_pipeline(ds, spec, params, chunk_size=chunk,
                         staging="double_buffered", token_backing="mmap",
                         mmap_dir=str(tmp_path / f"tc{chunk}"))
    assert base[0] == oooc[0]                              # identical run
    assert base[1] == oooc[1]                              # identical scores
    assert base[2].metrics == oooc[2].metrics


def test_pipeline_double_buffered_matches_sync(ds):
    spec = _toy_spec()
    params = spec.init(jax.random.PRNGKey(2))
    sync = _run_pipeline(ds, spec, params, chunk_size=48, staging="sync")
    dbuf = _run_pipeline(ds, spec, params, chunk_size=48,
                         staging="double_buffered")
    assert sync[0] == dbuf[0] and sync[1] == dbuf[1]


def test_pipeline_staging_depth_sweep(ds):
    """The configurable prefetch depth (ValidationConfig.staging_depth) must
    not change results: depths 1, 2, and 4 produce bit-for-bit identical
    runs/scores/metrics — deeper pipelines only stage further ahead."""
    spec = _toy_spec()
    params = spec.init(jax.random.PRNGKey(3))
    ref = None
    for depth in (1, 2, 4):
        got = _run_pipeline(ds, spec, params, chunk_size=48,
                            staging_depth=depth)
        if ref is None:
            ref = got
        else:
            assert got[0] == ref[0] and got[1] == ref[1]
            assert got[2].metrics == ref[2].metrics
    # the depth actually reaches the engine (not silently defaulted)
    vcfg = ValidationConfig(staging_depth=4)
    pipe = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels, vcfg)
    assert pipe.engine.staging_depth == 4


def test_streaming_engine_rejects_unknown_staging(ds, tmp_path):
    spec = _toy_spec()
    with pytest.raises(ValueError):
        ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                           ValidationConfig(staging="bogus"))
    with pytest.raises(ValueError):
        ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                           ValidationConfig(token_backing="mmap"))  # no dir
    with pytest.raises(ValueError):
        ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                           ValidationConfig(staging_depth=0))
    with pytest.raises(ValueError):
        ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                           ValidationConfig(token_backing="mmap",
                                            mmap_dir=str(tmp_path / "fp"),
                                            token_fingerprint="bogus"))


def test_mmap_store_via_validator_multiple_checkpoints(tmp_path, ds):
    """The mmap cache is built once and reused for every checkpoint the
    validator sees (the amortization argument)."""
    from repro.ckpt import checkpoint as ckpt
    from repro.core.validator import AsyncValidator

    spec = _toy_spec()
    root = str(tmp_path / "ck")
    for step in (1, 2):
        ckpt.save(root, step,
                  {"params": spec.init(jax.random.PRNGKey(step))})
    cache = str(tmp_path / "tokens")
    pipe = ValidationPipeline(
        spec, ds.corpus, ds.queries, ds.qrels,
        ValidationConfig(batch_size=64, token_backing="mmap",
                         mmap_dir=cache))
    assert pipe.engine.doc_store.backing == "mmap"
    v = AsyncValidator(root, pipe)
    assert v.validate_pending() == 2
    ref = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                             ValidationConfig(batch_size=64))
    for res in v.results:
        state, _ = ckpt.restore(root, res.step)
        assert res.metrics == ref.validate_params(
            state["params"], step=res.step).metrics


# ---------------------------------------------------------------------------
# Sharded query encoding (forced multi-device, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_query_encoding_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine as E
        from repro.distributed import compat
        from repro.distributed.sharding import rows_sharding

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        D = 16
        params = {"table": jnp.asarray(rng.normal(size=(64, D)), jnp.float32)}

        def enc(params, tokens, mask):
            return jnp.take(params["table"], tokens[:, 0], axis=0)

        # 50 queries, chunk 16 (divisible by the 8 shards), ragged tail
        q_texts = [[int(i % 64), 1] for i in range(50)]
        store = E.TokenStore.build(q_texts, max_len=2, chunk=16)
        ref = E.encode_store(enc, params, store)
        sharded = E.encode_store(enc, params, store, mesh=mesh)
        assert sharded.shape == (50, D)
        np.testing.assert_allclose(np.asarray(sharded), np.asarray(ref),
                                   rtol=1e-6)
        # staged chunks land with the row sharding the shard_map expects
        s = rows_sharding(mesh)
        assert s.spec == jax.sharding.PartitionSpec(("data", "model"))

        # the full engine path: make_engine on a mesh routes query encoding
        # through the sharded stage and still scores identically
        from repro.data import corpus as corpus_lib
        from repro.core.pipeline import ValidationConfig, ValidationPipeline
        from repro.models.biencoder import EncoderSpec
        ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=200,
                                                    n_queries=20)
        def enc2(params, tokens, mask):
            emb = jnp.take(params["t"], tokens, axis=0)
            m = mask.astype(emb.dtype)[..., None]
            v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
            return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True),
                                1e-6)
        spec = EncoderSpec(
            name="toy", dim=16, encode_query=enc2, encode_passage=enc2,
            init=lambda rng: {"t": 0.1 * jax.random.normal(rng, (503, 16))},
            q_max_len=8, p_max_len=20)
        params2 = spec.init(jax.random.PRNGKey(0))
        kw = dict(metrics=("MRR@10",), k=50, batch_size=40)
        on_mesh = ValidationPipeline(
            spec, ds.corpus, ds.queries, ds.qrels,
            ValidationConfig(mesh=mesh, chunk_size=40, **kw))
        assert on_mesh.engine.query_mesh is mesh
        single = ValidationPipeline(spec, ds.corpus, ds.queries, ds.qrels,
                                    ValidationConfig(chunk_size=40, **kw))
        rm = on_mesh.validate_params(params2)
        rs = single.validate_params(params2)
        assert rm.metrics == rs.metrics, (rm.metrics, rs.metrics)
        print("SHARDED_QUERY_ENCODE_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "SHARDED_QUERY_ENCODE_OK" in out.stdout, out.stdout + out.stderr
