"""PR-6 precision axis: score_dtype through kernels, every engine stage,
suite config, ledger rows, reporters, and control events — plus the sparse
rerank gather compaction.

The contract under test: f32 stays bit-for-bit the legacy path (the
existing parity suites enforce that; here we only spot-check), while bf16
and int8 agree at equal precision ACROSS data paths (streaming vs blocked
topk_exact) because quantization is per-ROW and therefore independent of
chunking, sharding, and block size.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.control import ControlConfig, ControlPlane, replay_ledger
from repro.core import engine as E
from repro.core import retrieval as R
from repro.core.precision import chunk_scores, itemsize, validate_score_dtype
from repro.core.reporting import CSVLogger
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import ValidationLedger
from repro.data import corpus as synthetic_ds
from repro.models.biencoder import EncoderSpec

DIM = 16
VOCAB = 64

NARROW = ("bf16", "int8")


def _gather_encode(params, tokens, mask):
    del mask
    return jnp.take(params["table"], tokens[:, 0], axis=0)


def _gather_setup(N, Q, seed=0):
    rng = np.random.default_rng(seed)
    params = {"table": jnp.asarray(rng.normal(size=(VOCAB, DIM)),
                                   jnp.float32)}
    doc_texts = [[int(i % VOCAB)] for i in range(N)]
    c_emb = jnp.take(params["table"],
                     jnp.asarray([t[0] for t in doc_texts]), axis=0)
    q_emb = jnp.asarray(rng.normal(size=(Q, DIM)), jnp.float32)
    return params, doc_texts, c_emb, q_emb


def _stream(stage, params, q_emb, store):
    """Engine-loop twin: honors wants_chunk AND store_override, exactly
    like StreamingEngine.run."""
    store = getattr(stage, "store_override", None) or store
    carry = stage.init(q_emb)
    for ci, (toks, mask, base, n_valid) in enumerate(store.chunks()):
        if not getattr(stage, "wants_chunk", lambda c: True)(ci):
            continue
        carry = stage.step(params, q_emb, carry, toks, mask, base, n_valid)
    return carry


# ---------------------------------------------------------------------------
# precision helpers
# ---------------------------------------------------------------------------

def test_validate_score_dtype_and_itemsize():
    for dt, size in (("f32", 4), ("bf16", 2), ("int8", 1)):
        assert validate_score_dtype(dt) == dt
        assert itemsize(dt) == size
    with pytest.raises(ValueError, match="fp8"):
        validate_score_dtype("fp8")


def test_chunk_scores_f32_is_literal_matmul():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(4, DIM)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(10, DIM)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(chunk_scores(q, c, "f32")),
        np.asarray((q @ c.T).astype(jnp.float32)))


def test_chunk_scores_quantization_is_row_independent():
    """The load-bearing invariant: a row's quantized score doesn't depend on
    which other rows share its chunk — so all chunkings/shardings agree."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(5, DIM)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(32, DIM)) * 100, jnp.float32)
    for dt in NARROW:
        whole = np.asarray(chunk_scores(q, c, dt))
        parts = np.concatenate(
            [np.asarray(chunk_scores(q, c[i:i + 7], dt))
             for i in range(0, 32, 7)], axis=1)
        np.testing.assert_array_equal(whole, parts)


# ---------------------------------------------------------------------------
# streaming stages x topk_exact at equal precision
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("score_dtype", NARROW)
def test_stream_topk_stage_matches_topk_exact_same_dtype(score_dtype):
    """chunk == block -> same per-chunk quantized scores, same merge: the
    XLA streaming stage and the blocked scan agree bitwise per precision."""
    N, chunk, k, Q = 60, 16, 10, 6
    params, doc_texts, c_emb, q_emb = _gather_setup(N, Q)
    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    stage = E.StreamTopKStage(_gather_encode, k=k,
                              query_ids=[f"q{i}" for i in range(Q)],
                              doc_ids=[f"d{i}" for i in range(N)],
                              score_dtype=score_dtype)
    run_s, run_i = _stream(stage, params, q_emb, store)
    es, ei = R.topk_exact(q_emb, c_emb, k=k, block=chunk,
                          score_dtype=score_dtype)
    np.testing.assert_array_equal(np.asarray(run_s), np.asarray(es))
    np.testing.assert_array_equal(np.asarray(run_i), np.asarray(ei))


@pytest.mark.parametrize("score_dtype", NARROW)
def test_pallas_stage_rank_sets_match_xla_stage(score_dtype):
    """Pallas kernel path vs XLA stage at equal precision: int32/bf16
    accumulation is shared, only f32 scale reassociation differs -> scores
    to ~ulp, rank SETS exactly."""
    N, chunk, k, Q = 60, 16, 10, 6
    params, doc_texts, _, q_emb = _gather_setup(N, Q)
    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    ids = dict(query_ids=[f"q{i}" for i in range(Q)],
               doc_ids=[f"d{i}" for i in range(N)])
    xs, xi = _stream(E.StreamTopKStage(_gather_encode, k=k,
                                       score_dtype=score_dtype, **ids),
                     params, q_emb, store)
    ps, pi = _stream(E.PallasStreamTopKStage(_gather_encode, k=k,
                                             score_dtype=score_dtype, **ids),
                     params, q_emb, store)
    np.testing.assert_allclose(np.asarray(ps), np.asarray(xs), rtol=1e-5,
                               atol=1e-6)
    for r in range(Q):
        assert set(np.asarray(pi)[r]) == set(np.asarray(xi)[r])


def test_narrow_dtypes_rank_close_to_f32():
    """Fidelity sanity: quantized retrieval is a good approximation of f32
    (the bench_fidelity sweep measures this properly; here just a floor)."""
    N, k, Q = 200, 20, 8
    _, _, c_emb, q_emb = _gather_setup(N, Q, seed=3)
    fs, fi = R.topk_exact(q_emb, c_emb, k=k)
    for dt in NARROW:
        s, i = R.topk_exact(q_emb, c_emb, k=k, score_dtype=dt)
        overlap = np.mean([len(set(np.asarray(i)[r]) & set(np.asarray(fi)[r]))
                           / k for r in range(Q)])
        assert overlap >= 0.8, (dt, overlap)


# ---------------------------------------------------------------------------
# rerank gather compaction
# ---------------------------------------------------------------------------

def _sparse_setup(N=96, chunk=8, Q=4, cands_per_q=3):
    """1 candidate row per chunk region: every chunk survives chunk-skipping
    but holds mostly non-candidates — the compaction sweet spot."""
    params, doc_texts, _, q_emb = _gather_setup(N, Q, seed=4)
    query_ids = [f"q{i}" for i in range(Q)]
    doc_ids = [f"d{i}" for i in range(N)]
    per_query = {qid: [f"d{(qi * cands_per_q + j) * chunk % N}"
                       for j in range(cands_per_q)]
                 for qi, qid in enumerate(query_ids)}
    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    return params, q_emb, store, query_ids, doc_ids, per_query


def test_rerank_compaction_bitwise_and_fewer_chunks():
    params, q_emb, store, qids, dids, per_query = _sparse_setup()
    kw = dict(k=10, query_ids=qids, doc_ids=dids, per_query=per_query,
              store=store)
    plain = E.StreamRerankStage(_gather_encode, compact=False, **kw)
    packed = E.StreamRerankStage(_gather_encode, compact=True, **kw)
    assert packed.store_override is not None
    # the packed pseudo-chunk store is materially smaller than the set of
    # chunks the plain stage would encode
    surviving = sum(plain.wants_chunk(ci) for ci in range(store.n_chunks))
    assert packed.store_override.n_chunks * 2 <= surviving
    run_a, sc_a = plain.finalize(_stream(plain, params, q_emb, store))
    run_b, sc_b = packed.finalize(_stream(packed, params, q_emb, store))
    # row-independent encoder + same rows in packed slots -> bit-for-bit
    assert run_a == run_b
    assert sc_a == sc_b


@pytest.mark.parametrize("score_dtype", ["f32"] + list(NARROW))
def test_rerank_compaction_every_precision(score_dtype):
    """Per-row quantization is gather-independent, so compaction stays
    bit-for-bit at every score_dtype."""
    params, q_emb, store, qids, dids, per_query = _sparse_setup()
    kw = dict(k=10, query_ids=qids, doc_ids=dids, per_query=per_query,
              store=store, score_dtype=score_dtype)
    plain = E.StreamRerankStage(_gather_encode, compact=False, **kw)
    packed = E.StreamRerankStage(_gather_encode, compact=True, **kw)
    assert packed.store_override is not None
    assert plain.finalize(_stream(plain, params, q_emb, store)) == \
        packed.finalize(_stream(packed, params, q_emb, store))


def test_rerank_compaction_declines_when_dense():
    """Dense candidates (most rows of most chunks) must NOT compact — the
    packed store would be as big as the chunk-skipped schedule."""
    N, chunk, Q = 32, 8, 4
    params, doc_texts, _, q_emb = _gather_setup(N, Q, seed=5)
    per_query = {f"q{i}": [f"d{j}" for j in range(N)] for i in range(Q)}
    store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
    stage = E.StreamRerankStage(_gather_encode, k=10,
                                query_ids=[f"q{i}" for i in range(Q)],
                                doc_ids=[f"d{j}" for j in range(N)],
                                per_query=per_query, store=store,
                                compact=True)
    assert stage.store_override is None


def test_streaming_engine_honors_store_override():
    """End-to-end through StreamingEngine.run: the engine must stream the
    compacted store, and results must equal the non-compacted engine's."""
    params, q_emb, store, qids, dids, per_query = _sparse_setup()
    spec = EncoderSpec(name="gather", dim=DIM,
                       encode_query=_gather_encode,
                       encode_passage=_gather_encode,
                       init=lambda rng: params, q_max_len=2, p_max_len=2)
    # query tokens that reproduce q_emb are impossible with the gather
    # encoder (q_emb is random), so drive both engines with the same query
    # store and compare them to each other.
    q_texts = [[int(i % VOCAB)] for i in range(len(qids))]
    qstore = E.TokenStore.build(q_texts, max_len=2, chunk=4)
    runs = {}
    for compact in (False, True):
        stage = E.StreamRerankStage(_gather_encode, k=10, query_ids=qids,
                                    doc_ids=dids, per_query=per_query,
                                    store=store, compact=compact)
        if compact:
            assert stage.store_override is not None
        eng = E.StreamingEngine(spec, store, qstore, stage)
        runs[compact] = eng.run(params)[:2]
    assert runs[False] == runs[True]


# ---------------------------------------------------------------------------
# suite / ledger / reporters / control events
# ---------------------------------------------------------------------------

def _toy_encode(params, tokens, mask):
    emb = jnp.take(params["table"], tokens, axis=0)
    m = mask.astype(emb.dtype)[..., None]
    v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
    return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def _toy_spec(vocab=211):
    return EncoderSpec(
        name="toy", dim=DIM, encode_query=_toy_encode,
        encode_passage=_toy_encode,
        init=lambda rng: {"table": jax.random.normal(rng, (vocab, DIM))},
        q_max_len=10, p_max_len=26)


@pytest.fixture(scope="module")
def ds():
    return synthetic_ds.synthetic_retrieval_dataset(3, n_passages=120,
                                                    n_queries=12, vocab=211)


@pytest.fixture(scope="module")
def toy_params():
    return _toy_spec().init(jax.random.PRNGKey(0))


def _suite(ds, **vcfg_kw):
    return ValidationSuite(_toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels),
    ], ValidationConfig(batch_size=32, **vcfg_kw))


def test_suite_result_carries_score_dtype(ds, toy_params):
    res = _suite(ds, score_dtype="bf16").validate_params(toy_params, step=7)
    assert res.tasks["default"].score_dtype == "bf16"
    assert res.score_dtype == "bf16"
    assert res.engine == "streaming"
    # default stays f32 and the field defaults survive old-result shims
    res32 = _suite(ds).validate_params(toy_params, step=7)
    assert res32.score_dtype == "f32"


def test_suite_config_rejects_bad_score_dtype(ds, toy_params):
    suite = _suite(ds, score_dtype="fp8")
    with pytest.raises(ValueError, match="score_dtype"):
        suite.build_engines()


def test_materialized_engine_score_dtype(ds, toy_params):
    for dt in ("f32",) + NARROW:
        res = _suite(ds, engine="materialized",
                     score_dtype=dt).validate_params(toy_params, step=1)
        assert res.tasks["default"].engine == "materialized"
        assert res.score_dtype == dt


@pytest.mark.parametrize("score_dtype", NARROW)
def test_narrow_metrics_close_to_f32_end_to_end(ds, toy_params, score_dtype):
    """Whole-pipeline fidelity floor: quantized validation metrics stay in
    the neighborhood of f32's on the toy dataset."""
    base = _suite(ds).validate_params(toy_params, step=0).metrics["MRR@10"]
    quant = _suite(ds, score_dtype=score_dtype) \
        .validate_params(toy_params, step=0).metrics["MRR@10"]
    assert abs(quant - base) <= 0.15


def test_ledger_rows_record_score_dtype(ds, toy_params, tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    res = _suite(ds, score_dtype="int8").validate_params(toy_params, step=3)
    ValidationLedger(path).record(res)
    with open(path) as f:
        rows = [json.loads(l) for l in f if l.strip()]
    assert rows[0]["score_dtype"] == "int8"
    assert rows[0]["engine"] == "streaming"


def test_csv_logger_gets_engine_and_score_dtype_columns(ds, toy_params,
                                                        tmp_path):
    """Satellite 6: reporters surface precision like engine — via the
    validator's logger payload, landing as CSV columns."""
    import csv
    from repro.core.suite import params_from_checkpoint  # noqa: F401
    from repro.core.validator import AsyncValidator
    from repro.ckpt import checkpoint as ckpt
    root = str(tmp_path / "ck")
    ckpt.save(root, 5, {"params": toy_params})
    logger = CSVLogger(str(tmp_path / "metrics.csv"))
    v = AsyncValidator(root, _suite(ds, score_dtype="bf16"), logger=logger,
                       ledger_path=str(tmp_path / "ledger.jsonl"))
    assert v.validate_pending() == 1 and not v.errors
    with open(logger.path) as f:
        recs = list(csv.DictReader(f))
    assert recs[0]["score_dtype"] == "bf16"
    assert recs[0]["engine"] == "streaming"


def test_control_events_carry_precision_and_replay_matches(ds, toy_params,
                                                           tmp_path):
    """select events name engine + score_dtype, and offline replay over the
    ledger re-derives byte-identical decisions (context included)."""
    cfg = ControlConfig(metric="MRR@10", keep_top_k=0)
    online = ControlPlane(None, cfg)
    ledger = ValidationLedger(str(tmp_path / "ledger.jsonl"))
    suite = _suite(ds, score_dtype="int8")
    for step in (1, 2):
        res = suite.validate_params(toy_params, step=step)
        ledger.record(res)
        online.on_result(res)
    for ev in online.events.decisions():
        assert ev.payload["score_dtype"] == "int8"
        assert ev.payload["engine"] == "streaming"
    offline = replay_ledger(ledger.rows(), cfg)
    assert offline.events.decisions() == online.events.decisions()


def test_replay_of_pre_provenance_rows_has_no_context():
    """A ledger written before the provenance fields must replay with
    byte-identical events to the old online run — i.e. no context keys."""
    rows = [{"step": s, "metrics": {"m": v}}
            for s, v in ((1, 0.5), (2, 0.6))]
    cfg = ControlConfig(metric="m")
    plane = replay_ledger(rows, cfg)
    for ev in plane.events.decisions():
        assert "score_dtype" not in ev.payload
        assert "engine" not in ev.payload
