"""Sharding rules engine + fault-tolerance primitives."""

import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import compat
from repro.distributed import fault
from repro.distributed import sharding as shd

# ---------------------------------------------------------------------------
# spec_for: divisibility fallback + conflict dedup + stacked layers
# ---------------------------------------------------------------------------


class FakeMesh:
    """Duck-typed mesh: only ``.shape`` (dict) and ``.axis_names`` used."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)


def test_spec_for_basic_tp_fsdp():
    rules = shd.lm_train_rules()
    assert shd.spec_for((8192, 1024), ("embed", "kv_heads"), rules, MESH) \
        == P("data", "model")
    assert shd.spec_for((8192, 22016), ("embed", "mlp"), rules, MESH) \
        == P("data", "model")


def test_spec_for_divisibility_fallback():
    rules = shd.lm_train_rules()
    # BERT vocab 30522 is not divisible by 16 -> falls through model AND
    # data (30522 = 2 * 3 * 5087) -> replicated
    assert shd.spec_for((30522, 768), ("vocab", "embed"), rules, MESH) \
        == P(None, "data")
    # qwen2 vocab divides 16 -> model
    assert shd.spec_for((151936, 896), ("vocab", "embed"), rules, MESH) \
        == P("model", "data")


def test_spec_for_conflict_dedup():
    rules = shd.lm_train_rules()
    # MoE (expert, embed, mlp): expert wins "model"; mlp falls to replicated
    assert shd.spec_for((128, 7168, 4864), ("expert", "embed", "mlp"),
                        rules, MESH) == P("model", "data")


def test_spec_for_stacked_leading_dims():
    rules = shd.lm_train_rules()
    # 3-D array with 2 logical axes -> leading scan-stack dim unsharded
    assert shd.spec_for((95, 8192, 1024), ("embed", "kv_heads"), rules, MESH) \
        == P(None, "data", "model")


def test_spec_for_joint_axes():
    rules = shd.fsdp_only_rules()
    assert shd.spec_for((1024, 64), ("table_rows", "embed"), rules, MESH) \
        == P(("data", "model"))              # trailing None trimmed
    # second dim can't reuse consumed axes -> replicated
    assert shd.spec_for((256, 256), ("a", "b"), rules, MESH) \
        == P(("data", "model"))


def test_opt_state_shardings_adam_and_adafactor():
    from repro.train import optim
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params_abs = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
                  "b": jax.ShapeDtypeStruct((32,), jnp.float32)}
    param_sh = {"w": jax.NamedSharding(mesh, P("data", "model")),
                "b": jax.NamedSharding(mesh, P("model"))}
    adam_abs = jax.eval_shape(optim.adamw(1e-3).init, params_abs)
    sh = shd.opt_state_shardings(adam_abs, params_abs, param_sh, mesh)
    assert sh["m"]["w"].spec == P("data", "model")     # same-shape slot
    assert sh["v"]["b"].spec == P("model")
    assert sh["step"].spec == P()                      # scalar replicated
    af_abs = jax.eval_shape(optim.adafactor(1e-3).init, params_abs)
    sh = shd.opt_state_shardings(af_abs, params_abs, param_sh, mesh)
    assert sh["slots"]["w"]["vr"].spec == P("data")    # (64,) = w minus dim 1
    assert sh["slots"]["w"]["vc"].spec == P("model")   # (32,) = w minus dim 0


def test_cache_spec_layouts():
    # batch shardable -> batch on data, seq on model
    assert shd.cache_spec(MESH, (95, 128, 32768, 8, 128), 128) \
        == P(None, ("data",), "model")
    # batch=1 -> sequence takes the whole mesh
    assert shd.cache_spec(MESH, (95, 1, 524288, 8, 128), 1) \
        == P(None, None, ("data", "model"))


def test_lm_batch_spec():
    assert shd.lm_batch_spec(MESH, 256) == P(("data",))
    assert shd.lm_batch_spec(MESH, 7) == P()           # unshardable
    multi = FakeMesh(pod=2, data=16, model=16)
    assert shd.lm_batch_spec(multi, 256) == P(("pod", "data"))


# ---------------------------------------------------------------------------
# WorkQueue / straggler / fault injection
# ---------------------------------------------------------------------------

def test_make_chunks_over_decomposition():
    chunks = fault.make_chunks(list(range(100)), n_workers=4, over_factor=4)
    assert 13 <= len(chunks) <= 16
    flat = [x for c in chunks for x in c.payload]
    assert flat == list(range(100))


def test_run_chunked_basic_order():
    out = fault.run_chunked(list(range(50)), lambda xs: [x * 2 for x in xs],
                            n_workers=3)
    assert [x for c in out for x in c] == [x * 2 for x in range(50)]


def test_run_chunked_with_straggler():
    """One consistently slow worker must not serialize the job: speculation
    re-executes its chunks elsewhere; results stay exact."""
    delays = {"w0": 0.05, "w1": 0.0, "w2": 0.0, "w3": 0.0}
    out = fault.run_chunked(list(range(40)), lambda xs: [x + 1 for x in xs],
                            n_workers=4, worker_delay=lambda w: delays[w])
    assert [x for c in out for x in c] == [x + 1 for x in range(40)]


def test_run_chunked_with_injected_failures():
    """Chunks that fail once are retried and complete."""
    out = fault.run_chunked(list(range(30)), lambda xs: list(xs),
                            n_workers=3, fail_once=(0, 2))
    assert [x for c in out for x in c] == list(range(30))


def test_workqueue_first_result_wins():
    chunks = fault.make_chunks([1, 2, 3, 4], n_workers=1, over_factor=1)
    q = fault.WorkQueue(chunks)
    c = q.acquire("a")
    # b speculates on the same chunk once the queue drains
    c2 = q.acquire("b")
    assert c2 is not None and c2.chunk_id == c.chunk_id
    assert q.complete("a", c.chunk_id, "A") is True
    assert q.complete("b", c.chunk_id, "B") is False   # loser discarded
    assert q.results()[0].value == "A"
    assert q.finished


def test_workqueue_permanent_failure_surfaces():
    chunks = fault.make_chunks([1], n_workers=1, over_factor=1)
    q = fault.WorkQueue(chunks, max_attempts=2)
    for _ in range(2):
        c = q.acquire("w")
        q.fail("w", c.chunk_id)
    assert q.failed_chunks == [0]
    with pytest.raises(RuntimeError):
        fault.run_chunked([1], lambda x: x, n_workers=1,
                          fail_once=())  # sanity: no failure -> fine
        raise RuntimeError("unreachable-guard")


def test_elastic_workers_join_mid_run():
    """Workers joining after the queue is half-drained still help."""
    chunks = fault.make_chunks(list(range(20)), n_workers=2, over_factor=2)
    q = fault.WorkQueue(chunks)
    # worker 1 processes half
    for _ in range(2):
        c = q.acquire("w1")
        q.complete("w1", c.chunk_id, sum(c.payload))
    # new worker joins (elasticity: acquire needs no registration)
    while not q.finished:
        c = q.acquire("w2")
        if c is None:
            break
        q.complete("w2", c.chunk_id, sum(c.payload))
    assert q.finished


# ---------------------------------------------------------------------------
# Sharded streaming rerank on a real multi-device mesh (forced host devices,
# subprocess — mirrors the sharded retrieval test in tests/test_engine.py)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_rerank_multidevice_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine as E
        from repro.core import retrieval as R
        from repro.distributed import compat

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        N, Q, D, chunk, k = 96, 6, 16, 24, 50     # chunk % 8 shards == 0
        # integer-valued table/queries: exact float32 dot products, so the
        # 8-shard run must equal the references bit for bit, not approx.
        params = {"table": jnp.asarray(rng.integers(-4, 5, size=(64, D)),
                                       jnp.float32)}
        doc_texts = [[int(i % 64)] for i in range(N)]
        c_emb = jnp.take(params["table"],
                         jnp.asarray([t[0] for t in doc_texts]), axis=0)
        q_emb = jnp.asarray(rng.integers(-4, 5, size=(Q, D)), jnp.float32)

        def enc(params, tokens, mask):
            return jnp.take(params["table"], tokens[:, 0], axis=0)

        qids = [f"q{i}" for i in range(Q)]
        dids = [f"d{i}" for i in range(N)]
        per_query = {
            qids[0]: ["d3", "d3", "d40", "d95"],           # duplicates
            qids[1]: [],                                   # empty
            qids[2]: [f"d{j}" for j in range(30)],         # ragged, 2 chunks
            qids[3]: ["d95"],                              # final chunk only
            qids[4]: ["d0", "d24", "d48", "d72"],          # one per chunk
            qids[5]: ["d7", "d7", "d9", "bogus"],          # dup + unknown
        }
        ref = R.rerank_run(qids, q_emb, dids, c_emb, per_query, k=k)

        store = E.TokenStore.build(doc_texts, max_len=2, chunk=chunk)
        stage = E.ShardedStreamRerankStage(enc, mesh, k=k, query_ids=qids,
                                           doc_ids=dids, per_query=per_query,
                                           store=store)
        carry = stage.init(q_emb)
        skipped = 0
        for toks, mask, base, n_valid in store.chunks():
            if not stage.wants_chunk(base // store.chunk):
                skipped += 1
                continue
            carry = stage.step(params, q_emb, carry, toks, mask, base,
                               n_valid)
        assert stage.finalize(carry) == ref, "sharded != materialized"

        # end to end: make_stage routes (mode=rerank, mesh=...) to the
        # sharded stage and the full engine (pre-sharded staging included)
        # scores identically to the single-device pipeline.
        from repro.core.pipeline import ValidationConfig, ValidationPipeline
        from repro.core.samplers import RerankTopK
        from repro.data import corpus as corpus_lib
        from repro.models.biencoder import EncoderSpec
        ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=200,
                                                    n_queries=20)
        def enc2(params, tokens, mask):
            emb = jnp.take(params["t"], tokens, axis=0)
            m = mask.astype(emb.dtype)[..., None]
            v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
            return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True),
                                1e-6)
        spec = EncoderSpec(
            name="toy", dim=16, encode_query=enc2, encode_passage=enc2,
            init=lambda rng: {"t": 0.1 * jax.random.normal(rng, (503, 16))},
            q_max_len=8, p_max_len=20)
        params2 = spec.init(jax.random.PRNGKey(0))
        base_run = corpus_lib.lexical_baseline_run(ds, k=30)
        kw = dict(metrics=("MRR@10",), mode="rerank", k=100, batch_size=40)
        on_mesh = ValidationPipeline(
            spec, ds.corpus, ds.queries, ds.qrels,
            ValidationConfig(mesh=mesh, chunk_size=40, **kw),
            sampler=RerankTopK(depth=10), baseline_run=base_run)
        assert on_mesh.engine.stage.name == "rerank_sharded"
        single = ValidationPipeline(
            spec, ds.corpus, ds.queries, ds.qrels,
            ValidationConfig(chunk_size=40, **kw),
            sampler=RerankTopK(depth=10), baseline_run=base_run)
        rm = on_mesh.validate_params(params2)
        rs = single.validate_params(params2)
        assert rm.metrics == rs.metrics, (rm.metrics, rs.metrics)
        print("SHARDED_RERANK_OK skipped=%d" % skipped)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "SHARDED_RERANK_OK" in out.stdout, out.stdout + out.stderr
