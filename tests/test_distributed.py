"""Sharding rules engine + fault-tolerance primitives."""

import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import compat
from repro.distributed import fault
from repro.distributed import sharding as shd

# ---------------------------------------------------------------------------
# spec_for: divisibility fallback + conflict dedup + stacked layers
# ---------------------------------------------------------------------------


class FakeMesh:
    """Duck-typed mesh: only ``.shape`` (dict) and ``.axis_names`` used."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)


def test_spec_for_basic_tp_fsdp():
    rules = shd.lm_train_rules()
    assert shd.spec_for((8192, 1024), ("embed", "kv_heads"), rules, MESH) \
        == P("data", "model")
    assert shd.spec_for((8192, 22016), ("embed", "mlp"), rules, MESH) \
        == P("data", "model")


def test_spec_for_divisibility_fallback():
    rules = shd.lm_train_rules()
    # BERT vocab 30522 is not divisible by 16 -> falls through model AND
    # data (30522 = 2 * 3 * 5087) -> replicated
    assert shd.spec_for((30522, 768), ("vocab", "embed"), rules, MESH) \
        == P(None, "data")
    # qwen2 vocab divides 16 -> model
    assert shd.spec_for((151936, 896), ("vocab", "embed"), rules, MESH) \
        == P("model", "data")


def test_spec_for_conflict_dedup():
    rules = shd.lm_train_rules()
    # MoE (expert, embed, mlp): expert wins "model"; mlp falls to replicated
    assert shd.spec_for((128, 7168, 4864), ("expert", "embed", "mlp"),
                        rules, MESH) == P("model", "data")


def test_spec_for_stacked_leading_dims():
    rules = shd.lm_train_rules()
    # 3-D array with 2 logical axes -> leading scan-stack dim unsharded
    assert shd.spec_for((95, 8192, 1024), ("embed", "kv_heads"), rules, MESH) \
        == P(None, "data", "model")


def test_spec_for_joint_axes():
    rules = shd.fsdp_only_rules()
    assert shd.spec_for((1024, 64), ("table_rows", "embed"), rules, MESH) \
        == P(("data", "model"))              # trailing None trimmed
    # second dim can't reuse consumed axes -> replicated
    assert shd.spec_for((256, 256), ("a", "b"), rules, MESH) \
        == P(("data", "model"))


def test_opt_state_shardings_adam_and_adafactor():
    from repro.train import optim
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params_abs = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
                  "b": jax.ShapeDtypeStruct((32,), jnp.float32)}
    param_sh = {"w": jax.NamedSharding(mesh, P("data", "model")),
                "b": jax.NamedSharding(mesh, P("model"))}
    adam_abs = jax.eval_shape(optim.adamw(1e-3).init, params_abs)
    sh = shd.opt_state_shardings(adam_abs, params_abs, param_sh, mesh)
    assert sh["m"]["w"].spec == P("data", "model")     # same-shape slot
    assert sh["v"]["b"].spec == P("model")
    assert sh["step"].spec == P()                      # scalar replicated
    af_abs = jax.eval_shape(optim.adafactor(1e-3).init, params_abs)
    sh = shd.opt_state_shardings(af_abs, params_abs, param_sh, mesh)
    assert sh["slots"]["w"]["vr"].spec == P("data")    # (64,) = w minus dim 1
    assert sh["slots"]["w"]["vc"].spec == P("model")   # (32,) = w minus dim 0


def test_cache_spec_layouts():
    # batch shardable -> batch on data, seq on model
    assert shd.cache_spec(MESH, (95, 128, 32768, 8, 128), 128) \
        == P(None, ("data",), "model")
    # batch=1 -> sequence takes the whole mesh
    assert shd.cache_spec(MESH, (95, 1, 524288, 8, 128), 1) \
        == P(None, None, ("data", "model"))


def test_lm_batch_spec():
    assert shd.lm_batch_spec(MESH, 256) == P(("data",))
    assert shd.lm_batch_spec(MESH, 7) == P()           # unshardable
    multi = FakeMesh(pod=2, data=16, model=16)
    assert shd.lm_batch_spec(multi, 256) == P(("pod", "data"))


# ---------------------------------------------------------------------------
# WorkQueue / straggler / fault injection
# ---------------------------------------------------------------------------

def test_make_chunks_over_decomposition():
    chunks = fault.make_chunks(list(range(100)), n_workers=4, over_factor=4)
    assert 13 <= len(chunks) <= 16
    flat = [x for c in chunks for x in c.payload]
    assert flat == list(range(100))


def test_run_chunked_basic_order():
    out = fault.run_chunked(list(range(50)), lambda xs: [x * 2 for x in xs],
                            n_workers=3)
    assert [x for c in out for x in c] == [x * 2 for x in range(50)]


def test_run_chunked_with_straggler():
    """One consistently slow worker must not serialize the job: speculation
    re-executes its chunks elsewhere; results stay exact."""
    delays = {"w0": 0.05, "w1": 0.0, "w2": 0.0, "w3": 0.0}
    out = fault.run_chunked(list(range(40)), lambda xs: [x + 1 for x in xs],
                            n_workers=4, worker_delay=lambda w: delays[w])
    assert [x for c in out for x in c] == [x + 1 for x in range(40)]


def test_run_chunked_with_injected_failures():
    """Chunks that fail once are retried and complete."""
    out = fault.run_chunked(list(range(30)), lambda xs: list(xs),
                            n_workers=3, fail_once=(0, 2))
    assert [x for c in out for x in c] == list(range(30))


def test_workqueue_first_result_wins():
    chunks = fault.make_chunks([1, 2, 3, 4], n_workers=1, over_factor=1)
    q = fault.WorkQueue(chunks)
    c = q.acquire("a")
    # b speculates on the same chunk once the queue drains
    c2 = q.acquire("b")
    assert c2 is not None and c2.chunk_id == c.chunk_id
    assert q.complete("a", c.chunk_id, "A") is True
    assert q.complete("b", c.chunk_id, "B") is False   # loser discarded
    assert q.results()[0].value == "A"
    assert q.finished


def test_workqueue_permanent_failure_surfaces():
    chunks = fault.make_chunks([1], n_workers=1, over_factor=1)
    q = fault.WorkQueue(chunks, max_attempts=2)
    for _ in range(2):
        c = q.acquire("w")
        q.fail("w", c.chunk_id)
    assert q.failed_chunks == [0]
    with pytest.raises(RuntimeError):
        fault.run_chunked([1], lambda x: x, n_workers=1,
                          fail_once=())  # sanity: no failure -> fine
        raise RuntimeError("unreachable-guard")


def test_elastic_workers_join_mid_run():
    """Workers joining after the queue is half-drained still help."""
    chunks = fault.make_chunks(list(range(20)), n_workers=2, over_factor=2)
    q = fault.WorkQueue(chunks)
    # worker 1 processes half
    for _ in range(2):
        c = q.acquire("w1")
        q.complete("w1", c.chunk_id, sum(c.payload))
    # new worker joins (elasticity: acquire needs no registration)
    while not q.finished:
        c = q.acquire("w2")
        if c is None:
            break
        q.complete("w2", c.chunk_id, sum(c.payload))
    assert q.finished
