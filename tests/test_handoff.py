"""PR-10 lazy snapshot hand-off: validate checkpoints before they are
durable.

Locks the subsystem's four contracts:

  * **bit-parity** — a snapshot-scored verdict is bit-for-bit the durable-
    restore verdict, across retrieval/rerank x streaming/materialized x
    score_dtype (the hand-off changes WHEN validation runs, never what it
    computes);
  * **exactly-once** — a step arriving via both the channel and the
    watcher produces one (step, task) row set; the watcher stays the
    dedupe authority;
  * **crash/torn safety** — a trainer SIGKILLed mid-spill leaves a
    snapshot no reader ever claims, and the watcher fallback still scores
    the step from its durable checkpoint;
  * **durability gating** — irreversible actions (quality GC) wait for
    the step's durable COMMIT; reversible decisions (selection, early
    stop) act on provisional snapshot-scored rows immediately.

Plus the satellite regressions: the async saver never blocks the training
thread on the device->host transfer, ledger rows without hand-off
provenance stay byte-identical to pre-handoff ones, and the work queue
records the snapshot publish route.
"""

import json
import os
import signal
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.control import ControlConfig, ControlPlane
from repro.core.samplers import RerankTopK
from repro.core.suite import (ValidationConfig, ValidationSuite,
                              ValidationTask)
from repro.core.validator import AsyncValidator, ValidationLedger, \
    ValidatorWorker
from repro.core.workqueue import WorkQueue, replay
from repro.data import corpus as synthetic_ds
from repro.handoff import ParamSnapshot, SnapshotChannel, SnapshotSpool
from repro.models.biencoder import EncoderSpec

DIM = 16
VOCAB = 211


def _toy_encode(params, tokens, mask):
    emb = jnp.take(params["table"], tokens, axis=0)
    m = mask.astype(emb.dtype)[..., None]
    v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
    return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def toy_spec():
    return EncoderSpec(
        name="toy", dim=DIM, encode_query=_toy_encode,
        encode_passage=_toy_encode,
        init=lambda rng: {"table": jax.random.normal(rng, (VOCAB, DIM))},
        q_max_len=10, p_max_len=26)


@pytest.fixture(scope="module")
def ds():
    return synthetic_ds.synthetic_retrieval_dataset(7, n_passages=90,
                                                    n_queries=10,
                                                    vocab=VOCAB)


@pytest.fixture(scope="module")
def baseline_run(ds):
    return synthetic_ds.lexical_baseline_run(ds, k=20)


def toy_params(seed=0):
    return toy_spec().init(jax.random.PRNGKey(seed))


def make_suite(ds, baseline_run, *, mode="retrieval", engine="streaming",
               score_dtype="f32"):
    sampler = RerankTopK(depth=10) if mode == "rerank" else None
    return ValidationSuite(toy_spec(), [
        ValidationTask("default", ds.corpus, ds.queries, ds.qrels,
                       sampler=sampler, baseline_run=baseline_run),
    ], ValidationConfig(metrics=("MRR@10",), mode=mode, k=10,
                        batch_size=16, engine=engine,
                        score_dtype=score_dtype))


# ---------------------------------------------------------------------------
# ParamSnapshot / SnapshotSpool primitives
# ---------------------------------------------------------------------------

def test_param_snapshot_roundtrip_mixed_dtypes():
    tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.full((5,), 1.5, jnp.bfloat16)}
    snap = ParamSnapshot.from_tree(3, tree, extra={"tag": "x"})
    state = snap.state()
    assert jax.tree_util.tree_structure(state) \
        == jax.tree_util.tree_structure(tree)
    assert np.array_equal(np.asarray(state["w"]), np.asarray(tree["w"]))
    assert state["b"].dtype == tree["b"].dtype
    assert np.array_equal(np.asarray(state["b"], np.float32),
                          np.asarray(tree["b"], np.float32))
    assert snap.extra == {"tag": "x"}
    assert snap.nbytes > 0


def test_spool_roundtrip_and_mmap(tmp_path):
    spool = SnapshotSpool(str(tmp_path / "sp"))
    tree = {"w": jnp.ones((4, 4)), "h": jnp.zeros((2,), jnp.bfloat16)}
    snap = ParamSnapshot.from_tree(10, tree)
    spool.publish(10, snap.leaves, snap.treedef_hex, extra=snap.extra)
    assert spool.has(10) and spool.steps() == [10]
    got = spool.get(10)
    state = got.state()
    assert np.array_equal(np.asarray(state["w"]), np.asarray(tree["w"]))
    assert state["h"].dtype == tree["h"].dtype


def test_spool_torn_spill_is_invisible(tmp_path):
    """A snapshot dir without COMMIT (crash mid-spill) is never claimed."""
    root = str(tmp_path / "sp")
    spool = SnapshotSpool(root)
    # fake a torn spill: arrays + manifest present, COMMIT missing
    torn = os.path.join(root, "snap_0000000007")
    os.makedirs(os.path.join(torn, "arrays"))
    np.save(os.path.join(torn, "arrays", "00000.npy"), np.ones(3))
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        json.dump({"step": 7, "treedef": "", "leaves": []}, f)
    # announce it as the writer would have, just before dying
    from repro.core.jsonl import append_jsonl_atomic
    append_jsonl_atomic(spool.announce_path,
                        [{"kind": "snapshot", "step": 7}])
    assert not spool.has(7)
    assert spool.steps() == []
    assert spool.poll() == []           # marker authority beats announce
    assert spool.load(7) is None
    assert spool.get(7) is None
    assert spool.pending() == []


def test_spool_consumer_surface(tmp_path):
    spool = SnapshotSpool(str(tmp_path / "sp"))
    snap = ParamSnapshot.from_tree(4, {"w": jnp.ones(2)})
    spool.publish(4, snap.leaves, snap.treedef_hex)
    reader = SnapshotSpool(spool.root)
    assert reader.pending() == [4]
    assert reader.pending() == [4]      # unclaimed: stays pending
    got = reader.claim(4)
    assert got is not None and got.step == 4
    assert reader.pending() == []
    # retire removes the spill; a later claim falls through to None
    spool.retire(4)
    assert reader.claim(4) is None


# ---------------------------------------------------------------------------
# SnapshotChannel semantics
# ---------------------------------------------------------------------------

def _snap(step, val=1.0):
    return ParamSnapshot.from_tree(step, {"w": jnp.full((2,), val)})


def test_channel_backpressure_drops_oldest_unclaimed():
    ch = SnapshotChannel(capacity=2)
    for s in (1, 2, 3):
        ch.publish(_snap(s))
    assert ch.dropped == [1]
    assert ch.pending() == [2, 3]
    assert ch.get(1) is None            # evicted; watcher owns step 1 now


def test_channel_eviction_spares_claimed_then_falls_back():
    ch = SnapshotChannel(capacity=2)
    ch.publish(_snap(1))
    ch.publish(_snap(2))
    held = ch.claim(1)
    assert held is not None
    ch.publish(_snap(3))                # evicts 2 (oldest UNCLAIMED)
    assert ch.dropped == [2]
    assert ch.get(1) is not None        # claimed entry survived
    # with NO unclaimed candidate, publish still never blocks: the claimed
    # entry is evicted from the ring, but the claimant holds its own
    # reference so its in-flight validation is unaffected
    tight = SnapshotChannel(capacity=1)
    tight.publish(_snap(1))
    held = tight.claim(1)
    tight.publish(_snap(2))
    assert tight.dropped == [1]
    assert tight.get(1) is None
    assert held.step == 1


def test_channel_durability_and_retirement(tmp_path):
    spool = SnapshotSpool(str(tmp_path / "sp"))
    ch = SnapshotChannel(capacity=4, spool=spool)
    ch.publish(_snap(5))
    assert ch.durability(5) == "pending"
    assert ch.durability(999) == "durable"      # never published => durable
    assert spool.has(5)
    ch.claim(5)
    ch.mark_validated(5)
    assert spool.has(5)                 # validated but NOT durable: kept
    ch.mark_durable(5)
    assert ch.durability(5) == "durable"
    assert not spool.has(5)             # validated + durable: retired
    ch.publish(_snap(6))
    ch.mark_failed(6, error=RuntimeError("disk full"))
    assert ch.durability(6) == "failed"
    assert ch.get(6) is None and not spool.has(6)


def test_channel_subscriber_wakes_on_publish():
    ch = SnapshotChannel()
    woke = []
    ch.subscribe(woke.append)
    ch.publish(_snap(9))
    assert woke == [9]


# ---------------------------------------------------------------------------
# Satellite 1: the async saver never blocks the training thread
# ---------------------------------------------------------------------------

class _SlowLeaf:
    """Device-array stand-in: copy_to_host_async is instant (a DMA
    enqueue), materializing via np.asarray is slow (the transfer wait)."""

    def __init__(self, value, record, delay=0.25):
        self._value = np.asarray(value)
        self._record = record
        self._delay = delay

    def copy_to_host_async(self):
        self._record.append(("enqueue", threading.get_ident()))

    def __array__(self, dtype=None, copy=None):
        self._record.append(("materialize", threading.get_ident()))
        time.sleep(self._delay)
        return self._value if dtype is None \
            else self._value.astype(dtype)


def test_async_saver_training_thread_never_waits_on_transfer(tmp_path):
    record = []
    tree = {"a": _SlowLeaf(np.ones(3), record),
            "b": _SlowLeaf(np.zeros(2), record)}
    saver = ckpt.AsyncSaver()
    copied = []
    t0 = time.monotonic()
    saver.save(str(tmp_path / "ck"), 1, tree,
               on_host_copy=lambda step, host: copied.append(step))
    issue_time = time.monotonic() - t0
    # the calling thread only enqueued the copies — far below the 2 x 0.25s
    # a synchronous np.asarray of both leaves would cost
    assert issue_time < 0.2, f"save() blocked the caller for {issue_time}s"
    caller = threading.get_ident()
    assert [r for r in record if r[0] == "enqueue"] \
        == [("enqueue", caller)] * 2
    assert all(tid != caller for op, tid in record if op == "materialize") \
        or not [r for r in record if r[0] == "materialize"]
    saver.wait()
    # materialization happened exactly once per leaf, on the background
    # thread, and the host-copy hook fired before the durable commit
    mats = [r for r in record if r[0] == "materialize"]
    assert len(mats) == 2 and all(tid != caller for _, tid in mats)
    assert copied == [1]
    assert ckpt.list_steps(str(tmp_path / "ck")) == [1]


def test_async_saver_host_copy_failure_spares_durable_save(tmp_path):
    saver = ckpt.AsyncSaver()

    def boom(step, host):
        raise RuntimeError("publish failed")

    saver.save(str(tmp_path / "ck"), 2, {"w": np.ones(2)},
               on_host_copy=boom)
    with pytest.raises(RuntimeError, match="publish failed"):
        saver.wait()                    # surfaced...
    assert ckpt.list_steps(str(tmp_path / "ck")) == [2]   # ...but committed


def test_async_saver_failure_hook_fires_on_save_error(tmp_path):
    saver = ckpt.AsyncSaver()
    failed = []
    path = tmp_path / "blocked"
    path.write_text("not a directory")
    saver.save(str(path), 3, {"w": np.ones(2)},
               on_failure=lambda step, e: failed.append(step),
               on_durable=lambda step: failed.append(("durable", step)))
    with pytest.raises(Exception):
        saver.wait()
    assert failed == [3]


# ---------------------------------------------------------------------------
# Tentpole: snapshot-vs-durable bit parity (satellite 3 matrix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["retrieval", "rerank"])
@pytest.mark.parametrize("engine", ["streaming", "materialized"])
@pytest.mark.parametrize("score_dtype", ["f32", "int8"])
def test_snapshot_parity_matrix(tmp_path, ds, baseline_run, mode, engine,
                                score_dtype):
    """The paper-level contract: scoring from a pre-durable snapshot is
    bit-for-bit the durable-restore validation — across modes, engines,
    and scoring precisions."""
    root = str(tmp_path / "ck")
    params = toy_params()
    state = {"params": params}
    ckpt.save(root, 1, state)

    def result_rows(snapshots):
        suite = make_suite(ds, baseline_run, mode=mode, engine=engine,
                           score_dtype=score_dtype)
        ledger = ValidationLedger(None, expected_tasks=suite.task_names)
        worker = ValidatorWorker(root, suite, ledger=ledger,
                                 snapshots=snapshots)
        res = worker.run_step(1)
        return res, ledger.rows(), worker.last_handoff

    ch = SnapshotChannel()
    ch.publish(ParamSnapshot.from_tree(1, state))
    res_snap, rows_snap, hand_snap = result_rows(ch)
    res_dur, rows_dur, hand_dur = result_rows(None)

    assert hand_snap == "snapshot" and hand_dur == ""
    # metrics bit-equal (== on floats, not allclose)
    assert res_snap.metrics == res_dur.metrics
    for name in res_dur.tasks:
        assert res_snap.tasks[name].metrics == res_dur.tasks[name].metrics
    # provenance: snapshot rows carry handoff="snapshot"; durable rows
    # omit the key entirely (byte-identity with pre-handoff ledgers)
    for row in rows_snap:
        assert row["handoff"] == "snapshot"
    for row in rows_dur:
        assert "handoff" not in row
    # everything else in the rows is identical
    strip = lambda r: {k: v for k, v in r.items()
                       if k not in ("handoff", "timings")}
    assert [strip(r) for r in rows_snap] == [strip(r) for r in rows_dur]


def test_snapshot_parity_through_spool(tmp_path, ds, baseline_run):
    """Cross-process route: mmap'd spool leaves score bit-identically."""
    root = str(tmp_path / "ck")
    state = {"params": toy_params()}
    ckpt.save(root, 2, state)
    spool = SnapshotSpool(str(tmp_path / "sp"))
    snap = ParamSnapshot.from_tree(2, state)
    spool.publish(2, snap.leaves, snap.treedef_hex)

    def run(snapshots):
        suite = make_suite(ds, baseline_run)
        worker = ValidatorWorker(
            root, suite,
            ledger=ValidationLedger(None,
                                    expected_tasks=suite.task_names),
            snapshots=snapshots)
        return worker.run_step(2)

    res_spool = run(SnapshotSpool(spool.root))
    res_dur = run(None)
    assert res_spool.metrics == res_dur.metrics
    assert res_spool.handoff == "snapshot" and res_dur.handoff == "durable"


# ---------------------------------------------------------------------------
# Satellite 2: exactly-once when both routes surface a step
# ---------------------------------------------------------------------------

def test_no_double_validation_snapshot_then_watcher(tmp_path, ds,
                                                    baseline_run):
    root = str(tmp_path / "ck")
    state = {"params": toy_params()}
    ch = SnapshotChannel()
    suite = make_suite(ds, baseline_run)
    v = AsyncValidator(root, suite, snapshots=ch,
                       ledger_path=str(tmp_path / "ledger.jsonl"))
    # snapshot first (pre-durable), then the durable commit
    ch.publish(ParamSnapshot.from_tree(1, state))
    assert v.validate_pending() == 1
    ckpt.save(root, 1, state)
    # the watcher discovers step 1 now — but the snapshot verdict consumed it
    assert v.validate_pending() == 0
    keys = [(r["step"], r["task"]) for r in v.ledger.rows()]
    assert sorted(keys) == sorted(set(keys)), "duplicate (step, task) rows"
    assert keys == [(1, "default")]
    assert v.ledger.rows()[0]["handoff"] == "snapshot"


def test_no_double_validation_watcher_then_snapshot(tmp_path, ds,
                                                    baseline_run):
    root = str(tmp_path / "ck")
    state = {"params": toy_params()}
    ch = SnapshotChannel()
    suite = make_suite(ds, baseline_run)
    v = AsyncValidator(root, suite, snapshots=ch,
                       ledger_path=str(tmp_path / "ledger.jsonl"))
    # durable first (fast save), snapshot published late
    ckpt.save(root, 1, state)
    assert v.validate_pending() == 1
    ch.publish(ParamSnapshot.from_tree(1, state))
    assert v.validate_pending() == 0    # ledger idempotency consumed it
    assert [(r["step"], r["task"]) for r in v.ledger.rows()] \
        == [(1, "default")]
    assert "handoff" not in v.ledger.rows()[0]
    # the late snapshot is marked validated so the channel can retire it
    assert ch.pending() == []


def test_snapshot_failure_falls_back_to_watcher(tmp_path, ds, baseline_run):
    """A poisoned snapshot is discarded; the durable path still scores."""
    root = str(tmp_path / "ck")
    state = {"params": toy_params()}
    ch = SnapshotChannel()
    suite = make_suite(ds, baseline_run)
    v = AsyncValidator(root, suite, snapshots=ch,
                       ledger_path=str(tmp_path / "ledger.jsonl"))
    # a snapshot whose tree is garbage -> validation raises
    bad = ParamSnapshot.from_tree(1, {"not_params": jnp.ones(2)})
    ch.publish(bad)
    assert v.validate_pending() == 0
    assert len(v.errors) == 1
    assert ch.get(1) is None            # discarded, not retried from host
    ckpt.save(root, 1, state)
    assert v.validate_pending() == 1    # watcher fallback, durable restore
    assert "handoff" not in v.ledger.rows()[0]


# ---------------------------------------------------------------------------
# Satellite 3: SIGKILL the trainer mid-spill
# ---------------------------------------------------------------------------

_CRASHER = r"""
import os, sys, signal
import numpy as np
sys.path.insert(0, {src!r})
from repro.handoff.spool import SnapshotSpool, _snap_dir
from repro.core.jsonl import append_jsonl_atomic

root = {root!r}
spool = SnapshotSpool(root)
# one COMPLETE snapshot (step 1)...
spool.publish(1, [np.ones(4, np.float32)], "aa")
# ...then die mid-spill of step 2: arrays written, no COMMIT, announce
# already appended (worst interleaving for a reader)
torn = _snap_dir(root, 2) + ".tmp"
os.makedirs(os.path.join(torn, "arrays"))
np.save(os.path.join(torn, "arrays", "00000.npy"), np.ones(4, np.float32))
os.rename(torn, _snap_dir(root, 2))
append_jsonl_atomic(os.path.join(root, "announce.jsonl"),
                    [{{"kind": "snapshot", "step": 2}}])
os.kill(os.getpid(), signal.SIGKILL)
"""


def test_sigkilled_trainer_torn_spill_never_claimed(tmp_path, ds,
                                                    baseline_run):
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    spool_root = str(tmp_path / "sp")
    code = _CRASHER.format(src=os.path.abspath(src), root=spool_root)
    proc = subprocess.run([sys.executable, "-c", code])
    assert proc.returncode == -signal.SIGKILL
    reader = SnapshotSpool(spool_root)
    # step 1 committed before the crash; step 2's torn spill is invisible
    assert reader.steps() == [1]
    assert reader.pending() == [1]
    assert reader.get(2) is None
    assert reader.claim(1).step == 1    # drain the pre-crash snapshot
    # the watcher fallback still owns step 2: a durable checkpoint written
    # by the (restarted) trainer validates through the normal path
    root = str(tmp_path / "ck")
    state = {"params": toy_params()}
    ckpt.save(root, 2, state)
    suite = make_suite(ds, baseline_run)
    v = AsyncValidator(root, suite, snapshots=reader,
                       ledger_path=str(tmp_path / "ledger.jsonl"))
    assert v.validate_pending() == 1
    rows = v.ledger.rows()
    assert [(r["step"], r["task"]) for r in rows] == [(2, "default")]
    assert "handoff" not in rows[0]     # scored from the durable restore


# ---------------------------------------------------------------------------
# Satellite 3: durability gate — GC deferred, early stop provisional
# ---------------------------------------------------------------------------

def _score_rows(v, ch, root, state, step, value, *, durable):
    """Publish + (optionally) commit one step and validate it."""
    if durable:
        ckpt.save(root, step, state)
        ch.publish(ParamSnapshot.from_tree(step, state))
        ch.mark_durable(step)
    else:
        ch.publish(ParamSnapshot.from_tree(step, state))
    return v.validate_pending()


def test_gc_waits_for_durable_commit(tmp_path, ds, baseline_run):
    root = str(tmp_path / "ck")
    ch = SnapshotChannel(capacity=8)
    suite = make_suite(ds, baseline_run)
    control = ControlPlane(root, ControlConfig(metric="MRR@10",
                                               keep_top_k=1),
                           durability=ch.durability)
    v = AsyncValidator(root, suite, snapshots=ch, controller=control,
                       ledger_path=str(tmp_path / "ledger.jsonl"))
    state = {"params": toy_params()}
    # two durable validated steps: GC may act freely
    for step in (1, 2):
        ckpt.save(root, step, state)
        ch.publish(ParamSnapshot.from_tree(step, state))
        ch.mark_durable(step)
    v.validate_pending()
    n_after_durable = len(ckpt.list_steps(root))
    # step 3 scored from a PRE-durable snapshot: GC must hold — nothing
    # may be deleted on the evidence of a step that could fail to persist
    ch.publish(ParamSnapshot.from_tree(3, {"params": toy_params(1)}))
    v.validate_pending()
    assert 3 in [r["step"] for r in v.ledger.rows()]
    assert len(ckpt.list_steps(root)) == n_after_durable    # held
    assert not control.maybe_gc(v)
    # selection DID act on the provisional row (reversible decision)
    assert control.selector.best_step is not None
    # the durable commit lands: the hold releases and GC runs
    ckpt.save(root, 3, {"params": toy_params(1)})
    ch.mark_durable(3)
    assert control.maybe_gc(v)


def test_gc_hold_releases_on_failed_save(tmp_path, ds, baseline_run):
    root = str(tmp_path / "ck")
    ch = SnapshotChannel(capacity=8)
    suite = make_suite(ds, baseline_run)
    control = ControlPlane(root, ControlConfig(metric="MRR@10",
                                               keep_top_k=1),
                           durability=ch.durability)
    v = AsyncValidator(root, suite, snapshots=ch, controller=control,
                       ledger_path=str(tmp_path / "ledger.jsonl"))
    state = {"params": toy_params()}
    ch.publish(ParamSnapshot.from_tree(1, state))
    v.validate_pending()
    assert not control.maybe_gc(v)      # pending: held
    ch.mark_failed(1, error=RuntimeError("disk died"))
    assert control.maybe_gc(v)          # failed releases the hold


def test_early_stop_acts_on_provisional_rows(tmp_path, ds, baseline_run):
    """Early stopping is a reversible decision: it fires from snapshot-
    scored rows without waiting for any durable commit."""
    root = str(tmp_path / "ck")
    stop_path = str(tmp_path / "STOP")
    ch = SnapshotChannel(capacity=16)
    suite = make_suite(ds, baseline_run)
    control = ControlPlane(root, ControlConfig(metric="MRR@10",
                                               early_stop=True,
                                               patience=2),
                           stop_path=stop_path, durability=ch.durability)
    v = AsyncValidator(root, suite, snapshots=ch, controller=control,
                       ledger_path=str(tmp_path / "ledger.jsonl"))
    state = {"params": toy_params()}
    for step in (1, 2, 3, 4):
        ch.publish(ParamSnapshot.from_tree(step, state))   # never durable
        v.validate_pending()
        if control.stopped:
            break
    # identical metrics every step -> plateau -> stop, all provisional
    assert control.stopped
    assert os.path.exists(stop_path)
    assert all(ch.durability(r["step"]) == "pending"
               for r in v.ledger.rows())


# ---------------------------------------------------------------------------
# Ledger byte-identity + provenance surfaces
# ---------------------------------------------------------------------------

def test_durable_rows_stay_byte_identical(tmp_path, ds, baseline_run):
    """A run without the hand-off writes EXACTLY the pre-handoff schema:
    no `handoff` key anywhere, keys byte-for-byte the pre-feature set."""
    root = str(tmp_path / "ck")
    ckpt.save(root, 1, {"params": toy_params()})
    suite = make_suite(ds, baseline_run)
    path = str(tmp_path / "ledger.jsonl")
    v = AsyncValidator(root, suite, ledger_path=path)
    v.validate_pending()
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            assert "handoff" not in rec
            assert set(rec) == {"step", "task", "metrics", "timings",
                                "subset_size", "engine", "score_dtype"}


def test_flatten_rows_exposes_handoff_context():
    from repro.control.metricspec import flatten_rows
    rows = [
        {"step": 1, "task": "default", "metrics": {"MRR@10": 0.5},
         "engine": "streaming", "score_dtype": "f32"},
        {"step": 2, "task": "default", "metrics": {"MRR@10": 0.6},
         "engine": "streaming", "score_dtype": "f32",
         "handoff": "snapshot"},
    ]
    out = flatten_rows(rows, ("default",), with_context=True)
    ctx = dict((step, c) for step, _, c in out)
    assert "handoff" not in ctx[1]          # pre-handoff rows unchanged
    assert ctx[2]["handoff"] == "snapshot"


def test_workqueue_publish_source_provenance(tmp_path):
    from repro.core.workqueue import WorkUnit
    path = str(tmp_path / "queue.jsonl")
    q = WorkQueue(path, "supervisor")
    q.publish([WorkUnit.make(1, "default")], source="snapshot")
    # idempotent: the watcher's later re-publish of the same key no-ops
    q.publish([WorkUnit.make(1, "default")])
    q.publish([WorkUnit.make(2, "default")])
    state = q.refresh()
    assert state.units[(1, "default")].source == "snapshot"
    assert state.units[(2, "default")].source == ""
    # offline replay folds the same provenance from the raw records
    replayed = replay(path)
    assert replayed.units[(1, "default")].source == "snapshot"
    # the record only carries the key when stamped (byte-compat)
    with open(path) as f:
        recs = [json.loads(line) for line in f]
    unit_recs = [r for r in recs if r.get("kind") == "unit"]
    assert [("source" in r) for r in unit_recs] == [True, False]


def test_fleet_supervisor_publishes_snapshot_units(tmp_path, ds,
                                                   baseline_run):
    from repro.launch.fleet import FleetSupervisor
    root = str(tmp_path / "ck")
    os.makedirs(root)
    spool = SnapshotSpool(str(tmp_path / "sp"))
    sup = FleetSupervisor(root, str(tmp_path / "queue.jsonl"),
                          ("default",),
                          snapshots=SnapshotSpool(spool.root))
    state = {"params": toy_params()}
    snap = ParamSnapshot.from_tree(1, state)
    # the trainer spills step 1 BEFORE any durable checkpoint exists
    spool.publish(1, snap.leaves, snap.treedef_hex)
    assert sup.publish_pending() == 1
    st = sup.queue.refresh().units[(1, "default")]
    assert st.source == "snapshot"
    # the durable commit arrives later: watcher discovery collapses in the
    # fold (no duplicate unit), and a fleet worker scores from the spool
    ckpt.save(root, 1, state)
    assert sup.publish_pending() == 0
    suite = make_suite(ds, baseline_run)
    worker = ValidatorWorker(
        root, suite,
        ledger=ValidationLedger(str(tmp_path / "queue.jsonl"),
                                expected_tasks=suite.task_names),
        queue=WorkQueue(str(tmp_path / "queue.jsonl"), "w0"),
        worker_id="w0", snapshots=SnapshotSpool(spool.root))
    assert worker.run_once() == 1
    rows = worker.ledger.rows()
    assert rows[0]["handoff"] == "snapshot"
    assert rows[0]["worker_id"] == "w0"


# ---------------------------------------------------------------------------
# End-to-end: trainer publishes, validator scores pre-durable
# ---------------------------------------------------------------------------

def test_trainer_handoff_end_to_end(tmp_path, ds, baseline_run):
    """Trainer._save publishes the host copy the moment it lands; the
    validator's verdict from it is bit-identical to re-validating the
    durable checkpoint afterwards."""
    from repro.train import optim
    from repro.train.trainer import Trainer, TrainerConfig

    ch = SnapshotChannel(capacity=8)
    root = str(tmp_path / "ck")
    tcfg = TrainerConfig(total_steps=4, ckpt_every=2, ckpt_dir=root,
                         log_every=2, async_save=True, snapshots=ch)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean(jnp.square(pred - batch["y"]))
        return loss, {"mse": loss}

    def batch_for(step, n=8):
        rng = np.random.default_rng(step)
        x = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
        return {"x": x, "y": x @ jnp.asarray([3.0, -2.0])}

    trainer = Trainer(tcfg, loss_fn, optim.adamw(5e-2),
                      {"w": jnp.zeros((2,))}, batch_for)
    trainer.run()
    # every saved step was published and marked durable via the hooks
    assert ch.durability(2) == "durable"
    assert ch.durability(4) == "durable"
    # the published snapshots reconstruct the committed checkpoints exactly
    for step in (2, 4):
        snap = ch.get(step)
        if snap is None:
            continue                    # retired already (validated race)
        state, _ = ckpt.restore(root, step)
        got = snap.state()
        assert np.array_equal(np.asarray(got["params"]["w"]),
                              np.asarray(state["params"]["w"]))
