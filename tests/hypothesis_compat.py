"""Optional-dependency guard for hypothesis (tier-1 must run without it).

``pytest.importorskip`` at module scope would skip whole files, losing the
plain (non-property) tests that share them; this shim instead degrades just
the ``@given`` tests to per-test skips when hypothesis is absent.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _Strategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (optional dependency)")(fn)
        return deco
