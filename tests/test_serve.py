"""Serving tier: hot-swap races, promotion failures, admission, events.

The zero-downtime claims as tests: a query racing a promotion never sees
a torn index (its answer is exactly ONE checkpoint's answer — the one it
attributes), a failed build leaves the old index serving, stacked select
events coalesce to the newest winner, and every swap is a replayable
fsync'd event carrying checkpoint/engine/score_dtype provenance.
"""

import os
import threading
import time

import jax
import numpy as np
import pytest

from benchmarks.common import toy_spec, train_toy_dr
from repro.ckpt import checkpoint as ckpt
from repro.control.events import (ACTUATION_KINDS, DECISION_KINDS,
                                  ControlEventLog)
from repro.data import corpus as corpus_lib
from repro.serve import (AdmissionController, IndexBuilder, Promoter,
                         QueryService, ServeConfig, ServeOverloaded,
                         replay_swaps)

K = 8


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    """Corpus + 3 committed checkpoints + the bitwise-expected answers of
    every (step, query) pair, computed offline — the oracle the torn-index
    test checks every racing response against."""
    base = tmp_path_factory.mktemp("serve")
    ds = corpus_lib.synthetic_retrieval_dataset(0, n_passages=180,
                                                n_queries=10)
    spec = toy_spec(ds.vocab)
    _, snaps = train_toy_dr(ds, spec, steps=60, snapshot_every=20)
    ckdir = str(base / "ckpts")
    for step, params in snaps:
        ckpt.save(ckdir, step, {"params": params})
    builder = IndexBuilder(spec, ds.corpus, ServeConfig(k=K, batch_size=32))
    expected = {}
    for step, params in snaps:
        index = builder.build(params, step)
        svc = QueryService(spec, k=K, max_batch=4)
        svc.install(index)
        for r in svc.answer([(q, ds.queries[q]) for q in ds.queries]):
            expected[(step, r.qid)] = (r.doc_ids, r.scores)
    steps = [s for s, _ in snaps]
    return {"base": base, "ds": ds, "spec": spec, "ckdir": ckdir,
            "steps": steps, "expected": expected}


def _stack(world, tmp, *, target_fn=None, events=None, **prom_kw):
    ds, spec = world["ds"], world["spec"]
    builder = IndexBuilder(spec, ds.corpus, ServeConfig(k=K, batch_size=32))
    service = QueryService(spec, k=K, max_batch=4, flush_ms=2.0)
    promoter = Promoter(builder, service, world["ckdir"],
                        target_fn=target_fn, control_events=events,
                        log=str(tmp / "serve_events.jsonl"), **prom_kw)
    return builder, service, promoter


# ---------------------------------------------------------------------------
# Hot-swap races
# ---------------------------------------------------------------------------

def test_no_torn_index_under_concurrent_promotions(world, tmp_path):
    """Queries hammered across repeated promotions: every response must
    equal the offline answer of exactly the step it attributes — a torn
    read (old corpus embeddings + new params, or a half-installed
    pointer) would produce an answer matching NO single checkpoint."""
    ds = world["ds"]
    target = {"step": world["steps"][0]}
    _, service, promoter = _stack(world, tmp_path,
                                  target_fn=lambda: target["step"])
    assert promoter.poll_once()
    service.start()
    stop = threading.Event()
    failures = []
    served_steps = set()

    def client(i):
        qids = list(ds.queries)
        j = 0
        while not stop.is_set():
            qid = qids[(i + j) % len(qids)]
            j += 1
            try:
                r = service.submit(qid, ds.queries[qid], timeout=30)
            except BaseException as e:     # noqa: BLE001 — a dropped query
                failures.append(("exc", qid, repr(e)))    # IS a blackout
                return
            served_steps.add(r.step)
            want = world["expected"][(r.step, r.qid)]
            if (r.doc_ids, r.scores) != want:
                failures.append((r.step, r.qid))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    try:
        # >= 3 promotions under sustained load, cycling through checkpoints
        flips = world["steps"][1:] + world["steps"][:1]
        for s in flips:
            time.sleep(0.05)
            target["step"] = s
            assert promoter.poll_once(), f"promotion to {s} failed"
    finally:
        stop.set()
        for t in threads:
            t.join()
        service.stop()
    assert not failures, f"torn responses: {failures[:5]}"
    assert len(promoter.swaps) >= 3
    assert service.served > 0
    assert served_steps <= set(world["steps"])


def test_failed_build_leaves_old_index_serving(world, tmp_path):
    """Two-phase discipline: a promotion dying mid-build must not touch
    the live pointer, and must leave an auditable swap_failed event."""
    ds = world["ds"]
    s1, s2 = world["steps"][:2]
    target = {"step": s1}

    def hook(step):
        if step == s2:
            raise RuntimeError("mid-build device loss")

    builder, service, promoter = _stack(world, tmp_path,
                                        target_fn=lambda: target["step"],
                                        build_hook=hook)
    assert promoter.poll_once() and service.live_step() == s1
    target["step"] = s2
    assert not promoter.poll_once()
    assert service.live_step() == s1          # old index still serving
    r = service.answer([(next(iter(ds.queries)),
                         ds.queries[next(iter(ds.queries))])])[0]
    assert r.step == s1
    assert (r.doc_ids, r.scores) == world["expected"][(s1, r.qid)]
    (step, err), = promoter.failures
    assert step == s2 and "mid-build" in str(err)
    fail_ev = [e for e in promoter.log.events() if e.kind == "swap_failed"]
    assert len(fail_ev) == 1 and fail_ev[0].step == s2
    assert fail_ev[0].payload["live_step"] == s1
    # the failure is transient: clearing it lets the next poll promote
    promoter.build_hook = None
    assert promoter.poll_once() and service.live_step() == s2


def test_verify_rejects_nonfinite_index(world, tmp_path):
    """Phase-two verify catches a checkpoint that encodes garbage (NaN
    embeddings) BEFORE the flip."""
    ds, spec = world["ds"], world["spec"]
    s1, s2 = world["steps"][:2]
    target = {"step": s1}
    builder, service, promoter = _stack(world, tmp_path,
                                        target_fn=lambda: target["step"])
    assert promoter.poll_once() and service.live_step() == s1
    poisoned = jax.tree_util.tree_map(lambda x: x * np.nan,
                                      ckpt.restore(world["ckdir"], s2)[0])
    promoter.params_extractor = lambda state: poisoned["params"]
    target["step"] = s2
    assert not promoter.poll_once()
    assert service.live_step() == s1
    assert "non-finite" in str(promoter.failures[-1][1])


def test_stacked_selects_coalesce(world, tmp_path):
    """N select events between polls collapse into ONE swap to the newest
    winner — intermediate checkpoints are never built."""
    s1, s2, s3 = world["steps"][:3]
    events = str(tmp_path / "control.jsonl")
    log = ControlEventLog(events)
    builder, service, promoter = _stack(world, tmp_path, events=events)
    log.emit("select", s1, best_step=s1)
    assert promoter.poll_once() and service.live_step() == s1
    builds_before = builder.index_builds
    log.emit("select", s2, best_step=s2)
    log.emit("select", s3, best_step=s3)
    assert promoter.poll_once()
    assert service.live_step() == s3
    assert builder.index_builds == builds_before + 1   # s2 never built
    assert not promoter.poll_once()                    # idempotent at rest


def test_select_during_inflight_swap_coalesces(world, tmp_path):
    """A select landing DURING a build doesn't deadlock and doesn't get
    lost: the in-flight swap completes, the next poll promotes the newer
    winner."""
    s1, s2, s3 = world["steps"][:3]
    events = str(tmp_path / "control.jsonl")
    log = ControlEventLog(events)

    def hook(step):
        if step == s2:                 # mid-build of s2, s3 gets selected
            log.emit("select", s3, best_step=s3)

    _, service, promoter = _stack(world, tmp_path, events=events,
                                  build_hook=hook)
    log.emit("select", s1, best_step=s1)
    assert promoter.poll_once()
    log.emit("select", s2, best_step=s2)
    assert promoter.poll_once() and service.live_step() == s2
    assert promoter.poll_once() and service.live_step() == s3
    assert [w for _, w in promoter.swaps] == [s1, s2, s3]


def test_uncommitted_selection_waits(world, tmp_path):
    """A selected-but-not-yet-durable checkpoint is not promoted (no
    failure either) — the promoter waits for the two-phase commit."""
    target = {"step": 999}
    _, service, promoter = _stack(world, tmp_path,
                                  target_fn=lambda: target["step"])
    assert not promoter.poll_once()
    assert promoter.failures == [] and service.live_step() is None


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_admission_rejects_past_bound(world, tmp_path):
    """Beyond max_pending in-flight requests, submits fail fast with
    ServeOverloaded instead of queueing unboundedly; capacity frees once
    the batcher drains."""
    ds, spec = world["ds"], world["spec"]
    builder = IndexBuilder(spec, ds.corpus, ServeConfig(k=K, batch_size=32))
    adm = AdmissionController(max_pending=1)
    service = QueryService(spec, k=K, max_batch=4, flush_ms=2.0,
                           admission=adm)
    params = ckpt.restore(world["ckdir"], world["steps"][0])[0]["params"]
    service.install(builder.build(params, world["steps"][0]))
    qid = next(iter(ds.queries))
    # service NOT started: the first submit occupies the one slot forever
    blocker = threading.Thread(
        target=lambda: pytest.raises(TimeoutError, service.submit, qid,
                                     ds.queries[qid], timeout=0.7))
    blocker.start()
    time.sleep(0.1)
    with pytest.raises(ServeOverloaded):
        service.submit(qid, ds.queries[qid], timeout=1.0)
    blocker.join()
    assert adm.rejected == 1 and adm.peak == 1
    # slot released after the blocked request timed out
    service.start()
    try:
        r = service.submit(qid, ds.queries[qid], timeout=30)
        assert r.step == world["steps"][0]
    finally:
        service.stop()
    assert adm.pending == 0


def test_admission_controller_counters():
    adm = AdmissionController(max_pending=2)
    assert adm.try_acquire() and adm.try_acquire()
    assert not adm.try_acquire()
    adm.release()
    assert adm.try_acquire()
    assert adm.admitted == 3 and adm.rejected == 1 and adm.peak == 2
    with pytest.raises(ValueError):
        AdmissionController(max_pending=0)


# ---------------------------------------------------------------------------
# Swap events: provenance + replay
# ---------------------------------------------------------------------------

def test_swap_events_carry_provenance_and_replay(world, tmp_path):
    """Every swap is an actuation event with full provenance, and the
    live-step timeline is re-derivable offline from the log alone."""
    ds = world["ds"]
    s1, s2 = world["steps"][:2]
    target = {"step": s1}
    _, service, promoter = _stack(world, tmp_path,
                                  target_fn=lambda: target["step"])
    assert promoter.poll_once()
    target["step"] = s2
    assert promoter.poll_once()
    evs = [e for e in promoter.log.events() if e.kind == "swap"]
    assert [e.step for e in evs] == [s1, s2]
    for e in evs:
        assert e.payload["engine"] == "serve"
        assert e.payload["score_dtype"] == "f32"
        assert e.payload["n_docs"] == len(ds.corpus)
        assert e.payload["build_s"] >= 0
    assert evs[0].payload["prev_step"] == -1
    assert evs[1].payload["prev_step"] == s1
    # offline replay reconstructs the live timeline from the fsync'd file
    timeline = replay_swaps(str(tmp_path / "serve_events.jsonl"))
    assert [(t["prev_step"], t["step"]) for t in timeline] == \
        [(-1, s1), (s1, s2)]
    # swaps are actuations: excluded from decision replay comparison
    assert {"swap", "swap_failed"} <= ACTUATION_KINDS
    assert not ({"swap", "swap_failed"} & DECISION_KINDS)
    assert promoter.log.decisions() == []


def test_background_promoter_loop(world, tmp_path):
    """The threaded promoter: select events flow to live swaps without any
    explicit polling by the caller."""
    s1, s2 = world["steps"][:2]
    events = str(tmp_path / "control.jsonl")
    log = ControlEventLog(events)
    _, service, promoter = _stack(world, tmp_path, events=events,
                                  poll_interval_s=0.02)
    promoter.start()
    try:
        log.emit("select", s1, best_step=s1)
        deadline = time.time() + 30
        while service.live_step() != s1 and time.time() < deadline:
            time.sleep(0.02)
        assert service.live_step() == s1
        log.emit("select", s2, best_step=s2)
        while service.live_step() != s2 and time.time() < deadline:
            time.sleep(0.02)
        assert service.live_step() == s2
    finally:
        promoter.stop()


# ---------------------------------------------------------------------------
# Index build economics + GC contract
# ---------------------------------------------------------------------------

def test_token_store_built_once_across_builds(world):
    """The corpus TokenStore (the checkpoint-independent half of an index
    build) is padded once at construction and shared by every promoted
    checkpoint — only the encode pass reruns."""
    ds, spec = world["ds"], world["spec"]
    builder = IndexBuilder(spec, ds.corpus, ServeConfig(k=K, batch_size=32))
    store = builder.store
    p1 = ckpt.restore(world["ckdir"], world["steps"][0])[0]["params"]
    p2 = ckpt.restore(world["ckdir"], world["steps"][1])[0]["params"]
    i1, i2 = builder.build(p1, 1), builder.build(p2, 2)
    assert builder.store is store and builder.index_builds == 2
    assert i1.doc_ids is builder.doc_ids and i2.doc_ids is builder.doc_ids
    assert i1.n_docs == i2.n_docs == len(ds.corpus)


def test_promoter_protect_set(world, tmp_path):
    """The GC contract: live + in-flight-promotion steps are protected;
    nothing is protected before the first install."""
    s1, s2 = world["steps"][:2]
    target = {"step": s1}
    seen = {}

    def hook(step):
        # snapshot DURING the build: both old-live and promoting protected
        seen["mid"] = promoter.protect_set()

    _, service, promoter = _stack(world, tmp_path,
                                  target_fn=lambda: target["step"],
                                  build_hook=hook)
    assert promoter.protect_set() == set()
    assert promoter.poll_once()
    assert promoter.protect_set() == {s1}
    target["step"] = s2
    assert promoter.poll_once()
    assert seen["mid"] == {s1, s2}
    assert promoter.protect_set() == {s2}


# ---------------------------------------------------------------------------
# launch/serve.py: retrieval entry point + LM-demo compatibility
# ---------------------------------------------------------------------------

def toy_encoder_from_cli(args):
    """--encoder hook for the launch CLI test."""
    return toy_spec(503)


def test_launch_serve_is_retrieval_cli(world, tmp_path, capsys):
    """The rebuilt launch/serve.py serves retrieval queries end to end:
    promote latest committed checkpoint, answer the query file, report
    latency percentiles."""
    from repro.launch.serve import main
    ds = world["ds"]
    cdir = tmp_path / "corpus"
    cdir.mkdir()
    corpus_lib.write_jsonl(str(cdir / "c.jsonl"), ds.corpus)
    qfile = tmp_path / "q.jsonl"
    corpus_lib.write_jsonl(str(qfile), ds.queries)
    rc = main(["--candidate_dir", str(cdir), "--query_file", str(qfile),
               "--ckpts_dir", world["ckdir"], "--k", "5",
               "--max_batch", "4",
               "--encoder", "tests.test_serve:toy_encoder_from_cli"])
    assert rc == 0
    out = capsys.readouterr().out
    assert f"step={world['steps'][-1]}" in out and "p99=" in out
    assert os.path.exists(os.path.join(world["ckdir"],
                                       "serve_events.jsonl"))


def test_launch_serve_cli_docs_are_retrieval():
    """The stale LM prefill/decode surface is gone from launch/serve —
    and the demo survives, importable from launch/lm_demo."""
    import repro.launch.lm_demo as lm_demo
    import repro.launch.serve as serve
    assert "retrieval" in (serve.__doc__ or "").lower()
    assert "--arch qwen2" not in (serve.__doc__ or "")
    assert serve.serve_batch is lm_demo.serve_batch   # compat re-export
    assert callable(lm_demo.serve_batch)


def test_core_cli_serve_one_shot(world, tmp_path, capsys):
    """`asyncval --serve`: validation and serving in one process — the
    control plane picks the best checkpoint, the promoter promotes exactly
    that pick, and the one-shot serve pass answers the validation query
    file attributing it.  Swap provenance lands in <run>_serve.jsonl."""
    from repro.core.cli import main
    from repro.serve import replay_swaps
    ds = world["ds"]
    cdir = tmp_path / "corpus"
    cdir.mkdir()
    corpus_lib.write_jsonl(str(cdir / "c.jsonl"), ds.corpus)
    qfile = tmp_path / "q.jsonl"
    corpus_lib.write_jsonl(str(qfile), ds.queries)
    qrels = tmp_path / "qrels.txt"
    with open(qrels, "w") as f:
        for qid, docs in ds.qrels.items():
            for did, g in docs.items():
                f.write(f"{qid} 0 {did} {g}\n")
    outdir = tmp_path / "out"
    rc = main(["--query_file", str(qfile),
               "--candidate_dir", str(cdir),
               "--ckpts_dir", world["ckdir"],
               "--qrel_file", str(qrels),
               "--metrics", "MRR@10",
               "--keep_top_k", "3",      # control plane drives promotion
               "--run_name", "t", "--output_dir", str(outdir),
               "--serve", "--serve_k", "5", "--serve_batch", "4",
               "--encoder", "tests.test_serve:toy_encoder_from_cli"])
    assert rc == 0
    out = capsys.readouterr().out
    swaps = replay_swaps(str(outdir / "t_serve.jsonl"))
    assert len(swaps) == 1               # one-shot: exactly one promotion
    best = swaps[0]["step"]
    assert best in world["steps"]
    assert f"[serve] answered {len(ds.queries)} queries" in out
    assert f"step={best}" in out         # responses attribute the pick
    # the promoted step is the control plane's selection: its ledger MRR
    # must equal the best MRR observed (ties resolve inside the selector)
    import json
    rows = [json.loads(l) for l in open(outdir / "t_ledger.jsonl")]
    mrr = {r["step"]: r["metrics"]["MRR@10"] for r in rows}
    assert mrr[best] == max(mrr.values())
