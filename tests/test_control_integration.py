"""End-to-end convergence control: trainer + async validator + control plane.

The acceptance scenario for the control subsystem, on synthetic data:

  * training runs with a generous step budget and NEVER blocks on
    validation; the async validator feeds every ledger row to the control
    plane;
  * the plateau detector publishes an atomic STOP marker; the trainer polls
    it between steps and halts early;
  * quality-aware GC leaves exactly top-k ∪ protected checkpoints on disk;
  * the greedy checkpoint soup materializes a virtual checkpoint that
    re-validates (through the ordinary watcher/validator path) at least as
    well as the best single checkpoint;
  * replaying the validation ledger offline reproduces the identical
    decision sequence (determinism).
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.control import ControlConfig, ControlPlane, replay_ledger, \
    stop_requested
from repro.control.ensemble import VIRTUAL_KEY
from repro.core.pipeline import ValidationConfig, ValidationPipeline
from repro.core.samplers import RunFileTopK
from repro.core.validator import AsyncValidator
from repro.data import corpus as synthetic_ds
from repro.models.biencoder import EncoderSpec
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig

DIM, VOCAB = 16, 211


def _toy_encode(params, tokens, mask):
    table = params["table"]
    emb = jnp.take(table, tokens, axis=0)
    m = mask.astype(emb.dtype)[..., None]
    v = (emb * m).sum(1) / jnp.clip(m.sum(1), 1e-6)
    return v / jnp.clip(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def toy_spec():
    return EncoderSpec(
        name="toy", dim=DIM, encode_query=_toy_encode,
        encode_passage=_toy_encode,
        init=lambda rng: {"table": jax.random.normal(rng, (VOCAB, DIM))},
        q_max_len=10, p_max_len=26)


def test_control_plane_end_to_end(tmp_path):
    workdir = str(tmp_path)
    root = os.path.join(workdir, "ck")
    stop_path = os.path.join(workdir, "STOP")

    spec = toy_spec()
    ds = synthetic_ds.synthetic_retrieval_dataset(0, n_passages=120,
                                                  n_queries=16, vocab=VOCAB)
    baseline = synthetic_ds.lexical_baseline_run(ds, k=30)
    pipe = ValidationPipeline(
        spec, ds.corpus, ds.queries, ds.qrels,
        ValidationConfig(metrics=("MRR@10",), k=20, batch_size=32),
        sampler=RunFileTopK(depth=5), baseline_run=baseline)

    cfg = ControlConfig(metric="MRR@10", early_stop=True, patience=3,
                        min_delta=1e-6, keep_top_k=2, ensemble_top_k=2)
    plane = ControlPlane(root, cfg, stop_path=stop_path,
                         event_path=os.path.join(workdir, "control.jsonl"))
    validator = AsyncValidator(
        root, pipe, controller=plane, poll_interval_s=0.01,
        ledger_path=os.path.join(workdir, "ledger.jsonl"))

    # training converges to a fixed target table, so the validation metric
    # provably plateaus: loss = ||table - T||^2 (a quadratic the optimizer
    # drives to zero while MRR freezes once the ranking stabilizes).
    target = spec.init(jax.random.PRNGKey(7))["table"]

    def loss_fn(params, batch):
        d = params["table"] - target
        return jnp.mean(d * d), {}

    def batch_iter(step):
        time.sleep(0.004)      # a realistic per-step cost so checkpoints
        return {}              # outpace validation without racing the test

    total_budget = 3000
    tcfg = TrainerConfig(total_steps=total_budget, ckpt_every=20,
                         log_every=20, ckpt_dir=root, stop_file=stop_path)
    trainer = Trainer(tcfg, loss_fn, optim.adamw(0.1, weight_decay=0.0),
                      {"table": spec.init(jax.random.PRNGKey(0))["table"]},
                      batch_iter)

    train_history = []

    def on_metrics(step, m):
        train_history.append((step, m["loss"]))
        plane.note_train(step, m)

    validator.start()
    t0 = time.time()
    trainer.run(on_metrics=on_metrics)
    train_wall = time.time() - t0
    validator.stop(drain=True)           # validate whatever is committed
    assert not validator.errors

    # -- asynchronous early stop --------------------------------------------
    assert trainer.stopped_early, "plateau never detected"
    assert trainer.step < total_budget   # halted early, not on the budget
    verdict = stop_requested(stop_path)
    assert verdict is not None and verdict["reason"] == "plateau"
    assert trainer.stop_verdict["reason"] == "plateau"
    # training never blocks on validation: wall time is training-shaped
    # (steps x per-step cost), not training + validation backlog.  Generous
    # 4x bound — a blocking design would show the full validation series.
    assert train_wall < 4.0 * (trainer.step * 0.004 + 2.0)

    # -- quality-aware GC: exactly top-k ∪ protected ------------------------
    # after the drain everything committed is validated, so protected = ∅
    assert plane.cfg.keep_top_k == 2
    expected_keep = plane.selector.keep_set(protect=validator.protect_set(),
                                            k=2)
    assert set(ckpt.list_steps(root)) == expected_keep
    assert len(expected_keep) == 2

    # -- ensemble: soup >= best single, via the NORMAL validation path ------
    best_single = plane.selector.best_value
    best_single_step = plane.selector.best_step
    vstep = plane.build_ensemble(
        lambda p: pipe.validate_params(p).metrics["MRR@10"])
    assert vstep is not None
    _, extra = ckpt.restore(root, vstep)
    assert extra[VIRTUAL_KEY] == plane.ensemble_members
    n = validator.validate_pending()     # watcher discovers the soup ckpt
    assert n == 1
    soup_row = validator.ledger.rows()[-1]
    assert soup_row["step"] == vstep
    assert soup_row["metrics"]["MRR@10"] >= best_single - 1e-12, \
        f"soup {soup_row['metrics']} < best single {best_single} " \
        f"(step {best_single_step})"

    # -- determinism: offline replay reproduces every decision --------------
    offline = replay_ledger(validator.ledger.rows(), cfg,
                            train_history=train_history)
    assert offline.events.decisions() == plane.events.decisions()
    assert offline.stopped and offline.earlystop.reason == "plateau"
    assert offline.selector.top_steps() == plane.selector.top_steps()
    # and the persisted event log round-trips
    with open(os.path.join(workdir, "control.jsonl")) as f:
        kinds = [json.loads(l)["kind"] for l in f if l.strip()]
    assert "stop" in kinds and "gc" in kinds and "ensemble" in kinds


def test_stale_stop_marker_cleared_on_new_run(tmp_path):
    """A STOP verdict belongs to one run: a restarted/continued run in the
    same workdir must clear it and train, not halt at step 0."""
    from repro.control.earlystop import write_stop_marker
    from repro.launch.train import run

    class Args:
        arch = "dr-bert-base"
        workdir = str(tmp_path / "run")
        steps = 8
        ckpt_every = 8
        batch_size = 8
        corpus_size = 80
        n_queries = 12
        q_max_len = 10
        p_max_len = 26
        depth = 10
        lr = 2e-3
        seed = 0
        subset = True
        sync = False
        full = False
        early_stop_patience = 3            # control plane armed

    os.makedirs(Args.workdir, exist_ok=True)
    write_stop_marker(os.path.join(Args.workdir, "STOP"),
                      {"reason": "plateau", "step": 999})   # stale verdict
    res = run(Args())
    assert not res["stopped_early"]        # trained through the budget
    assert res["validated_steps"] == [8]


def test_sync_mode_control_plane_still_works(tmp_path):
    """Fig. 1a (inline validation) composes with the control plane too: the
    same plateau stops training via the same marker, synchronously."""
    workdir = str(tmp_path)
    root = os.path.join(workdir, "ck")
    stop_path = os.path.join(workdir, "STOP")
    spec = toy_spec()
    ds = synthetic_ds.synthetic_retrieval_dataset(1, n_passages=80,
                                                  n_queries=12, vocab=VOCAB)
    baseline = synthetic_ds.lexical_baseline_run(ds, k=20)
    pipe = ValidationPipeline(
        spec, ds.corpus, ds.queries, ds.qrels,
        ValidationConfig(metrics=("MRR@10",), k=10, batch_size=32),
        sampler=RunFileTopK(depth=5), baseline_run=baseline)
    plane = ControlPlane(root, ControlConfig(metric="MRR@10",
                                             early_stop=True, patience=2,
                                             min_delta=1e-6),
                         stop_path=stop_path)
    validator = AsyncValidator(root, pipe, controller=plane)
    target = spec.init(jax.random.PRNGKey(3))["table"]

    def loss_fn(params, batch):
        d = params["table"] - target
        return jnp.mean(d * d), {}

    tcfg = TrainerConfig(total_steps=2000, ckpt_every=20, log_every=20,
                         ckpt_dir=root, stop_file=stop_path,
                         async_save=False)
    trainer = Trainer(tcfg, loss_fn, optim.adamw(0.1, weight_decay=0.0),
                      {"table": spec.init(jax.random.PRNGKey(1))["table"]},
                      lambda step: {})

    def on_metrics(step, m):
        plane.note_train(step, m)
        validator.validate_pending()     # paper Fig. 1a: inline validation

    trainer.run(on_metrics=on_metrics)
    assert trainer.stopped_early and trainer.step < 2000
    assert plane.earlystop.reason == "plateau"
