"""Per-arch smoke tests for the recsys + gnn families (reduced configs):
one forward/train step on CPU, asserting output shapes + finiteness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import graphcast as gcast
from repro.models import nn
from repro.models import recsys as rcs

RECSYS_ARCHS = ["bert4rec", "sasrec", "mind", "deepfm"]


def _recsys_setup(arch):
    cfg = registry.get(arch).smoke_config()
    if cfg.model_type in ("bert4rec", "sasrec", "mind"):
        cfg = dataclasses.replace(cfg, item_vocab=500, seq_len=16,
                                  n_negatives=32, n_serve_candidates=20)
    params = nn.materialize(rcs.init(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _recsys_batch(cfg, B=4, seed=1):
    rng = np.random.default_rng(seed)
    S = cfg.seq_len
    if cfg.model_type == "sasrec":
        return {"hist": jnp.asarray(rng.integers(1, cfg.item_vocab, (B, S)),
                                    jnp.int32),
                "pos": jnp.asarray(rng.integers(1, cfg.item_vocab, (B, S)),
                                   jnp.int32),
                "neg_ids": jnp.asarray(
                    rng.integers(1, cfg.item_vocab, (cfg.n_negatives,)),
                    jnp.int32)}
    if cfg.model_type == "bert4rec":
        M = 4
        return {"tokens": jnp.asarray(rng.integers(1, cfg.item_vocab, (B, S)),
                                      jnp.int32),
                "mlm_positions": jnp.asarray(rng.integers(0, S, (B, M)),
                                             jnp.int32),
                "mlm_labels": jnp.asarray(rng.integers(1, cfg.item_vocab,
                                                       (B, M)), jnp.int32),
                "mlm_mask": jnp.ones((B, M), jnp.float32),
                "neg_ids": jnp.asarray(
                    rng.integers(1, cfg.item_vocab, (cfg.n_negatives,)),
                    jnp.int32)}
    if cfg.model_type == "mind":
        return {"hist": jnp.asarray(rng.integers(1, cfg.item_vocab, (B, S)),
                                    jnp.int32),
                "target": jnp.asarray(rng.integers(1, cfg.item_vocab, (B,)),
                                      jnp.int32),
                "neg_ids": jnp.asarray(
                    rng.integers(1, cfg.item_vocab, (cfg.n_negatives,)),
                    jnp.int32)}
    F, M = cfg.n_fields, cfg.max_hot
    rows = cfg.total_rows
    return {"ids": jnp.asarray(rng.integers(0, rows, (B, F, M)), jnp.int32),
            "valid": jnp.ones((B, F, M), bool),
            "label": jnp.asarray(rng.integers(0, 2, (B,)), jnp.float32)}


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_step(arch):
    cfg, params = _recsys_setup(arch)
    batch = _recsys_batch(cfg)
    (loss, _), grads = jax.jit(jax.value_and_grad(
        lambda p, b: rcs.loss_fn(p, cfg, b), has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_serve(arch):
    cfg, params = _recsys_setup(arch)
    rng = np.random.default_rng(2)
    B = 3
    if cfg.model_type == "deepfm":
        batch = {k: v for k, v in _recsys_batch(cfg, B=B).items()
                 if k != "label"}
        scores = jax.jit(lambda p, b: rcs.serve_fn(p, cfg, b))(params, batch)
        assert scores.shape == (B,)
    else:
        C = cfg.n_serve_candidates
        batch = {"hist": jnp.asarray(
                     rng.integers(1, cfg.item_vocab, (B, cfg.seq_len)),
                     jnp.int32),
                 "cand_ids": jnp.asarray(rng.integers(1, cfg.item_vocab, (C,)),
                                         jnp.int32)}
        scores = jax.jit(lambda p, b: rcs.serve_fn(p, cfg, b))(params, batch)
        assert scores.shape == (B, C)
    assert np.isfinite(np.asarray(scores, np.float32)).all()


def test_mind_interests_shape():
    cfg, params = _recsys_setup("mind")
    rng = np.random.default_rng(3)
    hist = jnp.asarray(rng.integers(1, cfg.item_vocab, (2, cfg.seq_len)),
                       jnp.int32)
    interests = rcs.user_embed(params, cfg, hist)
    assert interests.shape == (2, cfg.n_interests, cfg.embed_dim)
    assert np.isfinite(np.asarray(interests, np.float32)).all()


def test_embedding_bag_matches_torch_semantics():
    """EmbeddingBag(sum/mean/max) against a numpy loop oracle."""
    from repro.models.embedding_ops import embedding_bag
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 50, (30,)), jnp.int32)
    seg = jnp.asarray(np.sort(rng.integers(0, 6, (30,))), jnp.int32)
    valid = jnp.asarray(rng.random(30) > 0.2)
    for mode in ("sum", "mean", "max"):
        out = embedding_bag(table, ids, seg, 6, mode=mode, valid=valid)
        tt, vv = np.asarray(table), np.asarray(valid)
        for b in range(6):
            sel = (np.asarray(seg) == b) & vv
            rows = tt[np.asarray(ids)[sel]]
            if mode == "sum":
                exp = rows.sum(0) if sel.any() else np.zeros(8)
            elif mode == "mean":
                exp = rows.mean(0) if sel.any() else np.zeros(8)
            else:
                exp = rows.max(0) if sel.any() else np.zeros(8)
            np.testing.assert_allclose(np.asarray(out)[b], exp, rtol=1e-5,
                                       atol=1e-5)


# ---------------------------------------------------------------------------
# graphcast
# ---------------------------------------------------------------------------


def _graph(cfg, n=20, e=60, seed=5):
    rng = np.random.default_rng(seed)
    return {"node_feat": jnp.asarray(rng.normal(size=(n, cfg.d_feat)),
                                     jnp.float32),
            "src": jnp.asarray(rng.integers(0, n, (e,)), jnp.int32),
            "dst": jnp.asarray(rng.integers(0, n, (e,)), jnp.int32),
            "target": jnp.asarray(rng.normal(size=(n, cfg.n_vars)),
                                  jnp.float32)}


def test_graphcast_smoke_train_step():
    cfg = registry.get("graphcast").smoke_config()
    params = nn.materialize(gcast.init(jax.random.PRNGKey(0), cfg))
    batch = _graph(cfg)
    (loss, _), grads = jax.jit(jax.value_and_grad(
        lambda p, b: gcast.loss_fn(p, cfg, b), has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_graphcast_forward_shapes():
    cfg = registry.get("graphcast").smoke_config()
    params = nn.materialize(gcast.init(jax.random.PRNGKey(0), cfg))
    b = _graph(cfg, n=13, e=31)
    pred = gcast.forward(params, cfg, b["node_feat"], b["src"], b["dst"])
    assert pred.shape == (13, cfg.n_vars)
    assert np.isfinite(np.asarray(pred)).all()


def test_graphcast_isolated_node_invariance():
    """A node with no incident edges must only be affected by its own MLP
    path (message passing sums nothing into it)."""
    cfg = registry.get("graphcast").smoke_config()
    params = nn.materialize(gcast.init(jax.random.PRNGKey(0), cfg))
    n = 10
    rng = np.random.default_rng(6)
    feat = jnp.asarray(rng.normal(size=(n, cfg.d_feat)), jnp.float32)
    # edges only among nodes 0..4; node 9 isolated
    src = jnp.asarray(rng.integers(0, 5, (20,)), jnp.int32)
    dst = jnp.asarray(rng.integers(0, 5, (20,)), jnp.int32)
    p1 = gcast.forward(params, cfg, feat, src, dst)
    feat2 = feat.at[0].set(0.0)          # perturb a connected node
    p2 = gcast.forward(params, cfg, feat2, src, dst)
    np.testing.assert_allclose(np.asarray(p1[9]), np.asarray(p2[9]),
                               rtol=1e-5, atol=1e-6)


def test_graphcast_aggregators():
    cfg = registry.get("graphcast").smoke_config()
    for agg in ("sum", "mean", "max"):
        c = dataclasses.replace(cfg, aggregator=agg)
        params = nn.materialize(gcast.init(jax.random.PRNGKey(0), c))
        b = _graph(c, n=8, e=20)
        pred = gcast.forward(params, c, b["node_feat"], b["src"], b["dst"])
        assert np.isfinite(np.asarray(pred)).all(), agg


def test_neighbor_sampler():
    """The minibatch_lg cell needs a real neighbor sampler."""
    from repro.data.sampler import (CSRGraph, sample_subgraph,
                                    sampled_subgraph_shape)
    rng = np.random.default_rng(7)
    n, e = 200, 1200
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    g = CSRGraph.from_edges(src, dst, n)
    seeds = rng.choice(n, 8, replace=False).astype(np.int64)
    sub = sample_subgraph(g, seeds, (5, 3), rng)
    max_n, max_e = sampled_subgraph_shape(8, (5, 3))
    assert sub["nodes"].shape == (max_n,)
    assert sub["src"].shape == (max_e,) and sub["dst"].shape == (max_e,)
    # seeds come first in the relabelled node list
    assert (sub["nodes"][:8] == seeds).all()
    # real (unmasked) edges point at real local node ids
    n_real = int(sub["node_mask"].sum())
    real_edges = sub["edge_mask"]
    assert (sub["src"][real_edges] < n_real).all()
    assert (sub["dst"][real_edges] < n_real).all()
    # every sampled edge exists in the original graph
    glob = sub["nodes"]
    edge_set = set(zip(src.tolist(), dst.tolist()))
    for s_l, d_l in zip(sub["src"][real_edges], sub["dst"][real_edges]):
        # sampler stores neighbor(v) -> center(u) with v from u's out-edges
        assert (int(glob[d_l]), int(glob[s_l])) in edge_set
