"""Integration tests: pallas attn inside the model, end-to-end async
train+validate, and the dry-run machinery on a small simulated mesh."""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import nn
from repro.models import transformer as tfm


def test_pallas_attention_path_matches_xla():
    for arch in ("qwen2-0.5b", "dr-bert-base"):
        cfg = registry.get(arch).smoke_config()
        cfg = dataclasses.replace(cfg, compute_dtype=jnp.float32)
        params = nn.materialize(tfm.init(jax.random.PRNGKey(0), cfg))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 1,
                                  cfg.vocab_size)
        h1, _, _ = tfm.forward(params, cfg, toks)
        cfgp = dataclasses.replace(cfg, attn_impl="pallas")
        h2, _, _ = tfm.forward(params, cfgp, toks)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=3e-4, atol=3e-4)


def test_end_to_end_async_training_and_validation(tmp_path):
    """The launch/train.py deployment: async validator beats checkpoints out
    of a live training run, MRR improves, ledger is written."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from repro.launch.train import run

    class Args:
        arch = "dr-bert-base"
        workdir = str(tmp_path / "run")
        steps = 24
        ckpt_every = 8
        batch_size = 8
        corpus_size = 150
        n_queries = 25
        q_max_len = 10
        p_max_len = 26
        depth = 15
        lr = 2e-3
        seed = 0
        subset = True
        sync = False
        full = False

    res = run(Args())
    assert res["mode"] == "async"
    assert res["validated_steps"] == [8, 16, 24]
    assert not res["errors"]
    mrrs = [res["metrics"][s]["MRR@10"] for s in (8, 24)]
    assert mrrs[1] >= mrrs[0] - 0.05          # training not diverging


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    """build_step + jit(lower/compile) + analysis on an 4x2 simulated mesh
    with the paper's own arch (subprocess so device count never leaks)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from jax.sharding import Mesh
        import numpy as np
        from repro.launch import analysis
        from repro.launch.steps import build_step
        from repro.distributed import compat

        mesh = compat.make_mesh((4, 2), ("data", "model"))
        spec = build_step("dr-bert-base", "encode_corpus", mesh,
                          variant="cost")
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings)
        lowered = jitted.lower(*spec.abstract_args)
        compiled = lowered.compile()
        m = analysis.measure(compiled, 8)
        assert m.flops > 0
        assert m.bytes_accessed > 0
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes >= 0
        r = analysis.roofline(m, spec.meta["model_flops"] / 8)
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert 0 <= r["roofline_frac"]
        print("DRYRUN_OK", r["bottleneck"])
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "DRYRUN_OK" in out.stdout, out.stdout + out.stderr


def test_collective_parser():
    from repro.launch.analysis import parse_collectives
    hlo = """
      %ag = bf16[64,128]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]
      %ar = f32[32]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
      %rs = f32[8,8]{1,0} reduce-scatter(%z), replica_groups=[2,128]<=[256]
      %cp = bf16[4,4]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
      %notacoll = f32[9]{0} add(%a, %b)
    """
    ops = parse_collectives(hlo, 256)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "collective-permute",
                     "reduce-scatter"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.group == 16
    assert ag.result_bytes == 64 * 128 * 2
    assert ag.wire_bytes == (15 / 16) * 64 * 128 * 2
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.group == 4
    assert ar.wire_bytes == 2 * (3 / 4) * 32 * 4
    rs = next(o for o in ops if o.kind == "reduce-scatter")
    assert rs.group == 128
    assert rs.wire_bytes == 127 * 64 * 4


def test_extrapolation_math():
    from repro.launch.analysis import Measurement, extrapolate
    q1 = Measurement(flops=10.0, bytes_accessed=100.0, coll_wire_bytes=4.0,
                     coll_ops=[], hbm_bytes_est=50.0)
    q2 = Measurement(flops=13.0, bytes_accessed=130.0, coll_wire_bytes=5.0,
                     coll_ops=[], hbm_bytes_est=60.0)
    full = extrapolate(q1, q2, n_scaled=10)
    assert full.flops == pytest.approx(10.0 + 9 * 3.0)
    assert full.bytes_accessed == pytest.approx(100.0 + 9 * 30.0)
    assert full.coll_wire_bytes == pytest.approx(4.0 + 9 * 1.0)
    assert full.hbm_bytes_est == pytest.approx(50.0 + 9 * 10.0)
    # no second measurement -> exact single measurement
    assert extrapolate(q1, None, 5).flops == 10.0
