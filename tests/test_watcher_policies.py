"""Watcher policy × retry interplay + the adaptive BudgetPolicy.

Satellite coverage for the control plane's scheduling layer: the stride
seen-leak fix, requeued failing steps under every skipping policy, and the
protect_set()/quality-GC interaction (no validated-but-unprotected deletion
races, no permanent protection leaks for policy-skipped steps)."""

import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.core.watcher import BudgetPolicy, CheckpointWatcher, Policy


def _save(root, step):
    ckpt.save(root, step, {"x": jnp.zeros(1)})


# ---------------------------------------------------------------------------
# Stride policy: leak fix + collapsed condition
# ---------------------------------------------------------------------------

def test_stride_select_single_modulus_condition():
    p = Policy(kind="stride", stride=10)
    assert p.select([10, 15, 20, 25, 30]) == [10, 20, 30]
    assert p.select([15]) == []
    assert Policy(kind="stride", stride=0).select([3, 4]) == [3, 4]  # clamped


def test_stride_nonselected_steps_marked_seen_no_regrow(tmp_path):
    """Regression: off-stride steps used to stay pending forever, re-listed
    and re-filtered on every poll."""
    root = str(tmp_path / "ck")
    for s in (10, 15, 20, 25):
        _save(root, s)
    w = CheckpointWatcher(root, policy=Policy(kind="stride", stride=10))
    assert w.poll() == [10, 20]
    assert w._seen == {10, 15, 20, 25}         # off-stride consumed too
    assert w.poll() == []                      # nothing regrows
    assert w.skipped == {15, 25}


def test_latest_first_skipped_tracked(tmp_path):
    root = str(tmp_path / "ck")
    for s in (1, 2, 3):
        _save(root, s)
    w = CheckpointWatcher(root, policy=Policy(kind="latest_first"))
    assert w.poll() == [3]
    assert w.skipped == {1, 2}


# ---------------------------------------------------------------------------
# Requeue (failed validation) × each policy
# ---------------------------------------------------------------------------

def test_requeue_under_stride_retries_on_stride_step(tmp_path):
    root = str(tmp_path / "ck")
    for s in (10, 15, 20):
        _save(root, s)
    w = CheckpointWatcher(root, policy=Policy(kind="stride", stride=10))
    assert w.poll() == [10, 20]
    w.requeue(20)                              # validation of 20 failed
    assert w.poll() == [20]                    # retried (still on-stride)
    assert w.poll() == []


def test_requeue_under_latest_first_loses_to_newer(tmp_path):
    """A requeued step re-enters the policy: if a newer checkpoint arrived,
    latest_first drops the failed one as stale — the staleness bound, not a
    lost retry."""
    root = str(tmp_path / "ck")
    _save(root, 1)
    w = CheckpointWatcher(root, policy=Policy(kind="latest_first"))
    assert w.poll() == [1]
    w.requeue(1)
    assert w.poll() == [1]                     # no newer rival: retried
    w.requeue(1)
    _save(root, 2)
    assert w.poll() == [2]                     # newer wins; 1 skipped
    assert 1 in w.skipped
    assert w.poll() == []


def test_requeue_under_budget_always_retries_newest(tmp_path):
    root = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        _save(root, s)
    w = CheckpointWatcher(root, policy=BudgetPolicy(target_depth=1))
    first = w.poll()
    assert first and first[-1] == 4            # newest always selected
    w.requeue(4)
    assert 4 in w.poll()                       # newest retried after failure


def test_requeue_unskips_an_explicitly_requeued_step(tmp_path):
    root = str(tmp_path / "ck")
    for s in (10, 15):
        _save(root, s)
    w = CheckpointWatcher(root, policy=Policy(kind="stride", stride=10))
    w.poll()
    assert w.skipped == {15}
    w.requeue(15)                              # operator override
    assert w.skipped == set()
    # fifo-reconfigured watcher would now hand it out; under stride it is
    # re-skipped deterministically
    assert w.poll() == []
    assert w.skipped == {15}


# ---------------------------------------------------------------------------
# BudgetPolicy adaptation
# ---------------------------------------------------------------------------

def test_budget_policy_widens_under_backlog_and_recovers():
    p = BudgetPolicy(target_depth=1, max_stride=8)
    sel = p.select(list(range(1, 9)))          # depth 8 > target: widen
    assert p.effective_stride == 2
    assert sel[-1] == 8                        # newest always included
    p.select(list(range(9, 17)))               # still deep: widen again
    assert p.effective_stride == 4
    p.select([17])                             # drained: relax
    p.select([18])
    assert p.effective_stride == 1             # back to validating everything


def test_budget_policy_latency_cadence_floor():
    p = BudgetPolicy(target_depth=4, smooth=0.0)
    p.observe_latency(10.0)                    # validation takes 10s
    p.observe_cadence(2.0)                     # checkpoints every 2s
    sel = p.select([1, 2, 3])                  # shallow queue alone says 1
    assert p.effective_stride == 5             # but latency/cadence floors it
    assert sel == [3] or sel[-1] == 3


def test_budget_policy_newest_always_selected_bounds_staleness():
    p = BudgetPolicy(max_stride=64)
    for lo in range(0, 640, 64):
        sel = p.select(list(range(lo, lo + 64)))
        assert (lo + 63) in sel                # staleness <= one validation


def test_budget_policy_select_empty():
    assert BudgetPolicy().select([]) == []


# ---------------------------------------------------------------------------
# protect_set() × quality-aware GC (no deletion races, no protection leaks)
# ---------------------------------------------------------------------------

def _toy_validator(root, policy=None, **kw):
    """AsyncValidator over a trivially-failing pipeline double."""
    from repro.core.validator import AsyncValidator

    class PipeDouble:
        def validate_params(self, params, step=0, engine=None):
            from repro.core.pipeline import ValidationResult
            return ValidationResult(step=step, metrics={"m": step / 100.0},
                                    timings={"total_s": 0.001}, subset_size=1)

    return AsyncValidator(root, PipeDouble(), policy=policy, **kw)


def test_protect_set_excludes_policy_skipped_but_keeps_failed(tmp_path):
    root = str(tmp_path / "ck")
    for s in (10, 15, 20):
        _save(root, s)
    v = _toy_validator(root, policy=Policy(kind="stride", stride=10),
                       params_extractor=lambda s: s, max_retries=0)
    v.validate_pending()
    # 15 was policy-skipped: permanently unprotected; 10, 20 validated
    assert v.ledger.validated_steps == [10, 20]
    assert v.protect_set() == set()
    _save(root, 30)                            # committed, pending
    assert v.protect_set() == {30}


def test_failed_step_stays_protected_through_quality_gc(tmp_path):
    """A checkpoint whose validation keeps failing must survive quality GC
    until it is validated — no validated-but-unprotected deletion race."""
    from repro.control import CheckpointSelector, SelectionConfig
    root = str(tmp_path / "ck")
    for s in (1, 2, 3):
        _save(root, s)
    calls = {"n": 0}

    def flaky(state):
        calls["n"] += 1
        if calls["n"] == 2:                    # second hand-out (step 2) fails
            raise RuntimeError("transient")
        return state

    v = _toy_validator(root, params_extractor=flaky, max_retries=3)
    v.validate_pending()
    assert v.ledger.validated_steps == [1, 3]
    assert v.protect_set() == {2}              # failed, retrying: protected
    sel = CheckpointSelector(SelectionConfig(metric="m", top_k=1))
    for row in v.ledger.rows():
        sel.observe(row["step"], row["metrics"])
    deleted = sel.gc(root, protect=v.protect_set())
    assert deleted == [1]                      # only the quality loser
    assert ckpt.list_steps(root) == [2, 3]     # failed step survived
    v.validate_pending()                       # retry succeeds
    assert v.protect_set() == set()
    sel.observe(2, v.ledger.rows()[-1]["metrics"])
    assert sel.gc(root, protect=v.protect_set()) == [2]
    assert ckpt.list_steps(root) == [3]        # exactly top-1 remains


def test_skipping_policy_storage_does_not_leak_under_quality_gc(tmp_path):
    """Under latest_first, stale-skipped checkpoints are deletable — the
    protect set must not grow without bound."""
    from repro.control import CheckpointSelector, SelectionConfig
    root = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        _save(root, s)
    v = _toy_validator(root, policy=Policy(kind="latest_first"))
    v.validate_pending()
    assert v.ledger.validated_steps == [5]
    assert v.protect_set() == set()            # 1-4 skipped, not protected
    sel = CheckpointSelector(SelectionConfig(metric="m", top_k=1))
    for row in v.ledger.rows():
        sel.observe(row["step"], row["metrics"])
    sel.gc(root, protect=v.protect_set())
    assert ckpt.list_steps(root) == [5]        # skipped stale ones pruned
