import os
import sys

# NOTE: no XLA_FLAGS / device-count override here — smoke tests and benches
# must see the single real CPU device.  Multi-device behaviour is tested via
# subprocesses (tests/test_dryrun.py) so device count never leaks.
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, _SRC)
# subprocess-based tests (forced multi-device) re-import repro in a child
# interpreter: export the path so they work without a PYTHONPATH prefix.
_existing = os.environ.get("PYTHONPATH", "")
if _SRC not in _existing.split(os.pathsep):
    os.environ["PYTHONPATH"] = \
        _SRC + os.pathsep + _existing if _existing else _SRC
