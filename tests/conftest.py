import os
import sys

# NOTE: no XLA_FLAGS / device-count override here — smoke tests and benches
# must see the single real CPU device.  Multi-device behaviour is tested via
# subprocesses (tests/test_dryrun.py) so device count never leaks.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
