"""Pallas kernel validation: shape/dtype sweeps + properties vs jnp oracles.

All kernels run in interpret mode on CPU (the TPU-target path is the same
kernel body); tolerances are fp32-accumulation level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.topk_mips.kernel import (topk_mips_kernel,
                                            topk_mips_kernel_int8)
from repro.kernels.topk_mips.ops import quantize_int8, topk_mips
from repro.kernels.topk_mips.ref import topk_mips_ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# topk_mips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Q,N,D,k", [
    (4, 300, 17, 10),          # ragged everything
    (128, 2048, 128, 100),     # aligned
    (7, 50, 64, 60),           # k > N (clipped)
    (1, 4096, 256, 1),         # top-1
    (33, 1000, 96, 128),       # k > default bn/8
])
def test_topk_mips_matches_ref(Q, N, D, k, dtype):
    q, c = _arr((Q, D), dtype), _arr((N, D), dtype)
    s, i = topk_mips(q, c, k=k)
    rs, ri = topk_mips_ref(q, c, k=min(k, N))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=tol,
                               atol=tol)
    # indices may legitimately differ on exact ties; compare as score sets
    agree = (np.asarray(i) == np.asarray(ri)).mean()
    assert agree > 0.95


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 400), st.integers(1, 80),
       st.integers(1, 50))
def test_topk_mips_property(Q, N, D, k):
    """Top-k scores are sorted desc and are the true row-wise maxima."""
    q, c = _arr((Q, D), jnp.float32), _arr((N, D), jnp.float32)
    s, i = topk_mips(q, c, k=k)
    s, i = np.asarray(s), np.asarray(i)
    assert (np.diff(s, axis=1) <= 1e-6).all()
    full = np.asarray(q) @ np.asarray(c).T
    kk = min(k, N)
    np.testing.assert_allclose(s[:, 0], full.max(axis=1), rtol=1e-5, atol=1e-5)
    gathered = np.take_along_axis(full, i, axis=1)
    np.testing.assert_allclose(gathered, s, rtol=1e-5, atol=1e-5)
    assert (np.sort(full, axis=1)[:, -kk:] >= s[:, -1:] - 1e-5).all()


def _quantized_oracle(q, c):
    """Numpy twin of the int8 scoring path: per-row symmetric quantization,
    EXACT integer accumulation (int32), then the per-row scale outer
    product — what the kernel's raw int32 scores dequantize to."""
    qv, qs = (np.asarray(a) for a in quantize_int8(jnp.asarray(q)))
    cv, cs = (np.asarray(a) for a in quantize_int8(jnp.asarray(c)))
    raw = qv.astype(np.int32) @ cv.astype(np.int32).T       # exact
    return raw.astype(np.float32) * qs * cs.T


@pytest.mark.parametrize("score_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("Q,N,D,k", [
    (4, 300, 17, 10),          # ragged everything
    (16, 1024, 128, 50),       # aligned
    (7, 50, 64, 60),           # k > N (clipped)
])
def test_topk_mips_narrow_dtype_parity(Q, N, D, k, score_dtype):
    """bf16/int8 paths: tolerance vs the f32 ref AND an exact rank-set gate
    vs the same-precision full-score oracle (quantization may legitimately
    reorder near-ties vs f32; it must NOT disagree with its own oracle)."""
    q, c = _arr((Q, D), jnp.float32), _arr((N, D), jnp.float32)
    s, i = topk_mips(q, c, k=k, score_dtype=score_dtype)
    s, i = np.asarray(s), np.asarray(i)
    kk = min(k, N)
    # tolerance gate vs f32 ref: quantization error is bounded
    rs, _ = topk_mips_ref(q, c, k=kk)
    scale = float(np.abs(np.asarray(rs)).max()) or 1.0
    assert np.abs(np.sort(s, 1) - np.sort(np.asarray(rs), 1)).max() \
        <= 0.05 * scale
    # exact rank-set gate vs the same-precision oracle
    if score_dtype == "int8":
        full = _quantized_oracle(q, c)
    else:
        full = np.asarray(jax.lax.dot_general(
            jnp.asarray(q, jnp.bfloat16), jnp.asarray(c, jnp.bfloat16),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32))
    oracle_i = np.argsort(-full, axis=1, kind="stable")[:, :kk]
    for r in range(Q):
        assert set(i[r]) == set(oracle_i[r])


@pytest.mark.parametrize("score_dtype", ["bf16", "int8"])
def test_topk_mips_n_valid_mask_narrow_dtypes(score_dtype):
    """Garbage in the corpus padding rows must be invisible at every
    precision — EXACTLY: per-row quantization means real rows' quantized
    images don't depend on the padding rows at all."""
    Q, N, D, k, n_valid = 8, 256, 32, 12, 200
    q, c = _arr((Q, D), jnp.float32), _arr((N, D), jnp.float32)
    s1, i1 = topk_mips(q, c[:n_valid], k=k, score_dtype=score_dtype)
    c2 = c.at[n_valid:].set(1e6)                 # garbage past n_valid
    s2, i2 = topk_mips(q, c2, k=k, n_valid=n_valid, score_dtype=score_dtype)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_topk_mips_kernel_rejects_k_gt_bn():
    """The raw kernels assert k <= bn (a top-k wider than a corpus tile has
    no single-tile merge); the ops wrapper instead GROWS bn and succeeds."""
    q = jnp.zeros((8, 128), jnp.float32)
    c = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(AssertionError):
        topk_mips_kernel(q, c, k=256, n_valid=128, bq=8, bn=128,
                         interpret=True)
    qv, qs = quantize_int8(q)
    cv, cs = quantize_int8(c)
    with pytest.raises(AssertionError):
        topk_mips_kernel_int8(qv, cv, qs, cs.reshape(1, -1), k=256,
                              n_valid=128, bq=8, bn=128, interpret=True)
    s, i = topk_mips(q, c, k=96, bn=64)          # ops-level: bn grows
    assert np.asarray(s).shape == (8, 96)


def test_topk_mips_int8_per_tile_scales_at_boundaries():
    """Per-corpus-row scales must ride with their tiles: a corpus with a
    1000x magnitude cliff exactly at a bn-tile boundary still dequantizes
    each tile with its own rows' scales (a mixed-up tile/scale pairing
    would surface instantly as wrong winners)."""
    Q, D, bn = 4, 64, 128
    q = _arr((Q, D), jnp.float32)
    tiles = [np.asarray(_arr((bn, D), jnp.float32)) * m
             for m in (1.0, 1000.0, 0.001)]      # cliffs at rows 128, 256
    c = jnp.asarray(np.concatenate(tiles, axis=0))
    s, i = topk_mips(q, c, k=10, bn=bn, score_dtype="int8")
    s, i = np.asarray(s), np.asarray(i)
    full = _quantized_oracle(q, c)
    oracle_i = np.argsort(-full, axis=1, kind="stable")[:, :10]
    for r in range(Q):
        assert set(i[r]) == set(oracle_i[r])
    # dequantized kernel scores equal the exact-int oracle's to ~ulp (the
    # two f32 scale multiplies may reassociate between compilers)
    gathered = np.take_along_axis(full, i, axis=1)
    np.testing.assert_allclose(s, gathered, rtol=1e-6)
    # the big-magnitude tile's rows must dominate the top-k
    assert ((i >= bn) & (i < 2 * bn)).all()


def test_topk_mips_int8_matches_exact_integer_oracle():
    """The kernel's int8 x int8 accumulation is exact: its scores match the
    numpy int32 oracle to reassociation-ulp, never quantization-tolerance."""
    Q, N, D, k = 8, 512, 96, 20
    q, c = _arr((Q, D), jnp.float32), _arr((N, D), jnp.float32)
    s, i = topk_mips(q, c, k=k, score_dtype="int8")
    full = _quantized_oracle(np.asarray(q), np.asarray(c))
    gathered = np.take_along_axis(full, np.asarray(i), axis=1)
    np.testing.assert_allclose(np.asarray(s), gathered, rtol=1e-6)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,T,d,causal", [
    (2, 4, 2, 64, 64, 32, True),       # GQA causal
    (1, 8, 8, 33, 57, 64, False),      # MHA ragged bidir
    (2, 2, 1, 128, 256, 128, True),    # MQA cross-len
    (1, 14, 2, 40, 40, 64, True),      # qwen2-0.5b head config
])
def test_flash_attention_matches_ref(B, H, KV, S, T, d, causal, dtype):
    q = _arr((B, H, S, d), dtype)
    k = _arr((B, KV, T, d), dtype)
    v = _arr((B, KV, T, d), dtype)
    o = flash_attention(q, k, v, causal=causal, bq=32, bk=64)
    r = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


def test_flash_attention_kv_padding_mask():
    """t_valid must make padded keys invisible."""
    B, H, S, T, d = 1, 2, 16, 64, 32
    q, k, v = _arr((B, H, S, d), jnp.float32), _arr((B, H, T, d), jnp.float32), \
        _arr((B, H, T, d), jnp.float32)
    o1 = flash_attention(q, k, v, causal=False, t_valid=40, bq=16, bk=16)
    k2 = k.at[:, :, 40:].set(1e3)          # garbage in padding
    v2 = v.at[:, :, 40:].set(-1e3)
    o2 = flash_attention(q, k2, v2, causal=False, t_valid=40, bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 64),
       st.integers(1, 64), st.sampled_from([16, 32, 64]),
       st.booleans())
def test_flash_attention_property(B, H, S, T, d, causal):
    if causal and T < S:
        T = S
    q = _arr((B, H, S, d), jnp.float32)
    k = _arr((B, H, T, d), jnp.float32)
    v = _arr((B, H, T, d), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, bq=16, bk=32)
    r = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=3e-4,
                               atol=3e-4)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KV,G,T,d,L", [
    (2, 2, 4, 256, 64, 100),
    (1, 8, 1, 512, 128, 512),
    (3, 1, 7, 300, 32, 1),
    (1, 8, 8, 1024, 128, 700),     # deepseek-67b-like GQA decode
])
def test_decode_attention_matches_ref(B, KV, G, T, d, L, dtype):
    q = _arr((B, KV, G, d), dtype)
    k = _arr((B, KV, T, d), dtype)
    v = _arr((B, KV, T, d), dtype)
    o = decode_attention(q, k, v, L, bk=128)
    r = decode_attention_ref(L, q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


def test_decode_attention_length_invariance():
    """Cache contents past ``length`` must not affect the output."""
    B, KV, G, T, d, L = 1, 2, 4, 256, 64, 93
    q = _arr((B, KV, G, d), jnp.float32)
    k = _arr((B, KV, T, d), jnp.float32)
    v = _arr((B, KV, T, d), jnp.float32)
    o1 = decode_attention(q, k, v, L, bk=64)
    k2 = k.at[:, :, L:].set(1e4)
    v2 = v.at[:, :, L:].set(-1e4)
    o2 = decode_attention(q, k2, v2, L, bk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(1, 4), st.integers(1, 8),
       st.integers(1, 200))
def test_decode_attention_property(B, KV, G, L):
    T, d = 256, 32
    q = _arr((B, KV, G, d), jnp.float32)
    k = _arr((B, KV, T, d), jnp.float32)
    v = _arr((B, KV, T, d), jnp.float32)
    o = decode_attention(q, k, v, L, bk=64)
    r = decode_attention_ref(L, q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=3e-4,
                               atol=3e-4)
    # outputs are convex combinations of value rows -> bounded by their range
    vv = np.asarray(v[:, :, :L]).astype(np.float32)
    assert np.asarray(o).max() <= vv.max() + 1e-4
    assert np.asarray(o).min() >= vv.min() - 1e-4
