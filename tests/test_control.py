"""Convergence control plane units: event log, selection, early stopping,
ensembling, quality-aware GC — plus the reporting/ledger hardening the
control consumers depend on."""

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.control import (ControlConfig, ControlPlane, ControlEventLog,
                           CheckpointSelector, EarlyStopConfig,
                           EarlyStopController, SelectionConfig,
                           average_params, greedy_soup, materialize_virtual,
                           replay_ledger, stop_requested, uniform_soup,
                           write_stop_marker)
from repro.control.earlystop import _slope
from repro.core.pipeline import ValidationResult
from repro.core.reporting import CSVLogger
from repro.core.validator import ValidationLedger
from repro.core.watcher import CheckpointWatcher


def _res(step, value, metric="m"):
    return ValidationResult(step=step, metrics={metric: value},
                            timings={"total_s": 0.01}, subset_size=1)


# ---------------------------------------------------------------------------
# Event log
# ---------------------------------------------------------------------------

def test_event_log_appends_persists_and_reloads(tmp_path):
    path = str(tmp_path / "control.jsonl")
    log = ControlEventLog(path)
    log.emit("select", 10, value=0.5)
    log.emit("gc", 10, deleted=[1, 2])
    log.emit("stop", 20, reason="plateau")
    # on-disk rows are valid JSON with dense seq ids
    with open(path) as f:
        rows = [json.loads(l) for l in f]
    assert [r["seq"] for r in rows] == [0, 1, 2]
    # restart: a fresh log continues the sequence
    log2 = ControlEventLog(path)
    assert len(log2) == 3
    log2.emit("select", 30, value=0.6)
    assert log2.events()[-1].seq == 3


def test_event_log_decisions_renumbered_without_actuations():
    log = ControlEventLog()
    log.emit("select", 1, value=0.1)
    log.emit("gc", 1, deleted=[])
    log.emit("select", 2, value=0.2)
    log.emit("stop", 2, reason="plateau")
    dec = log.decisions()
    assert [e.kind for e in dec] == ["select", "select", "stop"]
    assert [e.seq for e in dec] == [0, 1, 2]   # dense despite the gc between


# ---------------------------------------------------------------------------
# CheckpointSelector
# ---------------------------------------------------------------------------

def test_selector_best_topk_and_tiebreak():
    sel = CheckpointSelector(SelectionConfig(metric="m", top_k=2))
    for s, v in [(10, 0.1), (20, 0.5), (30, 0.4), (40, 0.5)]:
        sel.observe(s, {"m": v})
    assert sel.best_step == 40                 # tie -> later (fresher) step
    assert sel.top_steps() == [40, 20]
    assert sel.ranking()[0] == (40, 0.5)


def test_selector_min_mode():
    sel = CheckpointSelector(SelectionConfig(metric="rank", mode="min",
                                             top_k=2))
    for s, v in [(1, 9.0), (2, 3.0), (3, 5.0)]:
        sel.observe(s, {"rank": v})
    assert sel.best_step == 2
    assert sel.top_steps() == [2, 3]


def test_selector_ema_smoothing_denoises_spike():
    """A one-evaluation spike wins raw ranking but not the smoothed one."""
    noisy = [(1, 0.50), (2, 0.52), (3, 0.90), (4, 0.60), (5, 0.62)]
    raw = CheckpointSelector(SelectionConfig(metric="m", top_k=1))
    smooth = CheckpointSelector(SelectionConfig(metric="m", top_k=1, ema=0.8))
    for s, v in noisy:
        raw.observe(s, {"m": v})
        smooth.observe(s, {"m": v})
    assert raw.best_step == 3                  # spike wins raw
    assert smooth.best_step != 3               # smoothed ranking rejects it


def test_selector_new_best_decisions():
    sel = CheckpointSelector(SelectionConfig(metric="m", top_k=3))
    d1 = sel.observe(1, {"m": 0.3})
    d2 = sel.observe(2, {"m": 0.2})
    d3 = sel.observe(3, {"m": 0.4})
    assert d1["new_best"] and not d2["new_best"] and d3["new_best"]
    assert d2["best_step"] == 1 and d3["best_step"] == 3


def _toy_tree(seed):
    return {"params": {"w": jnp.asarray(np.random.default_rng(seed)
                                        .normal(size=(4,)), jnp.float32)},
            "opt_state": {}}


def test_selector_quality_aware_gc_keeps_topk_union_protect(tmp_path):
    root = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(root, s, _toy_tree(s))
    sel = CheckpointSelector(SelectionConfig(metric="m", top_k=2))
    for s, v in [(1, 0.9), (2, 0.1), (3, 0.8), (4, 0.2)]:
        sel.observe(s, {"m": v})
    # 5 is committed but unvalidated -> protected; 2, 4 lose on quality
    deleted = sel.gc(root, protect={5})
    assert sorted(deleted) == [2, 4]
    assert ckpt.list_steps(root) == [1, 3, 5]
    gc_events = [e for e in sel.events if e.kind == "gc"]
    assert gc_events[-1].payload["kept"] == [1, 3, 5]


def test_gc_checkpoints_keep_set_and_keep_last_modes(tmp_path):
    root = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        ckpt.save(root, s, _toy_tree(s))
    # explicit keep set overrides recency entirely
    deleted = ckpt.gc_checkpoints(root, keep={1, 4}, protect={2})
    assert sorted(deleted) == [3]
    assert ckpt.list_steps(root) == [1, 2, 4]
    # keep_last window still works (backward compat)
    assert ckpt.gc_checkpoints(root, keep_last=1) == [1, 2]


def test_gc_keep_mode_spares_steps_newer_than_decision(tmp_path):
    """TOCTOU guard: a checkpoint committed AFTER keep/protect were
    computed (it is newer than every step the decision knew about) must
    survive the sweep — it has no quality verdict yet."""
    root = str(tmp_path / "ck")
    for s in (1, 2, 3):
        ckpt.save(root, s, _toy_tree(s))
    keep, protect = {3}, {2}                   # decision snapshot: 1..3
    ckpt.save(root, 4, _toy_tree(4))           # trainer commits concurrently
    deleted = ckpt.gc_checkpoints(root, keep=keep, protect=protect)
    assert deleted == [1]                      # 4 is past the horizon
    assert ckpt.list_steps(root) == [2, 3, 4]
    # an empty decision deletes nothing
    assert ckpt.gc_checkpoints(root, keep=set()) == []


# ---------------------------------------------------------------------------
# EarlyStopController
# ---------------------------------------------------------------------------

def test_earlystop_patience_and_min_delta(tmp_path):
    stop = str(tmp_path / "STOP")
    es = EarlyStopController(EarlyStopConfig(metric="m", patience=2,
                                             min_delta=0.05),
                             stop_path=stop)
    es.observe(1, {"m": 0.50})
    es.observe(2, {"m": 0.52})                 # +0.02 < min_delta: bad eval
    assert not es.stopped
    es.observe(3, {"m": 0.53})                 # still within noise
    assert es.stopped and es.reason == "plateau"
    verdict = stop_requested(stop)
    assert verdict["reason"] == "plateau" and verdict["best_step"] == 1
    assert verdict["step"] == 3


def test_earlystop_improvement_resets_patience():
    es = EarlyStopController(EarlyStopConfig(metric="m", patience=2))
    for s, v in [(1, 0.1), (2, 0.1), (3, 0.2), (4, 0.2)]:
        es.observe(s, {"m": v})
    assert not es.stopped                      # step 3 improved -> reset
    es.observe(5, {"m": 0.2})
    assert es.stopped


def test_earlystop_min_mode():
    es = EarlyStopController(EarlyStopConfig(metric="loss", mode="min",
                                             patience=2))
    for s, v in [(1, 1.0), (2, 0.5), (3, 0.6), (4, 0.7)]:
        stop = es.observe(s, {"loss": v})
    assert stop and es.best == 0.5 and es.best_step == 2


def test_earlystop_overfit_detector_needs_train_feed():
    cfg = EarlyStopConfig(metric="m", patience=10, overfit_window=3)
    # val worsening + train improving -> overfit
    es = EarlyStopController(cfg)
    for s, v, t in [(1, 0.50, 1.0), (2, 0.49, 0.9), (3, 0.48, 0.8)]:
        es.observe(s, {"m": v}, train_loss=t)
    assert es.stopped and es.reason == "overfit"
    # same val trend without train losses: gap undefined, no verdict
    es2 = EarlyStopController(cfg)
    for s, v in [(1, 0.50), (2, 0.49), (3, 0.48)]:
        es2.observe(s, {"m": v})
    assert not es2.stopped
    # val worsening while train ALSO worsening is divergence, not overfit
    es3 = EarlyStopController(cfg)
    for s, v, t in [(1, 0.50, 0.8), (2, 0.49, 0.9), (3, 0.48, 1.0)]:
        es3.observe(s, {"m": v}, train_loss=t)
    assert not es3.stopped


def test_earlystop_latched_after_stop():
    es = EarlyStopController(EarlyStopConfig(metric="m", patience=1))
    es.observe(1, {"m": 0.5})
    es.observe(2, {"m": 0.4})
    assert es.stopped
    # drain-time rows cannot un-stop, and no second stop event is emitted
    assert es.observe(3, {"m": 0.9}) is True
    assert len([e for e in es.events if e.kind == "stop"]) == 1


def test_slope_least_squares():
    assert _slope([0.0, 1.0, 2.0]) == pytest.approx(1.0)
    assert _slope([5.0, 5.0, 5.0]) == pytest.approx(0.0)
    assert _slope([3.0, 2.0, 1.0]) == pytest.approx(-1.0)


def test_stop_marker_atomic_write_and_poll(tmp_path):
    path = str(tmp_path / "sub" / "STOP")
    assert stop_requested(path) is None
    write_stop_marker(path, {"reason": "plateau", "step": 7})
    assert not os.path.exists(path + ".tmp")   # tmp renamed away
    assert stop_requested(path)["step"] == 7


def test_trainer_polls_stop_marker_between_steps(tmp_path):
    """Training halts on the marker without finishing the step budget and
    commits its final state."""
    from repro.train import optim
    from repro.train.trainer import Trainer, TrainerConfig
    stop = str(tmp_path / "STOP")
    ckdir = str(tmp_path / "ck")

    def loss_fn(params, batch):
        return jnp.mean(params["w"] ** 2), {}

    marker_written = {}

    def batches(step):
        if step == 7 and not marker_written:
            write_stop_marker(stop, {"reason": "test", "step": step})
            marker_written["at"] = step
        return {"x": jnp.zeros((1,), jnp.float32)}

    cfg = TrainerConfig(total_steps=100, ckpt_every=50, log_every=50,
                        ckpt_dir=ckdir, async_save=False, stop_file=stop)
    tr = Trainer(cfg, loss_fn, optim.adamw(1e-2),
                 {"w": jnp.ones((2,), jnp.float32)}, batches)
    tr.run()
    assert tr.stopped_early and tr.step == 8   # stopped before step 9
    assert tr.stop_verdict["reason"] == "test"
    assert ckpt.list_steps(ckdir) == [8]       # final state committed


# ---------------------------------------------------------------------------
# Ensembling
# ---------------------------------------------------------------------------

def test_average_params_weighted_and_dtype():
    t1 = {"w": jnp.asarray([1.0, 2.0], jnp.float32)}
    t2 = {"w": jnp.asarray([3.0, 4.0], jnp.float32)}
    avg = average_params([t1, t2])
    np.testing.assert_allclose(np.asarray(avg["w"]), [2.0, 3.0])
    assert np.asarray(avg["w"]).dtype == np.float32
    w = average_params([t1, t2], weights=[3.0, 1.0])
    np.testing.assert_allclose(np.asarray(w["w"]), [1.5, 2.5])


def test_uniform_soup_and_materialize_roundtrip(tmp_path):
    root = str(tmp_path / "ck")
    for s, fill in [(1, 1.0), (2, 3.0)]:
        ckpt.save(root, s, {"params": {"w": jnp.full((3,), fill)},
                            "opt_state": {}})
    soup = uniform_soup(root, [1, 2])
    np.testing.assert_allclose(np.asarray(soup["w"]), np.full((3,), 2.0))
    vstep = materialize_virtual(root, soup, members=[1, 2])
    assert vstep == 3                          # newest + 1
    # indistinguishable downstream: committed, restorable, watcher-visible
    state, extra = ckpt.restore(root, vstep)
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.asarray(soup["w"]))
    assert extra["ensemble_of"] == [1, 2]
    assert vstep in CheckpointWatcher(root).poll()


def test_greedy_soup_never_scores_below_best_single(tmp_path):
    """The greedy filter rejects a poisonous member; the soup's score is
    >= the best single under the same score_fn."""
    root = str(tmp_path / "ck")
    target = np.asarray([1.0, 1.0, 1.0, 1.0])
    fills = {1: [1.0, 1.0, 1.0, 0.8],          # best
             2: [1.1, 0.9, 1.0, 0.9],          # helpful
             3: [-5.0, 9.0, -4.0, 6.0]}        # poison
    for s, w in fills.items():
        ckpt.save(root, s, {"params": {"w": jnp.asarray(w, jnp.float32)},
                            "opt_state": {}})

    def score(params):
        return -float(np.sum((np.asarray(params["w"]) - target) ** 2))

    singles = {s: score({"w": np.asarray(w, np.float32)})
               for s, w in fills.items()}
    ranked = sorted(singles, key=lambda s: -singles[s])
    params, members, sc = greedy_soup(root, ranked, score)
    assert 3 not in members                    # poison rejected
    assert sc >= max(singles.values())


def test_trainer_resumes_past_virtual_checkpoint(tmp_path):
    """A restarted trainer must resume from the newest TRAINED checkpoint,
    not the ensemble soup (which has no optimizer state)."""
    from repro.train import optim
    from repro.train.trainer import Trainer, TrainerConfig
    ckdir = str(tmp_path / "ck")

    def loss_fn(params, batch):
        return jnp.mean(params["w"] ** 2), {}

    cfg = TrainerConfig(total_steps=10, ckpt_every=5, log_every=5,
                        ckpt_dir=ckdir, async_save=False)
    tr = Trainer(cfg, loss_fn, optim.adamw(1e-2),
                 {"w": jnp.ones((2,), jnp.float32)}, lambda s: {})
    tr.run()
    soup = uniform_soup(ckdir, [5, 10])
    vstep = materialize_virtual(ckdir, soup, members=[5, 10])
    assert vstep == 11
    tr2 = Trainer(TrainerConfig(total_steps=12, ckpt_every=5, log_every=5,
                                ckpt_dir=ckdir, async_save=False),
                  loss_fn, optim.adamw(1e-2),
                  {"w": jnp.ones((2,), jnp.float32)}, lambda s: {})
    assert tr2.step == 10                      # resumed past the soup
    tr2.run()                                  # optimizer state intact
    assert tr2.step == 12


# ---------------------------------------------------------------------------
# ControlPlane + offline replay
# ---------------------------------------------------------------------------

def test_plane_train_loss_lookup():
    plane = ControlPlane(None, ControlConfig(metric="m"))
    plane.note_train(10, {"loss": 1.0})
    plane.note_train(20, {"loss": 0.5})
    assert plane.train_loss_for(5) is None
    assert plane.train_loss_for(10) == 1.0
    assert plane.train_loss_for(15) == 1.0
    assert plane.train_loss_for(25) == 0.5


def test_plane_replay_reproduces_decisions():
    cfg = ControlConfig(metric="m", early_stop=True, patience=2,
                        min_delta=0.01, keep_top_k=2)
    online = ControlPlane(None, cfg)
    rows = []
    for s, v in [(10, 0.2), (20, 0.5), (30, 0.5), (40, 0.5), (50, 0.5)]:
        online.observe(s, {"m": v})
        rows.append({"step": s, "metrics": {"m": v}})
    assert online.stopped
    offline = replay_ledger(rows, cfg)
    assert offline.events.decisions() == online.events.decisions()
    assert offline.stopped and offline.selector.best_step == \
        online.selector.best_step


def test_plane_ema_smooths_earlystop_too():
    """--ema must de-noise the EARLY-STOP series, not just the ranking: a
    raw spike resets patience, the smoothed one does not."""
    series = [(1, 0.5), (2, 0.5), (3, 0.9), (4, 0.5), (5, 0.5)]
    smooth = ControlPlane(None, ControlConfig(
        metric="m", early_stop=True, patience=2, min_delta=0.05, ema=0.95))
    raw = ControlPlane(None, ControlConfig(
        metric="m", early_stop=True, patience=2, min_delta=0.05))
    stopped_at = {}
    for s, v in series:
        for name, plane in (("smooth", smooth), ("raw", raw)):
            plane.observe(s, {"m": v})
            if plane.stopped and name not in stopped_at:
                stopped_at[name] = s
    assert stopped_at["smooth"] == 3           # spike damped: still plateau
    assert stopped_at["raw"] == 5              # spike reset raw patience


def test_plane_on_result_runs_gc_with_protection(tmp_path):
    from repro.core.samplers import RunFileTopK  # noqa: F401 (import check)
    root = str(tmp_path / "ck")
    for s in (1, 2, 3):
        ckpt.save(root, s, _toy_tree(s))

    class FakeValidator:
        def protect_set(self):
            return {3}                          # 3 not validated yet

    plane = ControlPlane(root, ControlConfig(metric="m", keep_top_k=1))
    for s, v in [(1, 0.9), (2, 0.1)]:
        plane.on_result(_res(s, v), FakeValidator())
    assert ckpt.list_steps(root) == [1, 3]      # top-1 ∪ protected


def test_plane_ensemble_skips_gc_deleted_members(tmp_path):
    """Regression: with ensemble_top_k > keep_top_k the ranking tail is
    already GC-deleted — the soup must only use checkpoints still on disk
    instead of crashing on restore."""
    root = str(tmp_path / "ck")
    for s, fill in [(1, 1.0), (2, 2.0), (3, 4.0)]:
        ckpt.save(root, s, {"params": {"w": jnp.full((2,), fill)},
                            "opt_state": {}})
    plane = ControlPlane(root, ControlConfig(metric="m", keep_top_k=2,
                                             ensemble_top_k=3,
                                             ensemble_greedy=False))
    validated = set()

    class V:                                   # real contract: committed
        def protect_set(self):                 # minus validated stays safe
            return set(ckpt.list_steps(root)) - validated

    for s, v in [(1, 0.1), (2, 0.5), (3, 0.9)]:
        validated.add(s)
        plane.on_result(_res(s, v), V())
    assert ckpt.list_steps(root) == [2, 3]     # rank tail (1) deleted
    vstep = plane.build_ensemble(lambda p: 0.0)
    assert vstep is not None
    assert plane.ensemble_members == [3, 2]    # survivor set only
    np.testing.assert_allclose(
        np.asarray(ckpt.restore(root, vstep)[0]["params"]["w"]),
        np.full((2,), 3.0))                    # mean of fills 2.0, 4.0


def test_plane_rehydrate_protects_prior_best_across_restart(tmp_path):
    """Restart data loss: a fresh selector must be warmed from the prior
    session's ledger, or quality GC would delete the old best checkpoints
    (idempotency means they are never re-validated)."""
    root = str(tmp_path / "ck")
    led_path = str(tmp_path / "ledger.jsonl")
    led = ValidationLedger(led_path)
    for s, v in [(10, 0.9), (20, 0.8)]:        # session 1: validated + kept
        ckpt.save(root, s, _toy_tree(s))
        led.record(_res(s, v))
    # session 2: fresh process, new (worse) checkpoint arrives
    led2 = ValidationLedger(led_path)
    plane = ControlPlane(root, ControlConfig(metric="m", keep_top_k=2))
    assert plane.rehydrate(led2.rows()) == 2
    assert plane.selector.top_steps() == [10, 20]
    ckpt.save(root, 30, _toy_tree(30))

    class V:
        def protect_set(self):
            return set()                       # 30 validated below

    plane.on_result(_res(30, 0.1), V())
    assert ckpt.list_steps(root) == [10, 20]   # old best kept, loser GC'd


def test_validate_step_bypasses_skipping_policy(tmp_path):
    """A virtual (ensemble) checkpoint's step id is rarely on-stride: the
    explicit validate_step path must score it anyway, ledger it, and not
    leave it counted as policy-skipped."""
    from repro.core.watcher import Policy
    from test_watcher_policies import _toy_validator
    root = str(tmp_path / "ck")
    for s in (10, 20):
        ckpt.save(root, s, _toy_tree(s))
    v = _toy_validator(root, policy=Policy(kind="stride", stride=10))
    v.validate_pending()
    assert v.ledger.validated_steps == [10, 20]
    ckpt.save(root, 21, _toy_tree(21))         # off-stride soup step
    assert v.validate_pending() == 0           # policy would skip it...
    assert 21 in v.watcher.skipped
    assert v.validate_step(21) == 1            # ...explicit path scores it
    assert 21 in v.ledger.validated_steps
    assert 21 not in v.watcher.skipped         # claimed, not skipped
    assert v.validate_step(21) == 0            # still ledger-idempotent


def test_plane_ensemble_disabled_paths(tmp_path):
    plane = ControlPlane(str(tmp_path), ControlConfig(metric="m"))
    assert plane.build_ensemble(lambda p: 0.0) is None   # top_k = 0
    plane2 = ControlPlane(str(tmp_path),
                          ControlConfig(metric="m", ensemble_top_k=2))
    plane2.observe(1, {"m": 0.5})
    assert plane2.build_ensemble(lambda p: 0.0) is None  # < 2 members


# ---------------------------------------------------------------------------
# Satellite: ValidationLedger concurrency safety
# ---------------------------------------------------------------------------

def test_ledger_concurrent_records_and_reads(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    led = ValidationLedger(path)
    n_threads, per_thread = 8, 25
    errors = []

    def writer(base):
        try:
            for i in range(per_thread):
                led.record(_res(base * 1000 + i, 0.5))
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    def reader():
        try:
            for _ in range(50):
                for row in led.rows():          # snapshot: no mutation races
                    assert "step" in row and "metrics" in row
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)] + \
              [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(led.rows()) == n_threads * per_thread
    # every persisted line is a complete row (no torn appends)
    with open(path) as f:
        lines = [json.loads(l) for l in f if l.strip()]
    assert len(lines) == n_threads * per_thread
    # a restarted ledger sees the identical row set
    led2 = ValidationLedger(path)
    assert led2.validated_steps == led.validated_steps


def test_ledger_rows_preserve_record_order(tmp_path):
    """Replay fidelity: rows() is RECORD order (decision order), even when
    steps complete out of numeric order."""
    led = ValidationLedger(str(tmp_path / "l.jsonl"))
    for s in (30, 10, 20):
        led.record(_res(s, 0.1))
    assert [r["step"] for r in led.rows()] == [30, 10, 20]
    assert led.validated_steps == [10, 20, 30]


# ---------------------------------------------------------------------------
# Satellite: CSVLogger restart data loss
# ---------------------------------------------------------------------------

def test_csvlogger_restart_appends_instead_of_truncating(tmp_path):
    """Regression: a fresh process's first log() used to open the CSV with
    mode "w" (fields unknown), wiping the history the control plane now
    consumes."""
    path = str(tmp_path / "m.csv")
    lg1 = CSVLogger(path)
    lg1.log(1, {"mrr": 0.1})
    lg1.log(2, {"mrr": 0.2})
    # fresh process, same fields -> plain append
    lg2 = CSVLogger(path)
    lg2.log(3, {"mrr": 0.3})
    import csv as _csv
    with open(path) as f:
        rows = list(_csv.DictReader(f))
    assert [r["step"] for r in rows] == ["1", "2", "3"]
    # fresh process, NEW field -> header widens, history preserved
    lg3 = CSVLogger(path)
    lg3.log(4, {"mrr": 0.4, "recall": 0.9})
    with open(path) as f:
        rows = list(_csv.DictReader(f))
    assert [r["step"] for r in rows] == ["1", "2", "3", "4"]
    assert rows[0]["mrr"] == "0.1" and rows[3]["recall"] == "0.9"
