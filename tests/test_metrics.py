"""IR metrics + fidelity statistics: hand-computed cases and properties."""

import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import fidelity
from repro.core import metrics as M

QRELS = {"q1": {"d1": 1}, "q2": {"d9": 1, "d5": 2}, "q3": {"d7": 1}}
RUN = {"q1": ["d3", "d1", "d2"],          # gold at rank 2
       "q2": ["d5", "d2", "d9"],          # golds at ranks 1 and 3
       "q3": ["d2", "d3", "d4"]}          # gold missing


def test_mrr():
    # (1/2 + 1/1 + 0) / 3
    assert M.mrr_at_k(RUN, QRELS, 10) == pytest.approx((0.5 + 1.0) / 3)
    assert M.mrr_at_k(RUN, QRELS, 1) == pytest.approx(1.0 / 3)


def test_recall():
    # q1: 1/1, q2: 2/2, q3: 0/1
    assert M.recall_at_k(RUN, QRELS, 10) == pytest.approx(2 / 3)
    assert M.recall_at_k(RUN, QRELS, 1) == pytest.approx((0 + 0.5 + 0) / 3)


def test_success():
    assert M.success_at_k(RUN, QRELS, 1) == pytest.approx(1 / 3)
    assert M.success_at_k(RUN, QRELS, 3) == pytest.approx(2 / 3)


def test_ndcg():
    # q1: dcg = 1/log2(3), idcg = 1 -> 0.6309...
    q1 = (2 ** 1 - 1) / math.log2(3)
    # q2: dcg = (2^2-1)/log2(2) + (2^1-1)/log2(4) = 3 + 0.5
    #     idcg = 3/log2(2) + 1/log2(3)
    q2 = 3.5 / (3 + 1 / math.log2(3))
    assert M.ndcg_at_k(RUN, QRELS, 10) == pytest.approx((q1 + q2 + 0) / 3)


def test_average_rank():
    # q1 -> 2, q2 -> 1, q3 -> missing = len+1 = 4
    assert M.average_rank(RUN, QRELS) == pytest.approx((2 + 1 + 4) / 3)


def test_parse_metric_and_compute_all():
    out = M.compute_metrics(RUN, QRELS,
                            ["MRR@10", "Recall@3", "nDCG@10", "Success@1",
                             "AverageRank"])
    assert set(out) == {"MRR@10", "Recall@3", "nDCG@10", "Success@1",
                        "AverageRank"}
    with pytest.raises(ValueError):
        M.parse_metric("BogusMetric@5")


def test_trec_run_roundtrip(tmp_path):
    path = str(tmp_path / "run.trec")
    scores = {q: [10.0 - i for i in range(len(docs))]
              for q, docs in RUN.items()}
    M.write_trec_run(path, RUN, scores, tag="test")
    back = M.read_trec_run(path)
    for q, docs in RUN.items():
        assert [d for d, _ in back[q]] == docs


def test_trec_qrels_io(tmp_path):
    path = str(tmp_path / "qrels.txt")
    with open(path, "w") as f:
        for q, docs in QRELS.items():
            for d, g in docs.items():
                f.write(f"{q} 0 {d} {g}\n")
    assert M.read_trec_qrels(path) == QRELS


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=2, max_size=20, unique=True))
def test_mrr_bounded_and_monotone_in_k(ranks):
    """MRR in [0,1] and non-decreasing in k."""
    run = {"q": [f"d{i}" for i in ranks]}
    qrels = {"q": {f"d{ranks[0]}": 1}}
    vals = [M.mrr_at_k(run, qrels, k) for k in (1, 3, 5, 100)]
    assert all(0.0 <= v <= 1.0 for v in vals)
    assert vals == sorted(vals)


# ---------------------------------------------------------------------------
# fidelity statistics
# ---------------------------------------------------------------------------

def test_correlations_perfect_and_inverted():
    a = [0.1, 0.2, 0.3, 0.4]
    assert fidelity.spearman(a, a) == pytest.approx(1.0)
    assert fidelity.spearman(a, a[::-1]) == pytest.approx(-1.0)
    assert fidelity.kendall_tau(a, a) == pytest.approx(1.0)
    assert fidelity.kendall_tau(a, a[::-1]) == pytest.approx(-1.0)
    assert fidelity.pearson(a, [2 * x + 1 for x in a]) == pytest.approx(1.0)


def test_best_checkpoint_agreement():
    ref = [0.1, 0.3, 0.2]
    assert fidelity.best_checkpoint_agreement(ref, [0.5, 0.9, 0.6])
    assert not fidelity.best_checkpoint_agreement(ref, [0.9, 0.5, 0.6])
    # lower-is-better (AverageRank)
    assert fidelity.best_checkpoint_agreement([3, 1, 2], [30, 10, 20],
                                              higher_is_better=False)


def test_overestimation_report():
    rep = fidelity.overestimation([0.1, 0.2], [0.15, 0.3])
    assert rep["always_overestimates"] == 1.0
    assert rep["mean_delta"] == pytest.approx(0.075)


def test_fidelity_report_keys():
    rep = fidelity.fidelity_report([0.1, 0.2, 0.3], [0.2, 0.25, 0.4])
    for k in ("pearson", "spearman", "kendall_tau", "best_ckpt_agreement",
              "mean_delta"):
        assert k in rep


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=3, max_size=15),
       st.lists(st.floats(-100, 100), min_size=3, max_size=15))
def test_correlation_bounds(a, b):
    n = min(len(a), len(b))
    a, b = a[:n], b[:n]
    for fn in (fidelity.pearson, fidelity.spearman, fidelity.kendall_tau):
        v = fn(a, b)
        assert -1.0 - 1e-9 <= v <= 1.0 + 1e-9
