"""Exact MIPS retrieval: blocked scan, sharded hierarchical merge,
rerank scoring, and the encoder batching path."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import retrieval as R
from repro.core.encoder import encode_texts


def _qc(Q=8, N=500, D=24, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(Q, D)), jnp.float32),
            jnp.asarray(rng.normal(size=(N, D)), jnp.float32))


def test_topk_exact_matches_dense():
    q, c = _qc()
    s, i = R.topk_exact(q, c, k=25, block=64)
    full = np.asarray(q) @ np.asarray(c).T
    es, ei = jax.lax.top_k(jnp.asarray(full), 25)
    np.testing.assert_allclose(np.asarray(s), np.asarray(es), rtol=1e-5)
    assert (np.asarray(i) == np.asarray(ei)).mean() > 0.99


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 10), st.integers(1, 300), st.integers(1, 40),
       st.integers(1, 60), st.sampled_from([16, 100, 4096]))
def test_topk_exact_property(Q, N, D, k, block):
    q, c = _qc(Q, N, D, seed=Q * N + D)
    s, i = R.topk_exact(q, c, k=k, block=block)
    kk = min(k, N)
    assert s.shape == (Q, kk)
    full = np.asarray(q) @ np.asarray(c).T
    np.testing.assert_allclose(np.asarray(s[:, 0]), full.max(1), rtol=1e-5,
                               atol=1e-5)
    got = np.take_along_axis(full, np.asarray(i), axis=1)
    np.testing.assert_allclose(got, np.asarray(s), rtol=1e-5, atol=1e-5)


def test_topk_exact_block_invariance():
    q, c = _qc(5, 333, 16)
    outs = [np.asarray(R.topk_exact(q, c, k=10, block=b)[0])
            for b in (7, 64, 512)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6)


def test_retrieve_run_and_rerank_run():
    q, c = _qc(4, 60, 12)
    qids = [f"q{i}" for i in range(4)]
    dids = [f"d{i}" for i in range(60)]
    run, scores = R.retrieve_run(qids, q, dids, c, k=5)
    assert all(len(run[x]) == 5 for x in qids)
    full = np.asarray(q) @ np.asarray(c).T
    for qi, qid in enumerate(qids):
        assert run[qid][0] == dids[int(full[qi].argmax())]
    per_query = {qid: dids[:10] for qid in qids}
    rr, rs = R.rerank_run(qids, q, dids, c, per_query, k=5)
    for qid in qids:
        assert set(rr[qid]) <= set(per_query[qid])
        assert rs[qid] == sorted(rs[qid], reverse=True)


def test_topk_sharded_multidevice_subprocess():
    """Hierarchical sharded merge == dense result (8 forced host devices)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import retrieval as R
        from repro.distributed import compat
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(6, 16)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(400, 16)), jnp.float32)
        s, i = R.topk_sharded(mesh, q, c, k=17, block=32)
        full = np.asarray(q) @ np.asarray(c).T
        es, ei = jax.lax.top_k(jnp.asarray(full), 17)
        np.testing.assert_allclose(np.asarray(s), np.asarray(es), rtol=1e-5)
        assert (np.asarray(i) == np.asarray(ei)).mean() > 0.99
        print("SHARDED_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "SHARDED_OK" in out.stdout, out.stdout + out.stderr


def test_encode_texts_ragged_batching():
    """Final ragged batch is padded and sliced; single compiled shape."""
    def enc(params, tokens, mask):
        emb = jnp.take(params["t"], tokens, axis=0)
        m = mask.astype(emb.dtype)[..., None]
        return (emb * m).sum(1)

    params = {"t": jnp.asarray(np.random.default_rng(0).normal(size=(50, 8)),
                               jnp.float32)}
    texts = [[1, 2, 3], [4], [5, 6], [7, 8, 9], [10]]         # 5 texts
    embs, stats = encode_texts(enc, params, texts, max_len=4, batch_size=2)
    assert embs.shape == (5, 8)
    assert stats.n_batches == 3                                # 2+2+1(padded)
    # order and values match one-at-a-time encoding
    for i, t in enumerate(texts):
        toks = np.zeros((1, 4), np.int32)
        msk = np.zeros((1, 4), bool)
        toks[0, :len(t)] = t
        msk[0, :len(t)] = True
        one = np.asarray(enc(params, jnp.asarray(toks), jnp.asarray(msk)))[0]
        np.testing.assert_allclose(embs[i], one, rtol=1e-6)


def test_pallas_impl_matches_xla_impl():
    q, c = _qc(6, 300, 32)
    qids = [f"q{i}" for i in range(6)]
    dids = [f"d{i}" for i in range(300)]
    run_x, _ = R.retrieve_run(qids, q, dids, c, k=10, impl="xla")
    run_p, _ = R.retrieve_run(qids, q, dids, c, k=10, impl="pallas")
    for qid in qids:
        assert run_x[qid] == run_p[qid]
