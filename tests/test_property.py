"""Hypothesis property tests for system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: whole module is property tests
from hypothesis import given, settings, strategies as st

from repro.ckpt import checkpoint as ckpt
from repro.data.corpus import pad_batch
from repro.models import nn
from repro.models import transformer as tfm

# ---------------------------------------------------------------------------
# checkpoint: arbitrary pytrees round-trip exactly
# ---------------------------------------------------------------------------

_DTYPES = [np.float32, np.int32, np.float64, np.int8]


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(_DTYPES),
                          st.lists(st.integers(1, 5), min_size=0,
                                   max_size=3)),
                min_size=1, max_size=6),
       st.integers(0, 10_000))
def test_checkpoint_roundtrip_property(leaf_specs, step):
    import tempfile
    root = tempfile.mkdtemp(prefix="ckprop_")
    rng = np.random.default_rng(42)
    tree = {f"k{i}": jnp.asarray(
        rng.normal(size=tuple(shape)).astype(dt) * 10)
        for i, (dt, shape) in enumerate(leaf_specs)}
    ckpt.save(root, step, tree)
    back, _ = ckpt.restore(root, step)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]),
                                      np.asarray(back[k]))
        assert tree[k].dtype == back[k].dtype


@settings(max_examples=20, deadline=None)
@given(st.lists(st.lists(st.integers(1, 100), min_size=0, max_size=12),
                min_size=1, max_size=8),
       st.integers(1, 10))
def test_pad_batch_property(token_lists, max_len):
    toks, mask = pad_batch(token_lists, max_len)
    assert toks.shape == mask.shape == (len(token_lists), max_len)
    for i, t in enumerate(token_lists):
        n = min(len(t), max_len)
        assert mask[i, :n].all() and not mask[i, n:].any()
        assert (toks[i, :n] == np.asarray(t[:n])).all()
        assert (toks[i, n:] == 0).all()


# ---------------------------------------------------------------------------
# chunked xent == full xent for arbitrary shapes/chunks
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(2, 9), st.integers(8, 40),
       st.integers(1, 41))
def test_chunked_xent_matches_full_property(B, S, V, chunk):
    rng = np.random.default_rng(B * 100 + S)
    D = 16
    hidden = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.asarray(rng.random((B, S)) > 0.2)
    if not bool(mask.any()):
        mask = mask.at[0, 0].set(True)
    chunked = tfm.chunked_softmax_xent(hidden, w, labels, mask, chunk)
    lg = (hidden @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    lab = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    full = ((lse - lab) * mask).sum() / jnp.clip(mask.sum(), 1)
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(2, 16), st.integers(2, 8), st.integers(1, 3),
       st.integers(1, 6))
def test_moe_dispatch_property(S, E, K, capacity):
    K = min(K, E)
    rng = np.random.default_rng(S * E + K)
    D = 8
    x = jnp.asarray(rng.normal(size=(S, D)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, E, (S, K)), jnp.int32)
    gates = jnp.asarray(rng.random((S, K)), jnp.float32)
    xe, slot_tok, slot_gate, slot_valid = tfm._moe_dispatch(
        x, idx, gates, E, capacity)
    assert xe.shape == (E, capacity, D)
    sv = np.asarray(slot_valid)
    stok = np.asarray(slot_tok)
    # every valid slot holds the token's row exactly
    xe_flat = np.asarray(xe).reshape(E * capacity, D)
    for s in np.nonzero(sv)[0]:
        np.testing.assert_allclose(xe_flat[s], np.asarray(x)[stok[s]],
                                   rtol=1e-6)
    # per-expert valid count never exceeds capacity, and equals
    # min(capacity, assignments)
    assign = np.zeros(E, np.int64)
    for (e_row, g_row) in zip(np.asarray(idx), np.asarray(gates)):
        for e in e_row:
            assign[e] += 1
    per_expert = sv.reshape(E, capacity).sum(1)
    np.testing.assert_array_equal(per_expert, np.minimum(assign, capacity))


def test_moe_block_high_capacity_equals_dense_mixture():
    """With capacity high enough to drop nothing, the MoE output equals the
    explicit gate-weighted mixture of expert MLPs."""
    rng = np.random.default_rng(0)
    cfg = tfm.TransformerConfig(d_model=16, moe_num_experts=4, moe_top_k=2,
                                moe_d_ff=8, moe_capacity_factor=100.0,
                                compute_dtype=jnp.float32,
                                param_dtype=jnp.float32)
    p = nn.materialize(tfm._moe_init(jax.random.PRNGKey(0), cfg))
    x = jnp.asarray(rng.normal(size=(2, 6, 16)), jnp.float32)
    out, aux = tfm._moe_block(p, x, cfg)

    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)

    def expert(e, v):
        h = jax.nn.silu(v @ p["w1"][e]) * (v @ p["w3"][e])
        return h @ p["w2"][e]

    ref = np.zeros_like(np.asarray(out))
    for b in range(2):
        for s in range(6):
            for j in range(2):
                e = int(idx[b, s, j])
                ref[b, s] += float(gates[b, s, j]) * np.asarray(
                    expert(e, x[b, s]))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


# ---------------------------------------------------------------------------
# rope / norm invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 10), st.integers(1, 4),
       st.sampled_from([8, 16, 32]))
def test_rope_preserves_norm(B, S, H, d):
    rng = np.random.default_rng(B + S)
    x = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y = nn.apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-4)
    # position 0 is the identity
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(x[:, 0]),
                               rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.sampled_from([4, 16, 64]))
def test_rmsnorm_scale_invariance(B, D):
    rng = np.random.default_rng(B * D)
    p = nn.materialize(nn.rmsnorm_init(D))
    x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    y1 = nn.rmsnorm(p, x)
    y2 = nn.rmsnorm(p, x * 1000.0)          # rms-norm is scale-invariant
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3,
                               atol=1e-5)
